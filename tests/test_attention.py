"""Attention: chunked online-softmax vs dense oracle; decode path; GQA."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    _repeat_kv,
    chunked_attention,
    dense_attention,
)


@pytest.mark.parametrize("sq,sk,chunk", [(16, 16, 4), (32, 32, 8), (17, 17, 8), (8, 24, 8)])
def test_chunked_matches_dense_causal(rng, sq, sk, chunk):
    b, h, hd = 2, 3, 16
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sk, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sk, h, hd)), jnp.float32)
    off = sk - sq  # causal alignment when kv longer
    d_out = dense_attention(q, k, v, causal=True, q_offset=off)
    c_out = chunked_attention(q, k, v, causal=True, chunk=chunk, q_offset=off)
    np.testing.assert_allclose(np.asarray(c_out), np.asarray(d_out), rtol=1e-4, atol=1e-5)


def test_chunked_matches_dense_windowed(rng):
    b, s, h, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    d_out = dense_attention(q, k, v, causal=True, window=8)
    c_out = chunked_attention(q, k, v, causal=True, chunk=8, window=8)
    np.testing.assert_allclose(np.asarray(c_out), np.asarray(d_out), rtol=1e-4, atol=1e-5)


def test_repeat_kv(rng):
    x = jnp.asarray(rng.standard_normal((2, 4, 2, 8)), jnp.float32)
    y = _repeat_kv(x, 3)
    assert y.shape == (2, 4, 6, 8)
    np.testing.assert_array_equal(np.asarray(y[:, :, 0]), np.asarray(y[:, :, 1]))
    np.testing.assert_array_equal(np.asarray(y[:, :, 3]), np.asarray(y[:, :, 5]))
    assert not np.allclose(np.asarray(y[:, :, 0]), np.asarray(y[:, :, 3]))
