"""Serving supervisor units: perfmodel mode advice, the reconfiguration
decision loop (hysteresis / confirmation / cooldown — never flaps), and
admission control (token buckets, bounded queue, deadline shedding) with
a fake clock. All host-side pure Python — no model, no devices."""

import numpy as np
import pytest

from repro.core.modes import Mode
from repro.core.perfmodel import ServingMix, serving_mode_advice
from repro.serve import (
    AdmissionController,
    AdmissionPolicy,
    AdmissionRejected,
    ControllerConfig,
    ReconfigController,
    Request,
    SamplingParams,
    TenantPolicy,
    WindowSample,
)
from repro.serve.controller import build_continuation

# mixes with a verified preference under the default per-token costs
# (2e9 flops / 1e9 HBM bytes per token) on 2 devices: many independent
# short requests want split replicas; a couple of long decodes want the
# merged fabric's n-times HBM bandwidth on the sequential chain
MANY_SHORT = dict(
    n_requests=64, prompt_tokens=64 * 16.0, decode_tokens=64 * 2.0,
    longest_tokens=2.0, flops_per_token=2e9, hbm_bytes_per_token=1e9,
)
FEW_LONG = dict(
    n_requests=2, prompt_tokens=2 * 16.0, decode_tokens=2 * 256.0,
    longest_tokens=256.0, flops_per_token=2e9, hbm_bytes_per_token=1e9,
)


# ------------------------------------------------------ perfmodel advice


def test_advice_prefers_split_for_many_short():
    best, seconds = serving_mode_advice(ServingMix(**MANY_SHORT), 2)
    assert best == "split"
    assert seconds["split"] < seconds["merge"]


def test_advice_prefers_merge_for_few_long():
    best, seconds = serving_mode_advice(ServingMix(**FEW_LONG), 2)
    assert best == "merge"
    # the sequential decode chain rides the merged fabric's aggregate HBM
    assert seconds["merge"] < 0.75 * seconds["split"]


def test_advice_single_device_never_prefers_merge():
    """n=1 degenerate: merge pays barriers for no extra bandwidth, so a
    single-device controller never has a reason to switch."""
    for mix in (MANY_SHORT, FEW_LONG):
        best, _ = serving_mode_advice(ServingMix(**mix), 1)
        assert best == "split"


# ------------------------------------------------- reconfig decision loop


def _sample(t, mode, mix, queue=0):
    return WindowSample(
        t=t, mode=mode, queue_depth=queue,
        n_requests=mix["n_requests"],
        prompt_tokens=int(mix["prompt_tokens"]),
        decode_tokens=int(mix["decode_tokens"]),
        longest_tokens=int(mix["longest_tokens"]),
    )


def _ctl(**over):
    kw = dict(interval_s=0.1, window_s=0.1, cooldown_s=1.0,
              confirm=2, hysteresis=1.5)
    kw.update(over)
    return ReconfigController(2, ControllerConfig(**kw))


def test_controller_switch_needs_confirmation_streak():
    ctl = _ctl()
    # first long window: preference noted, no commit yet (confirm=2)
    assert ctl.observe(_sample(0.1, "split", FEW_LONG)) is None
    d = ctl.observe(_sample(0.2, "split", FEW_LONG))
    assert d is not None and d.mode is Mode.MERGE
    assert d.predicted_win_s > d.switch_cost_s
    ctl.note_switched(0.2)
    # already in the preferred mode: quiet
    assert ctl.observe(_sample(0.3, "merge", FEW_LONG)) is None


def test_controller_cooldown_blocks_flapping():
    """An adversarial oscillating load cannot flap the fabric: after a
    committed switch every opposite-direction decision inside cooldown_s
    is suppressed, no matter how long the streak."""
    ctl = _ctl(cooldown_s=5.0)
    ctl.observe(_sample(0.1, "split", FEW_LONG))
    d = ctl.observe(_sample(0.2, "split", FEW_LONG))
    assert d is not None
    ctl.note_switched(0.2)
    # the same preference streak keeps re-confirming every interval, but
    # nothing can commit inside the cooldown window
    for i in range(20):
        assert ctl.observe(_sample(0.3 + 0.1 * i, "split", FEW_LONG)) is None
    # past the cooldown the same preference commits again
    d2 = ctl.observe(_sample(5.3, "split", FEW_LONG))
    assert d2 is not None and d2.mode is Mode.MERGE
    assert ctl.switch_times == [0.2]


def test_controller_hysteresis_blocks_marginal_win():
    """The short mix's split-over-merge win (~3ms) never clears 1.5x the
    cold switch cost (~90ms): a marginal preference holds the mode."""
    ctl = _ctl()
    for i in range(6):
        assert ctl.observe(_sample(0.1 * (i + 1), "merge", MANY_SHORT)) is None


def test_controller_idle_window_holds_mode():
    # window shorter than the sampling spacing: every observation stands
    # alone, so an idle interval truly presents an empty mix
    ctl = _ctl(window_s=0.05)
    idle = dict(n_requests=0, prompt_tokens=0.0, decode_tokens=0.0,
                longest_tokens=0.0)
    assert ctl.observe(_sample(0.1, "split", idle)) is None
    # an idle window also resets the confirmation streak
    ctl.observe(_sample(0.2, "split", FEW_LONG))
    assert ctl.observe(_sample(0.3, "split", idle)) is None
    assert ctl.observe(_sample(0.4, "split", FEW_LONG)) is None  # streak restarts


def test_controller_cost_ewma_tracks_measured_switches():
    class Rep:
        def __init__(self, seconds, cached):
            self.seconds, self.cached = seconds, cached

    ctl = _ctl(cold_switch_s=0.060, warm_switch_s=0.006, cost_ewma=0.5)
    ctl.note_switched(1.0, Rep(0.100, cached=False))
    assert ctl.switch_cost(warm=False) == pytest.approx(0.080)
    ctl.note_switched(2.0, Rep(0.002, cached=True))
    assert ctl.switch_cost(warm=True) == pytest.approx(0.004)


# ------------------------------------------------------ admission control


def _req(rid=0, plen=8, max_new=8, tenant=None, deadline_s=None):
    return Request(
        rid=rid, prompt=np.zeros(plen, np.int32),
        params=SamplingParams(max_new=max_new), tenant=tenant,
        deadline_s=deadline_s,
    )


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_admission_rejected_is_typed_valueerror():
    rej = AdmissionRejected("queue_full", "detail here")
    assert isinstance(rej, ValueError)  # legacy except ValueError still works
    assert rej.reason == "queue_full"
    assert "queue_full" in str(rej) and "detail here" in str(rej)
    assert set(AdmissionRejected.REASONS) == {
        "infeasible", "shed_deadline", "rate_limited", "queue_full",
    }


def test_token_bucket_rate_limits_and_refills():
    clock = FakeClock()
    # cost of _req() = 8 prompt + 8 max_new = 16; burst admits exactly 2
    adm = AdmissionController(
        AdmissionPolicy(tenants={"a": TenantPolicy(rate=16.0, burst=32.0)}),
        clock=clock,
    )
    for rid in (0, 1):
        adm.admit(_req(rid, tenant="a"), queue_depth=0, queue_cost=0.0)
    with pytest.raises(AdmissionRejected) as e:
        adm.admit(_req(2, tenant="a"), queue_depth=0, queue_cost=0.0)
    assert e.value.reason == "rate_limited"
    # another tenant is unaffected (default policy: infinite rate)
    adm.admit(_req(3, tenant="b"), queue_depth=0, queue_cost=0.0)
    # one second refills one request's worth of cost tokens
    clock.t = 1.0
    adm.admit(_req(4, tenant="a"), queue_depth=0, queue_cost=0.0)
    assert adm.rate_limited == 1 and adm.admitted == 4


def test_queue_bound_and_priority_headroom():
    adm = AdmissionController(
        AdmissionPolicy(
            max_queue=4, priority_headroom=2.0,
            tenants={"vip": TenantPolicy(priority=1)},
        ),
        clock=FakeClock(),
    )
    with pytest.raises(AdmissionRejected) as e:
        adm.admit(_req(0), queue_depth=4, queue_cost=64.0)
    assert e.value.reason == "queue_full"
    # priority rides the deeper bound (4 x 2.0) before rejection
    adm.admit(_req(1, tenant="vip"), queue_depth=4, queue_cost=64.0)
    with pytest.raises(AdmissionRejected) as e:
        adm.admit(_req(2, tenant="vip"), queue_depth=8, queue_cost=128.0)
    assert e.value.reason == "queue_full"
    assert adm.queue_full == 2 and adm.rejected == 2 and adm.shed == 0


def test_deadline_shedding_uses_predicted_ttft():
    adm = AdmissionController(
        AdmissionPolicy(initial_tok_per_s=100.0), clock=FakeClock()
    )
    # 50 cost tokens queued ahead at 100 tok/s -> predicted TTFT 0.5s
    assert adm.predict_ttft(50.0) == pytest.approx(0.5)
    with pytest.raises(AdmissionRejected) as e:
        adm.admit(_req(0, deadline_s=0.2), queue_depth=3, queue_cost=50.0)
    assert e.value.reason == "shed_deadline"
    adm.admit(_req(1, deadline_s=1.0), queue_depth=3, queue_cost=50.0)
    # no deadline -> never shed, regardless of backlog
    adm.admit(_req(2), queue_depth=3, queue_cost=1e9)
    assert adm.shed == 1 and adm.admitted == 2


def test_deadline_shedding_disabled_until_rate_known():
    adm = AdmissionController(AdmissionPolicy(), clock=FakeClock())
    adm.admit(_req(0, deadline_s=0.01), queue_depth=9, queue_cost=1e6)
    adm.note_service_rate(100.0)
    with pytest.raises(AdmissionRejected):
        adm.admit(_req(1, deadline_s=0.01), queue_depth=9, queue_cost=1e6)


def test_service_rate_feedback_is_ewma():
    adm = AdmissionController(
        AdmissionPolicy(initial_tok_per_s=100.0, rate_ewma=0.5),
        clock=FakeClock(),
    )
    adm.note_service_rate(200.0)
    assert adm.predict_ttft(150.0) == pytest.approx(1.0)  # rate now 150


# -------------------------------------------------- re-homing continuation


def test_build_continuation_prompt_budget_and_seed():
    req = _req(rid=7, plen=4, max_new=10, tenant="a", deadline_s=0.5)
    req.params = SamplingParams(max_new=10, temperature=0.8, seed=99)
    req.generated = [3, 1, 4]
    cont, committed = build_continuation(req)
    assert committed == 3
    np.testing.assert_array_equal(
        cont.prompt, np.array([0, 0, 0, 0, 3, 1, 4], np.int32)
    )
    assert cont.params.max_new == 7
    assert cont.params.seed == 99  # same stream, same fold_in(seed, pos) keys
    assert cont.params.temperature == 0.8
    assert cont.rid == 7 and cont.tenant == "a"


def test_build_continuation_pins_engine_assigned_seed():
    req = _req(rid=1, plen=4, max_new=10)
    req.generated = [5]
    req._bound = True
    req._seed = 1234  # the dead engine had already bound a seed
    cont, committed = build_continuation(req)
    assert committed == 1 and cont.params.seed == 1234
