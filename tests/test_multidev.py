"""Multi-device behaviour via subprocess (XLA forced host devices).

Covers: SPLIT/MERGE on a real 2-pod fabric, reshard-on-mode-switch, ring
collectives vs oracles, q8 all-reduce, elastic pod-failure shrink, a
small-mesh multi-pod dry-run of REDUCED configs for every arch family, and
the split/merge SERVING cluster (bit-identity vs the single-device engine,
mid-stream reconfigure, router fairness) under 2 and 4 forced host devices.
Grouped into few subprocess scripts to amortize interpreter startup.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    return out.stdout


@pytest.mark.slow
def test_cluster_modes_collectives_elastic():
    out = run_py(
        r"""
import repro  # noqa: F401  (installs jax 0.4.x compat shims first)
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import SpatzformerCluster, Mode, switch_mode, reshard
from repro.dist.collectives import ring_rs_matmul, ring_ag_matmul
from repro.dist.compression import ring_allreduce_q8

# ---- cluster views
cl = SpatzformerCluster(n_pods=2)
assert cl.n_devices == 8
mi = cl.merge_info(); si = cl.split_infos()
assert mi.data_size == 4 and mi.model_size == 2
assert len(si) == 2 and si[0].n_devices == 4

# ---- reshard on mode switch preserves values
x = jnp.arange(64.0).reshape(8, 8)
state = jax.device_put({"w": x}, si[0].named(P("data", None)))
merged, rep = switch_mode(cl, Mode.MERGE, state)
np.testing.assert_array_equal(np.asarray(merged["w"]), np.asarray(x))
assert rep.bytes_moved == 64 * 4

# ---- elastic shrink
surv = cl.surviving_cluster(dead_pod=0)
assert surv.n_devices == 4
shrunk = reshard(merged, surv.pod_info(0))
np.testing.assert_array_equal(np.asarray(shrunk["w"]), np.asarray(x))

# ---- ring collectives on 4-way axis
mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("model",))
rng = np.random.default_rng(0)
a = rng.standard_normal((16, 32)).astype(np.float32)
w = rng.standard_normal((32, 24)).astype(np.float32)
f = jax.jit(jax.shard_map(lambda xl, wl: ring_rs_matmul(xl, wl, "model"),
    mesh=mesh, in_specs=(P(None, "model"), P("model", None)), out_specs=P("model", None)))
np.testing.assert_allclose(np.asarray(f(a, w)), a @ w, rtol=2e-4, atol=2e-4)
g = jax.jit(jax.shard_map(lambda xl, wl: ring_ag_matmul(xl, wl, "model"),
    mesh=mesh, in_specs=(P("model", None), P(None, "model")), out_specs=P(None, "model")))
np.testing.assert_allclose(np.asarray(g(a, w)), a @ w, rtol=2e-4, atol=2e-4)
vals = rng.standard_normal((4, 64)).astype(np.float32)
h = jax.jit(jax.shard_map(lambda v: ring_allreduce_q8(v[0], "model")[None],
    mesh=mesh, in_specs=(P("model", None),), out_specs=P("model", None)))
err = np.abs(np.asarray(h(vals)) - vals.mean(0)[None]).max()
assert err < 0.05 * np.abs(vals.mean(0)).max() + 1e-3
print("MULTIDEV-CORE-OK")
"""
    )
    assert "MULTIDEV-CORE-OK" in out


@pytest.mark.slow
def test_small_mesh_multipod_dryrun_reduced_archs():
    """Reduced config per family × (2,2,2) multi-pod mesh: lower+compile the
    train step — the structural multi-pod check at test scale."""
    out = run_py(
        r"""
import repro  # noqa: F401  (installs jax 0.4.x compat shims first)
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, AxisType
from repro.configs import get_arch, TrainConfig
from repro.dist.sharding import MeshInfo, batch_shardings, param_shardings, replicated
from repro.models import LM
from repro.models.model import input_specs
from repro.train import adamw_init, make_train_step

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,)*3)
info = MeshInfo(mesh, batch_axes=("pod", "data"))
for name in ["codeqwen1.5-7b", "deepseek-v2-lite-16b", "falcon-mamba-7b", "zamba2-2.7b", "musicgen-large"]:
    cfg = get_arch(name).reduced()
    model = LM(cfg, mesh_info=info)
    params_s = model.param_specs()
    p_sh = param_shardings(params_s, info)
    batch_s = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
               "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    if cfg.modality == "audio":
        batch_s = {"embeds": jax.ShapeDtypeStruct((8, 32, cfg.d_model), jnp.float32),
                   "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    b_sh = batch_shardings(batch_s, info)
    step = make_train_step(model, TrainConfig())
    opt_s = jax.eval_shape(lambda: adamw_init(params_s))
    o_sh = param_shardings(opt_s, info)
    m_sh = {k: replicated(info) for k in ("loss", "aux", "grad_norm", "lr")}
    with mesh:
        compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                           out_shardings=(p_sh, o_sh, m_sh)).lower(params_s, opt_s, batch_s).compile()
    assert compiled.cost_analysis() is not None
    print("OK", name)
print("MULTIDEV-DRYRUN-OK")
"""
    )
    assert "MULTIDEV-DRYRUN-OK" in out


@pytest.mark.slow
def test_serve_cluster_split_merge_2dev():
    """2 forced host devices: the serving cluster's split mode (2 pinned
    replicas + router) and merge mode (one 2-way tensor-parallel engine,
    heads sharded) both serve the same greedy mixed stream BIT-IDENTICAL to
    a plain single-device engine — including the ragged chunked-prefill
    tier and a mid-stream reconfigure (drain → re-home → resume)."""
    out = run_py(
        r"""
import repro  # noqa: F401
import numpy as np, jax
from repro.configs import get_arch
from repro.core.modes import Mode
from repro.models import LM
from repro.serve import Request, SamplingParams, ServeCluster, ServeEngine

assert jax.device_count() == 2
cfg = get_arch("codeqwen1.5-7b").reduced()
m = LM(cfg)
p = m.init(jax.random.key(0))

def stream(seed=11):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    params=SamplingParams(max_new=6))
            for i, s in enumerate((5, 23, 11, 31, 8, 17, 26, 3))]

eng = ServeEngine(m, p, batch_slots=3, max_len=64)
for r in stream(): eng.submit(r)
eng.run()
ref = {r.rid: r.generated for r in eng.finished}

cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=3, max_len=64)
assert cl.n_replicas == 2
for r in stream(): cl.submit(r)
cl.run()
assert {r.rid: r.generated for r in cl.finished} == ref, "split != single"

rep = cl.reconfigure(Mode.MERGE)
assert not rep.cached and rep.bytes_moved > 0
assert cl.engines[0].backend.mesh_info.model_size == 2
cl.finished.clear()
for r in stream(): cl.submit(r)
cl.run()
assert {r.rid: r.generated for r in cl.finished} == ref, "merge != single"

# chunked ragged tier under TP: tight budget forces packed prefills
cl2 = ServeCluster(m, p, mode=Mode.MERGE, batch_slots=3, max_len=64,
                   prefill_budget=5)
for r in stream(): cl2.submit(r)
cl2.run()
assert {r.rid: r.generated for r in cl2.finished} == ref, "merge chunked != single"

# mid-stream reconfigure: drain at t, re-home, resume
cl.finished.clear()
arrivals = [(i * 0.002, r) for i, r in enumerate(stream())]
st = cl.run(arrivals=arrivals, reconfigure_schedule=[(0.006, Mode.SPLIT)])
assert {r.rid: r.generated for r in cl.finished} == ref, "mid-stream != single"
assert len(st.reconfigures) == 1 and st.reconfigures[0].cached
assert st.mode == "merge->split"
print("CLUSTER-2DEV-OK")
""",
        devices=2,
    )
    assert "CLUSTER-2DEV-OK" in out


@pytest.mark.slow
def test_serve_cluster_router_fairness_4dev():
    """4 forced host devices: JSQ spreads uniform tenant-less traffic
    evenly over 4 replicas; tenant affinity keeps each tenant on one
    replica while distinct tenants spread; outputs stay bit-identical to
    the single-device engine; 4-way TP merge serves the same stream."""
    out = run_py(
        r"""
import repro  # noqa: F401
import numpy as np, jax
from repro.configs import get_arch
from repro.core.modes import Mode
from repro.models import LM
from repro.serve import Request, SamplingParams, ServeCluster, ServeEngine

assert jax.device_count() == 4
cfg = get_arch("codeqwen1.5-7b").reduced()
m = LM(cfg)
p = m.init(jax.random.key(0))

def stream(tenants=None, n=12, seed=31):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                    params=SamplingParams(max_new=4),
                    tenant=None if tenants is None else tenants[i % len(tenants)])
            for i in range(n)]

eng = ServeEngine(m, p, batch_slots=2, max_len=32)
for r in stream(): eng.submit(r)
eng.run()
ref = {r.rid: r.generated for r in eng.finished}

# fairness: 12 uniform requests over 4 replicas -> 3 each
cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
assert cl.n_replicas == 4
for r in stream(): cl.submit(r)
cl.run()
assert cl.router.assigned == [3, 3, 3, 3], cl.router.assigned
assert {r.rid: r.generated for r in cl.finished} == ref, "split != single"

# tenant affinity: each tenant pinned to one replica, tenants spread
cl2 = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
tenants = ["a", "b", "c", "d"]
routed = {}
for r in stream(tenants=tenants):
    routed.setdefault(r.tenant, set()).add(cl2.submit(r).replica)
cl2.run()
assert all(len(v) == 1 for v in routed.values()), routed
assert len(set(next(iter(v)) for v in routed.values())) == 4, routed
assert {r.rid: r.generated for r in cl2.finished} == ref, "tenants != single"

# 4-way TP merge on the same stream
rep = cl.reconfigure(Mode.MERGE)
assert cl.engines[0].backend.mesh_info.model_size == 4
cl.finished.clear()
for r in stream(): cl.submit(r)
cl.run()
assert {r.rid: r.generated for r in cl.finished} == ref, "merge != single"
print("CLUSTER-4DEV-OK")
""",
        devices=4,
    )
    assert "CLUSTER-4DEV-OK" in out
