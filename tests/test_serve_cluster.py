"""ServeCluster: router logic, placement backends, and split/merge/
reconfigure correctness on whatever devices exist.

Single-device runs (the fast CI lane) exercise the full cluster machinery
through degenerate fabrics (split = 1 replica, merge = model_size 1); the
dedicated 2-device CI lane (XLA_FLAGS=--xla_force_host_platform_device_count=2)
and the subprocess tests in test_multidev.py cover real multi-device
split/merge tensor parallelism.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.modes import Mode
from repro.models import LM
from repro.serve import Request, Router, ServeCluster, ServeEngine
from repro.serve.backend import DeviceBackend


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def _reqs(cfg, sizes, *, max_new=4, tenants=None, seed=21):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
            max_new=max_new,
            tenant=None if tenants is None else tenants[i % len(tenants)],
        )
        for i, s in enumerate(sizes)
    ]


def _engine_reference(m, p, reqs, **kw):
    eng = ServeEngine(m, p, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.rid: r.generated for r in eng.finished}


# ---------------------------------------------------------------- router


def _route_all(router, reqs):
    return [router.route(r) for r in reqs]


def test_router_jsq_balances_uniform_load():
    r = Router(4)
    reqs = [Request(rid=i, prompt=np.zeros(8, np.int32), max_new=4) for i in range(16)]
    _route_all(r, reqs)
    assert r.assigned == [4, 4, 4, 4]
    assert max(r.load) - min(r.load) == 0


def test_router_jsq_prefers_shortest_queue():
    r = Router(2)
    big = Request(rid=0, prompt=np.zeros(100, np.int32), max_new=50)
    small = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new=4) for i in (1, 2, 3)]
    assert r.route(big) == 0
    # the big request's cost keeps replica 0's queue longest: all the small
    # ones land on replica 1 until its cumulative cost catches up
    assert _route_all(r, small) == [1, 1, 1]


def test_router_tenant_affinity_sticks():
    r = Router(3)
    reqs = _route_all(
        r,
        [
            Request(rid=i, prompt=np.zeros(8, np.int32), max_new=4, tenant=t)
            for i, t in enumerate(["a", "b", "a", "c", "a", "b"])
        ],
    )
    homes = {"a": reqs[0], "b": reqs[1], "c": reqs[3]}
    assert reqs == [homes["a"], homes["b"], homes["a"], homes["c"], homes["a"], homes["b"]]
    assert len({homes["a"], homes["b"], homes["c"]}) == 3  # spread, not piled


# ------------------------------------------------------------- backends


def test_device_backend_bit_identical(small_model):
    """An engine pinned to an explicit device serves the same stream with
    the same tokens as the default placement."""
    cfg, m, p = small_model
    sizes = (5, 11, 8)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    eng = ServeEngine(
        m, p, batch_slots=2, max_len=32, backend=DeviceBackend(jax.devices()[-1])
    )
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    eng.run()
    assert {r.rid: r.generated for r in eng.finished} == ref


def test_engine_reset_reusable(small_model):
    """reset() returns an idle engine to a fresh-serving state: the same
    stream replays to identical outputs with no recompiles."""
    cfg, m, p = small_model
    sizes = (6, 13, 9)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    eng.run()
    first = {r.rid: r.generated for r in eng.finished}
    eng.reset()
    assert eng.finished == []
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    stats = eng.run()
    assert {r.rid: r.generated for r in eng.finished} == first
    assert stats.prefill_compiles == 0


# ------------------------------------------------------- cluster modes


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_matches_single_engine(small_model, mode):
    """Both cluster modes serve bit-identical greedy streams to a plain
    engine, on however many devices this process has."""
    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=48)
    for r in _reqs(cfg, sizes):
        cl.submit(r)
    stats = cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert stats.total_requests == len(sizes)
    assert stats.total_tokens > 0 and stats.wall_seconds > 0


def test_cluster_reconfigure_carries_waiting(small_model):
    """Requests still queued at reconfigure() survive the switch (TTFT
    clock intact) and serve correctly on the new fabric."""
    cfg, m, p = small_model
    sizes = (5, 9, 13, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
    reqs = _reqs(cfg, sizes)
    for r in reqs:
        cl.submit(r)
    t_before = [r.submitted_at for r in reqs]
    rep = cl.reconfigure(Mode.MERGE)
    assert cl.mode is Mode.MERGE
    assert rep.place_seconds >= 0 and not rep.cached
    assert [r.submitted_at for r in reqs] == t_before
    cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    # warm switch back: fabric cached, nothing re-placed
    rep2 = cl.reconfigure(Mode.SPLIT)
    assert rep2.cached and rep2.bytes_moved == 0
    assert len(cl.reconfigures) == 2


def test_cluster_mid_stream_reconfigure(small_model):
    """run(reconfigure_schedule=...) drains at the switch point, re-homes,
    resumes — outputs stay bit-identical to an uninterrupted engine."""
    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48)
    arrivals = [(i * 0.002, r) for i, r in enumerate(_reqs(cfg, sizes))]
    stats = cl.run(arrivals=arrivals, reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1
    assert stats.mode == "split->merge"
    assert stats.total_requests == len(sizes)
    assert stats.wall_seconds >= stats.reconfigures[0].seconds


def test_cluster_multi_device_split_uses_every_replica(small_model):
    """With >1 device, split mode spreads tenant-less uniform requests
    across every replica (JSQ fairness at the fabric level)."""
    cfg, m, p = small_model
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (the 2-device CI cluster lane)")
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
    n = 3 * cl.n_replicas
    for r in _reqs(cfg, (8,) * n):
        cl.submit(r)
    cl.run()
    assert cl.router.assigned == [3] * cl.n_replicas
    assert len(cl.finished) == n
