"""ServeCluster: router logic, placement backends, and split/merge/
reconfigure correctness on whatever devices exist.

Single-device runs (the fast CI lane) exercise the full cluster machinery
through degenerate fabrics (split = 1 replica, merge = model_size 1); the
dedicated 2-device CI lane (XLA_FLAGS=--xla_force_host_platform_device_count=2)
and the subprocess tests in test_multidev.py cover real multi-device
split/merge tensor parallelism.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.modes import Mode
from repro.models import LM
from repro.serve import Request, Router, SamplingParams, ServeCluster, ServeEngine
from repro.serve.backend import DeviceBackend


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def _reqs(cfg, sizes, *, max_new=4, tenants=None, seed=21):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
            params=SamplingParams(max_new=max_new),
            tenant=None if tenants is None else tenants[i % len(tenants)],
        )
        for i, s in enumerate(sizes)
    ]


def _engine_reference(m, p, reqs, **kw):
    eng = ServeEngine(m, p, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.rid: r.generated for r in eng.finished}


# ---------------------------------------------------------------- router


def _route_all(router, reqs):
    return [router.route(r) for r in reqs]


def test_router_jsq_balances_uniform_load():
    r = Router(4)
    reqs = [Request(rid=i, prompt=np.zeros(8, np.int32),
                    params=SamplingParams(max_new=4)) for i in range(16)]
    _route_all(r, reqs)
    assert r.assigned == [4, 4, 4, 4]
    assert max(r.load) - min(r.load) == 0


def test_router_jsq_prefers_shortest_queue():
    r = Router(2)
    big = Request(rid=0, prompt=np.zeros(100, np.int32), params=SamplingParams(max_new=50))
    small = [Request(rid=i, prompt=np.zeros(4, np.int32),
                     params=SamplingParams(max_new=4)) for i in (1, 2, 3)]
    assert r.route(big) == 0
    # the big request's cost keeps replica 0's queue longest: all the small
    # ones land on replica 1 until its cumulative cost catches up
    assert _route_all(r, small) == [1, 1, 1]


def test_router_tenant_affinity_sticks():
    r = Router(3)
    reqs = _route_all(
        r,
        [
            Request(rid=i, prompt=np.zeros(8, np.int32),
                    params=SamplingParams(max_new=4), tenant=t)
            for i, t in enumerate(["a", "b", "a", "c", "a", "b"])
        ],
    )
    homes = {"a": reqs[0], "b": reqs[1], "c": reqs[3]}
    assert reqs == [homes["a"], homes["b"], homes["a"], homes["c"], homes["a"], homes["b"]]
    assert len({homes["a"], homes["b"], homes["c"]}) == 3  # spread, not piled


# ------------------------------------------------------------- backends


def test_device_backend_bit_identical(small_model):
    """An engine pinned to an explicit device serves the same stream with
    the same tokens as the default placement."""
    cfg, m, p = small_model
    sizes = (5, 11, 8)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    eng = ServeEngine(
        m, p, batch_slots=2, max_len=32, backend=DeviceBackend(jax.devices()[-1])
    )
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    eng.run()
    assert {r.rid: r.generated for r in eng.finished} == ref


def test_engine_reset_reusable(small_model):
    """reset() returns an idle engine to a fresh-serving state: the same
    stream replays to identical outputs with no recompiles."""
    cfg, m, p = small_model
    sizes = (6, 13, 9)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    eng.run()
    first = {r.rid: r.generated for r in eng.finished}
    eng.reset()
    assert eng.finished == []
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    stats = eng.run()
    assert {r.rid: r.generated for r in eng.finished} == first
    assert stats.prefill_compiles == 0


# ------------------------------------------------------- cluster modes


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_matches_single_engine(small_model, mode):
    """Both cluster modes serve bit-identical greedy streams to a plain
    engine, on however many devices this process has."""
    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=48)
    for r in _reqs(cfg, sizes):
        cl.submit(r)
    stats = cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert stats.total_requests == len(sizes)
    assert stats.total_tokens > 0 and stats.wall_seconds > 0


def test_cluster_reconfigure_carries_waiting(small_model):
    """Requests still queued at reconfigure() survive the switch (TTFT
    clock intact) and serve correctly on the new fabric."""
    cfg, m, p = small_model
    sizes = (5, 9, 13, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
    reqs = _reqs(cfg, sizes)
    for r in reqs:
        cl.submit(r)
    t_before = [r.submitted_at for r in reqs]
    rep = cl.reconfigure(Mode.MERGE)
    assert cl.mode is Mode.MERGE
    assert rep.place_seconds >= 0 and not rep.cached
    assert [r.submitted_at for r in reqs] == t_before
    cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    # warm switch back: fabric cached, nothing re-placed
    rep2 = cl.reconfigure(Mode.SPLIT)
    assert rep2.cached and rep2.bytes_moved == 0
    assert len(cl.reconfigures) == 2


def test_cluster_mid_stream_reconfigure(small_model):
    """run(reconfigure_schedule=...) drains at the switch point, re-homes,
    resumes — outputs stay bit-identical to an uninterrupted engine."""
    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48)
    arrivals = [(i * 0.002, r) for i, r in enumerate(_reqs(cfg, sizes))]
    stats = cl.run(arrivals=arrivals, reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1
    assert stats.mode == "split->merge"
    assert stats.total_requests == len(sizes)
    assert stats.wall_seconds >= stats.reconfigures[0].seconds


def test_cluster_multi_device_split_uses_every_replica(small_model):
    """With >1 device, split mode spreads tenant-less uniform requests
    across every replica (JSQ fairness at the fabric level)."""
    cfg, m, p = small_model
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (the 2-device CI cluster lane)")
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
    n = 3 * cl.n_replicas
    for r in _reqs(cfg, (8,) * n):
        cl.submit(r)
    cl.run()
    assert cl.router.assigned == [3] * cl.n_replicas
    assert len(cl.finished) == n


# ------------------------------------------- request API across the cluster


def _sampled_reqs(cfg, sizes, *, max_new=5, seed=51):
    """Seeded mixed sampling stream: reproducibility across fabrics needs
    explicit per-request seeds (engine-assigned seeds differ per replica)."""
    rng = np.random.default_rng(seed)
    kinds = [
        SamplingParams(max_new=max_new),
        SamplingParams(max_new=max_new, temperature=0.9, top_p=0.85, seed=11),
        SamplingParams(max_new=max_new, temperature=1.1, top_k=6, seed=22),
        SamplingParams(max_new=max_new, temperature=1.0, top_k=9, top_p=0.9, seed=33),
    ]
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
            params=kinds[i % len(kinds)],
        )
        for i, s in enumerate(sizes)
    ]


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_seeded_sampling_matches_single_engine(small_model, mode):
    """Seeded top-k/top-p streams are bit-reproducible across cluster
    modes: the (request seed, position) sampling keys don't care which
    fabric — or which replica — serves the request."""
    cfg, m, p = small_model
    sizes = (5, 12, 8, 17, 9)
    ref = _engine_reference(m, p, _sampled_reqs(cfg, sizes),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=48)
    for r in _sampled_reqs(cfg, sizes):
        cl.submit(r)
    cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref


def test_cluster_mid_stream_reconfigure_seeded_sampling(small_model):
    """A drain→switch→resume mid-stream reconfigure must not perturb any
    seeded sampled stream (requests re-homed across fabrics keep their
    params and seeds)."""
    cfg, m, p = small_model
    sizes = (5, 12, 8, 17, 9, 7)
    ref = _engine_reference(m, p, _sampled_reqs(cfg, sizes),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48)
    arrivals = [(i * 0.002, r) for i, r in enumerate(_sampled_reqs(cfg, sizes))]
    stats = cl.run(arrivals=arrivals, reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1


def test_cluster_tenant_default_params(small_model):
    """A request submitted without sampling config inherits its tenant's
    default SamplingParams; explicit params always win; the defaults
    survive a reconfigure (params resolve once, at first submit)."""
    cfg, m, p = small_model
    rng = np.random.default_rng(61)
    defaults = {
        "free": SamplingParams(max_new=2),
        "pro": SamplingParams(max_new=4, temperature=0.9, top_p=0.9, seed=5),
    }
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32,
                      tenant_defaults=defaults)
    mk = lambda rid, tenant, **kw: Request(
        rid=rid,
        prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
        tenant=tenant, **kw,
    )
    r_free, r_pro = mk(0, "free"), mk(1, "pro")
    r_explicit = mk(2, "free", params=SamplingParams(max_new=3))
    r_other = mk(3, "unknown")
    for r in (r_free, r_pro, r_explicit, r_other):
        cl.submit(r)
    assert r_free.params == defaults["free"]
    assert r_pro.params == defaults["pro"]
    assert r_explicit.params.max_new == 3  # explicit config wins
    assert r_other.params.max_new == 16  # no default for this tenant
    cl.reconfigure(Mode.MERGE)  # carried requests keep their resolved params
    assert r_free.params == defaults["free"]
    cl.run()
    by = {r.rid: r for r in cl.finished}
    assert len(by[0].generated) == 2
    assert len(by[1].generated) == 4
    assert len(by[2].generated) == 3


def test_cluster_cancel_follows_reconfigure(small_model):
    """A handle's cancel() reaches the request wherever it lives — here,
    after a reconfigure re-homed the queue onto the other fabric."""
    cfg, m, p = small_model
    sizes = (5, 9, 7)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
    reqs = _reqs(cfg, sizes)
    handles = [cl.submit(r) for r in reqs]
    cl.reconfigure(Mode.MERGE)
    handles[1].cancel()
    assert handles[1].finish_reason == "cancelled"
    cl.run()
    served = {r.rid: r.generated for r in cl.finished if r.finish_reason != "cancelled"}
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    assert served == {0: ref[0], 2: ref[2]}
    assert reqs[1].generated == []


def test_cluster_mid_stream_cancel_preserves_other_streams(small_model):
    """Cancelling one request WHILE the cluster serves (controller threads
    live) frees its slot and leaves every other seeded stream bit-identical
    — per-request sampling keys make abort invisible to neighbours."""
    import threading

    cfg, m, p = small_model
    sizes = (5, 12, 8, 17)
    ref = _engine_reference(m, p, _sampled_reqs(cfg, sizes, max_new=16),
                            batch_slots=2, max_len=64)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=64)
    reqs = _sampled_reqs(cfg, sizes, max_new=16)
    handles = [cl.submit(r) for r in reqs]
    timer = threading.Timer(0.02, handles[2].cancel)
    timer.start()
    try:
        cl.run()
    finally:
        timer.cancel()
    by = {r.rid: r for r in cl.finished}
    for rid in (0, 1, 3):
        assert by[rid].generated == ref[rid], f"neighbour stream {rid} perturbed"
    # the cancelled stream is a clean prefix (or finished before the timer)
    cut = by[2].generated
    assert cut == ref[2][: len(cut)]
    if by[2].finish_reason == "cancelled":
        assert by[2].n_generated == len(cut)


def test_cluster_tenant_defaults_apply_to_arrival_streams(small_model):
    """run(arrivals=...) takes the same request intake as submit(): tenant
    default params attach and the ownership map learns the engine (so a
    mid-stream arrival is cancellable and honours tenant policy)."""
    cfg, m, p = small_model
    rng = np.random.default_rng(71)
    defaults = {"pro": SamplingParams(max_new=3)}
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32,
                      tenant_defaults=defaults)
    req = Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
        tenant="pro",
    )
    cl.run(arrivals=[(0.0, req)])
    assert req.params == defaults["pro"]
    assert len(req.generated) == 3


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_handle_streaming_without_run(small_model, mode):
    """Pure handle-driven streaming (no cluster.run()): the iterator pumps
    the owning engine to COMPLETION — including the final chunk, whose
    values are still in flight when the request count-finishes — and the
    ownership map is pruned afterwards (no unbounded growth)."""
    cfg, m, p = small_model
    sizes = (6, 9)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=32)
    handles = [cl.submit(r) for r in _reqs(cfg, sizes)]
    assert list(handles[0].tokens()) == ref[0]
    assert handles[1].result() == ref[1]
    assert all(h.done for h in handles)
    assert len(cl._where) == 0  # streamed-to-completion requests pruned


# ------------------------------------------------------------- speculation


def _patterned_reqs(cfg, *, n=5, max_new=6, seed=61):
    """Repetitive + random prompts, greedy + seeded-sampled slots: the mix
    a drafter partially predicts."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if i % 2 == 0:
            prompt = np.tile(rng.integers(0, cfg.vocab_size, size=3), 5)
        else:
            prompt = rng.integers(0, cfg.vocab_size, size=11)
        reqs.append(Request(
            rid=i, prompt=prompt.astype(np.int32),
            params=SamplingParams(
                max_new=max_new, temperature=0.8 if i % 2 else 0.0,
                top_p=0.9 if i % 2 else 1.0, seed=80 + i,
            ),
        ))
    return reqs


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_speculate_matches_plain_single_engine(small_model, mode):
    """A speculative cluster (either fabric) must be bit-identical to one
    plain NON-speculative engine: acceptance is exact-match against the
    same fold_in(seed, position) draws on every replica."""
    cfg, m, p = small_model
    ref = _engine_reference(m, p, _patterned_reqs(cfg),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=48,
                      speculate="ngram")
    for r in _patterned_reqs(cfg):
        cl.submit(r)
    stats = cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert stats.spec_ticks > 0
    assert stats.spec_accepted <= stats.spec_proposed


def test_cluster_mid_stream_reconfigure_speculate(small_model):
    """SPLIT->MERGE mid-stream with speculation on: re-homed requests keep
    their committed prefixes and their seeds; the drafter state is rebuilt
    per engine at admission, so the switch cannot perturb any stream."""
    cfg, m, p = small_model
    ref = _engine_reference(m, p, _patterned_reqs(cfg, n=6),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48,
                      speculate="ngram")
    arrivals = [
        (i * 0.002, r) for i, r in enumerate(_patterned_reqs(cfg, n=6))
    ]
    stats = cl.run(arrivals=arrivals,
                   reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1
    assert stats.spec_ticks > 0


# ------------------------------- supervision: control loop, admission, failure


def _seeded_reqs(cfg, n=4, *, max_new=24, seed=61):
    """Explicit per-request seeds + temperature: bit-reproducible across
    fabrics AND across a mid-stream re-homing (fold_in(seed, position))."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=6 + 3 * i).astype(np.int32),
            params=SamplingParams(
                max_new=max_new, temperature=0.9, top_p=0.85, seed=500 + i
            ),
            tenant="ab"[i % 2],
        )
        for i in range(n)
    ]


def test_engine_deadline_slice_resumes_bit_identical(small_model):
    """run(deadline_s=...) is a clean pause point: queued work stays
    queued, nothing is dropped, and resuming drains to the same tokens
    as one uninterrupted run — the invariant run_controlled's control
    intervals are built on."""
    cfg, m, p = small_model
    sizes = (5, 9, 13, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    reqs = _reqs(cfg, sizes)
    for r in reqs:
        eng.submit(r)
    eng.run(deadline_s=0.0)  # expires before admitting anything new
    assert len(eng.waiting) + sum(r.finish_reason is not None for r in reqs) > 0
    eng.run()
    assert {r.rid: r.generated for r in eng.finished} == ref


def test_cluster_run_controlled_matches_reference(small_model):
    """The closed control loop (interval slicing + observation) must be
    invisible to the served streams: bit-identical to one plain engine,
    and on one device the perfmodel never finds a switch worth paying for."""
    from repro.serve import ReconfigController

    cfg, m, p = small_model
    sizes = (5, 12, 8, 17, 9)
    ref = _engine_reference(m, p, _sampled_reqs(cfg, sizes),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48,
                      devices=[jax.devices()[0]])
    ctl = ReconfigController.for_cluster(cl, interval_s=0.05)
    arrivals = [(i * 0.002, r) for i, r in enumerate(_sampled_reqs(cfg, sizes))]
    stats = cl.run_controlled(arrivals, controller=ctl)
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert stats.total_requests == len(sizes)
    assert ctl.switch_times == []  # 1 device: merge never wins
    assert len(ctl.samples) > 0


def test_cluster_run_controlled_scripted_switch(small_model):
    """A scripted decider drives the control loop's switch machinery: the
    fabric reconfigures mid-stream, the controller hears note_switched,
    and every stream stays bit-identical."""
    from repro.serve import SwitchDecision

    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=48)

    class Scripted:
        interval_s = 0.03
        observed = 0
        switched = []

        def observe(self, sample, *, warm_target=False):
            self.observed += 1
            if self.observed == 2:
                return SwitchDecision(
                    mode=Mode.MERGE, predicted_win_s=1.0, switch_cost_s=0.0
                )
            return None

        def note_switched(self, t, report=None):
            self.switched.append((t, report))

    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48)
    ctl = Scripted()
    arrivals = [(i * 0.02, r) for i, r in enumerate(_reqs(cfg, sizes))]
    stats = cl.run_controlled(arrivals, controller=ctl)
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert cl.mode is Mode.MERGE
    assert len(ctl.switched) == 1 and len(stats.reconfigures) == 1
    assert "merge" in stats.mode


def test_cluster_admission_sheds_under_burst(small_model):
    """An arrival burst far beyond capacity: deadline-based shedding
    rejects up front (typed, with done_at set), admitted requests finish
    normally, and the cluster counters account for every request."""
    from repro.serve import AdmissionPolicy, ReconfigController

    cfg, m, p = small_model
    cl = ServeCluster(
        m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48,
        devices=[jax.devices()[0]],
        admission=AdmissionPolicy(max_queue=4, initial_tok_per_s=50.0),
    )
    cl.prewarm()
    reqs = _reqs(cfg, (8,) * 12, max_new=8)
    for r in reqs:
        r.deadline_s = 0.05
    ctl = ReconfigController.for_cluster(cl, interval_s=0.05)
    stats = cl.run_controlled(
        [(i * 0.001, r) for i, r in enumerate(reqs)], controller=ctl
    )
    shed = [r for r in reqs if r.finish_reason == "rejected"]
    served = [r for r in reqs if r.finish_reason == "length"]
    assert len(shed) > 0 and len(served) > 0
    assert len(shed) + len(served) == len(reqs)
    assert all(r.reject_reason == "shed_deadline" for r in shed)
    assert all(r.done_at >= r.submitted_at > 0 for r in shed)
    assert all(len(r.generated) == 8 for r in served)
    assert stats.shed == len(shed) and stats.rejected == 0
    assert stats.queue_peak >= 1


def test_cluster_submit_queue_full_typed(small_model):
    """Submit-time backpressure: the bounded queue rejects with the typed
    AdmissionRejected (still a ValueError for legacy callers)."""
    from repro.serve import AdmissionPolicy, AdmissionRejected

    cfg, m, p = small_model
    cl = ServeCluster(
        m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32,
        devices=[jax.devices()[0]],
        admission=AdmissionPolicy(max_queue=2),
    )
    reqs = _reqs(cfg, (6,) * 5)
    admitted = 0
    with pytest.raises(AdmissionRejected) as e:
        for r in reqs:
            cl.submit(r)
            admitted += 1
    assert e.value.reason == "queue_full"
    assert isinstance(e.value, ValueError)
    assert admitted == 2
    cl.run()
    assert len(cl.finished) == admitted


def test_cluster_replica_death_rehomes_bit_identical(small_model):
    """Kill one of two split replicas mid-decode (injected controller-
    thread stall -> straggler -> dead): its live requests re-home onto the
    survivor and every seeded stream completes bit-identical to an
    unkilled single-engine run — fold_in(seed, position) keying makes the
    continuation's draws independent of which engine draws them."""
    import threading
    import time as _time

    from repro.serve import FailurePolicy

    cfg, m, p = small_model
    reqs = _seeded_reqs(cfg)
    ref = _engine_reference(m, p, _seeded_reqs(cfg), batch_slots=2, max_len=64)

    ticks: dict[int, int] = {}
    lock = threading.Lock()

    def stall(idx: int) -> None:
        with lock:
            ticks[idx] = ticks.get(idx, 0) + 1
            n = ticks[idx]
        if idx == 1 and n == 3:
            _time.sleep(1.0)  # hung controller thread: heartbeats stop

    d0 = jax.devices()[0]
    cl = ServeCluster(
        m, p, mode=Mode.SPLIT, batch_slots=2, max_len=64,
        devices=[d0, d0],  # 2 replicas on 1 device: the 1-device CI lane
        failure=FailurePolicy(
            straggler_after=0.08, dead_after=0.25, poll=0.02, tick_hook=stall
        ),
    )
    # heartbeats fire at iteration boundaries: compiles must be off the
    # serving path or a replica mid-compile reads as dead (see FailurePolicy)
    cl.prewarm(sampling=True)
    for r in reqs:
        cl.submit(r)
    stats = cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert all(r.finish_reason == "length" for r in reqs)
    assert stats.dead_replicas == 1
    assert stats.rehomed >= 1  # replica 1's live requests moved over
    assert stats.stragglers >= 1  # straggler fired on the way to dead
    # the dead replica stays retired: later submissions avoid it
    late = _seeded_reqs(cfg, n=2, seed=77)
    for r in late:
        r.rid += 100
        cl.submit(r)
    cl.run()
    assert all(r.finish_reason == "length" for r in late)


# =============================== heterogeneous (multi-model) clusters ======
# models={name: (model, params)} pins one model per split replica; the
# router dispatches per-request/per-tenant by model name, and failure
# recovery refuses to re-home a request onto a survivor running a
# DIFFERENT model (that would silently answer from the wrong
# distribution). Merge mode is structurally impossible: one fused engine
# cannot hold two parameterizations.


@pytest.fixture(scope="module")
def hetero_models():
    cfg_a = get_arch("minicpm3-4b").reduced()  # dense + MLA
    cfg_b = get_arch("falcon-mamba-7b").reduced()  # pure SSM
    m_a, m_b = LM(cfg_a), LM(cfg_b)
    p_a = m_a.init(jax.random.key(6))
    p_b = m_b.init(jax.random.key(7))
    return (cfg_a, m_a, p_a), (cfg_b, m_b, p_b)


def _hetero_cluster(hetero_models, **kw):
    (cfg_a, m_a, p_a), (cfg_b, m_b, p_b) = hetero_models
    d0 = jax.devices()[0]
    kw.setdefault("devices", [d0, d0])  # 2 replicas on 1 device (CI lane)
    return ServeCluster(
        models={"mla": (m_a, p_a), "ssm": (m_b, p_b)},
        batch_slots=2, max_len=48, **kw,
    )


def test_hetero_router_model_dispatch():
    """Router-level model pinning: JSQ within the compatible replica set,
    tenant affinity honoured only when model-compatible, and an empty
    compatible set raises the typed NoModelReplica."""
    from repro.serve import NoModelReplica

    r = Router(3, replica_model=["a", "a", "b"])

    def req(rid, model=None, tenant=None):
        return Request(rid=rid, prompt=np.zeros(4, np.int32), model=model,
                       tenant=tenant, params=SamplingParams(max_new=4))

    assert r.route(req(0, "b")) == 2
    assert r.route(req(1, "a")) in (0, 1)
    assert r.route(req(2, "a")) in (0, 1)
    assert {r.route(req(3, "a")), r.route(req(4, "a"))} <= {0, 1}
    # tenant homed on an "a" replica: a "b" request from the same tenant
    # must not follow the home, and the home survives for "a" traffic
    home = r.route(req(5, "a", tenant="t1"))
    assert r.route(req(6, "b", tenant="t1")) == 2
    assert r.route(req(7, "a", tenant="t1")) == home
    # all replicas of a model retired -> typed rejection
    r.retire(2)
    with pytest.raises(NoModelReplica) as e:
        r.route(req(8, "b"))
    assert e.value.reason == "infeasible" and e.value.model == "b"


def test_plan_hetero_placement_cost_weighted():
    """Every model gets >= 1 replica; spare devices go to the costlier
    model (MLA streams KV rows per token, SSM state is cheap); too few
    devices is a ValueError."""
    from repro.serve import model_token_cost, plan_hetero_placement

    cfg_a = get_arch("minicpm3-4b").reduced()
    cfg_b = get_arch("falcon-mamba-7b").reduced()
    assert model_token_cost(cfg_a) > model_token_cost(cfg_b)
    plan = plan_hetero_placement({"mla": cfg_a, "ssm": cfg_b}, 5)
    assert plan["mla"] >= plan["ssm"] >= 1
    assert sum(plan.values()) == 5
    assert plan_hetero_placement({"mla": cfg_a, "ssm": cfg_b}, 2) == {
        "mla": 1, "ssm": 1,
    }
    with pytest.raises(ValueError, match="at least"):
        plan_hetero_placement({"mla": cfg_a, "ssm": cfg_b}, 1)


def test_hetero_cluster_routes_by_tenant_and_model(hetero_models):
    """Per-tenant model pinning end to end: each request serves on its
    model's replica, bit-identical to a single-engine run of that model;
    unpinned requests default to the primary (first) model."""
    (cfg_a, m_a, p_a), (cfg_b, m_b, p_b) = hetero_models
    cl = _hetero_cluster(hetero_models,
                         tenant_models={"alice": "mla", "bob": "ssm"})
    assert cl.replica_plan() == {"mla": [0], "ssm": [1]}
    rng = np.random.default_rng(41)
    prompts = [rng.integers(0, 200, size=s).astype(np.int32)
               for s in (5, 9, 7, 11)]
    cl.submit(Request(rid=0, prompt=prompts[0], tenant="alice",
                      params=SamplingParams(max_new=5)))
    cl.submit(Request(rid=1, prompt=prompts[1], tenant="bob",
                      params=SamplingParams(max_new=5)))
    cl.submit(Request(rid=2, prompt=prompts[2], model="ssm",
                      params=SamplingParams(max_new=5)))
    cl.submit(Request(rid=3, prompt=prompts[3],
                      params=SamplingParams(max_new=5)))
    cl.run()
    got = {r.rid: (r.model, r.generated) for r in cl.finished}
    refs = {
        0: (m_a, p_a), 1: (m_b, p_b), 2: (m_b, p_b), 3: (m_a, p_a),
    }
    assert got[3][0] == "mla"  # unpinned -> primary model
    for rid, (m, p) in refs.items():
        solo = _engine_reference(
            m, p,
            [Request(rid=rid, prompt=prompts[rid],
                     params=SamplingParams(max_new=5))],
            batch_slots=2, max_len=48,
        )
        assert got[rid][1] == solo[rid], rid


def test_hetero_unknown_model_typed_rejection(hetero_models):
    """A model name outside the placement is a typed NoModelReplica (an
    AdmissionRejected, reason 'infeasible') at submit time — and merge
    mode is refused at init and at reconfigure."""
    from repro.serve import AdmissionRejected, NoModelReplica

    cl = _hetero_cluster(hetero_models)
    with pytest.raises(NoModelReplica) as e:
        cl.submit(Request(rid=0, prompt=np.zeros(4, np.int32), model="nope",
                          params=SamplingParams(max_new=3)))
    assert isinstance(e.value, AdmissionRejected)
    assert e.value.reason == "infeasible" and e.value.model == "nope"
    with pytest.raises(ValueError, match="split-only"):
        cl.reconfigure(Mode.MERGE)
    (cfg_a, m_a, p_a), (cfg_b, m_b, p_b) = hetero_models
    with pytest.raises(ValueError, match="split-only"):
        ServeCluster(models={"a": (m_a, p_a), "b": (m_b, p_b)},
                     mode=Mode.MERGE, batch_slots=2, max_len=48,
                     devices=[jax.devices()[0]] * 2)


def test_hetero_replica_death_refuses_cross_model_rehoming(hetero_models):
    """Kill the only replica of one model: its requests close out with a
    typed rejection instead of continuing on the other model's survivor,
    while the surviving model keeps serving bit-identically — and later
    submissions for the dead model are refused at the gate."""
    from repro.serve import NoModelReplica

    (cfg_a, m_a, p_a), (cfg_b, m_b, p_b) = hetero_models
    cl = _hetero_cluster(hetero_models)
    rng = np.random.default_rng(43)
    pr_ssm = rng.integers(0, 200, size=7).astype(np.int32)
    pr_mla = rng.integers(0, 200, size=9).astype(np.int32)
    doomed = Request(rid=0, prompt=pr_ssm, model="ssm",
                     params=SamplingParams(max_new=5))
    alive = Request(rid=1, prompt=pr_mla, model="mla",
                    params=SamplingParams(max_new=5))
    cl.submit(doomed)
    cl.submit(alive)
    cl._rehome_dead(cl.replica_plan()["ssm"][0])  # waiting, not yet served
    cl.run()
    assert doomed.finish_reason == "rejected"
    assert doomed.reject_reason == "infeasible"
    solo = _engine_reference(
        m_a, p_a,
        [Request(rid=1, prompt=pr_mla, params=SamplingParams(max_new=5))],
        batch_slots=2, max_len=48,
    )
    assert alive.finish_reason == "length" and alive.generated == solo[1]
    with pytest.raises(NoModelReplica):
        cl.submit(Request(rid=2, prompt=pr_ssm, model="ssm",
                          params=SamplingParams(max_new=3)))
    # arrival-stream requests for the dead model reject instead of crash
    late_ssm = Request(rid=3, prompt=pr_ssm, model="ssm",
                       params=SamplingParams(max_new=3))
    late_mla = Request(rid=4, prompt=pr_mla, model="mla",
                       params=SamplingParams(max_new=3))
    cl.run(arrivals=[(0.0, late_ssm), (0.0, late_mla)])
    assert late_ssm.finish_reason == "rejected"
    assert late_ssm.reject_reason == "infeasible"
    assert late_mla.finish_reason == "length"
    assert late_mla.generated == solo[1][:3]


def test_hetero_run_controlled_never_merges(hetero_models):
    """A decider demanding MERGE is overruled: pinned models keep the
    fabric split, streams complete, and no reconfigure is recorded."""
    from repro.serve import SwitchDecision

    (cfg_a, m_a, p_a), (cfg_b, m_b, p_b) = hetero_models

    class MergeHappy:
        interval_s = 0.03
        switched = []

        def observe(self, sample, *, warm_target=False):
            return SwitchDecision(
                mode=Mode.MERGE, predicted_win_s=1.0, switch_cost_s=0.0
            )

        def note_switched(self, t, report=None):
            self.switched.append(t)

    cl = _hetero_cluster(hetero_models)
    rng = np.random.default_rng(47)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, 200, size=6).astype(np.int32),
                model=name, params=SamplingParams(max_new=4))
        for i, name in enumerate(("mla", "ssm", "mla", "ssm"))
    ]
    ctl = MergeHappy()
    stats = cl.run_controlled(
        [(i * 0.01, r) for i, r in enumerate(reqs)], controller=ctl
    )
    assert cl.mode is Mode.SPLIT
    assert ctl.switched == [] and stats.reconfigures == []
    assert all(r.finish_reason == "length" for r in reqs)


def test_hetero_cluster_two_devices_real_split(hetero_models):
    """The 2-device CI lane: a real heterogeneous split (one model per
    physical device) routes per-model and matches single-engine refs."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (the 2-device CI cluster lane)")
    (cfg_a, m_a, p_a), (cfg_b, m_b, p_b) = hetero_models
    cl = ServeCluster(
        models={"mla": (m_a, p_a), "ssm": (m_b, p_b)},
        batch_slots=2, max_len=48, devices=jax.devices()[:2],
    )
    rng = np.random.default_rng(53)
    prompts = [rng.integers(0, 200, size=s).astype(np.int32)
               for s in (6, 8, 10, 5)]
    reqs = [
        Request(rid=i, prompt=prompts[i], model=("mla", "ssm")[i % 2],
                params=SamplingParams(max_new=5))
        for i in range(4)
    ]
    for r in reqs:
        cl.submit(r)
    cl.run()
    assert cl.router.assigned[0] == 2 and cl.router.assigned[1] == 2
    for i, r in enumerate(reqs):
        m, p = (m_a, p_a) if r.model == "mla" else (m_b, p_b)
        solo = _engine_reference(
            m, p,
            [Request(rid=r.rid, prompt=prompts[i],
                     params=SamplingParams(max_new=5))],
            batch_slots=2, max_len=48,
        )
        assert r.generated == solo[r.rid]
