"""ServeCluster: router logic, placement backends, and split/merge/
reconfigure correctness on whatever devices exist.

Single-device runs (the fast CI lane) exercise the full cluster machinery
through degenerate fabrics (split = 1 replica, merge = model_size 1); the
dedicated 2-device CI lane (XLA_FLAGS=--xla_force_host_platform_device_count=2)
and the subprocess tests in test_multidev.py cover real multi-device
split/merge tensor parallelism.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.modes import Mode
from repro.models import LM
from repro.serve import Request, Router, SamplingParams, ServeCluster, ServeEngine
from repro.serve.backend import DeviceBackend


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def _reqs(cfg, sizes, *, max_new=4, tenants=None, seed=21):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
            params=SamplingParams(max_new=max_new),
            tenant=None if tenants is None else tenants[i % len(tenants)],
        )
        for i, s in enumerate(sizes)
    ]


def _engine_reference(m, p, reqs, **kw):
    eng = ServeEngine(m, p, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.rid: r.generated for r in eng.finished}


# ---------------------------------------------------------------- router


def _route_all(router, reqs):
    return [router.route(r) for r in reqs]


def test_router_jsq_balances_uniform_load():
    r = Router(4)
    reqs = [Request(rid=i, prompt=np.zeros(8, np.int32),
                    params=SamplingParams(max_new=4)) for i in range(16)]
    _route_all(r, reqs)
    assert r.assigned == [4, 4, 4, 4]
    assert max(r.load) - min(r.load) == 0


def test_router_jsq_prefers_shortest_queue():
    r = Router(2)
    big = Request(rid=0, prompt=np.zeros(100, np.int32), params=SamplingParams(max_new=50))
    small = [Request(rid=i, prompt=np.zeros(4, np.int32),
                     params=SamplingParams(max_new=4)) for i in (1, 2, 3)]
    assert r.route(big) == 0
    # the big request's cost keeps replica 0's queue longest: all the small
    # ones land on replica 1 until its cumulative cost catches up
    assert _route_all(r, small) == [1, 1, 1]


def test_router_tenant_affinity_sticks():
    r = Router(3)
    reqs = _route_all(
        r,
        [
            Request(rid=i, prompt=np.zeros(8, np.int32),
                    params=SamplingParams(max_new=4), tenant=t)
            for i, t in enumerate(["a", "b", "a", "c", "a", "b"])
        ],
    )
    homes = {"a": reqs[0], "b": reqs[1], "c": reqs[3]}
    assert reqs == [homes["a"], homes["b"], homes["a"], homes["c"], homes["a"], homes["b"]]
    assert len({homes["a"], homes["b"], homes["c"]}) == 3  # spread, not piled


# ------------------------------------------------------------- backends


def test_device_backend_bit_identical(small_model):
    """An engine pinned to an explicit device serves the same stream with
    the same tokens as the default placement."""
    cfg, m, p = small_model
    sizes = (5, 11, 8)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    eng = ServeEngine(
        m, p, batch_slots=2, max_len=32, backend=DeviceBackend(jax.devices()[-1])
    )
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    eng.run()
    assert {r.rid: r.generated for r in eng.finished} == ref


def test_engine_reset_reusable(small_model):
    """reset() returns an idle engine to a fresh-serving state: the same
    stream replays to identical outputs with no recompiles."""
    cfg, m, p = small_model
    sizes = (6, 13, 9)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    eng.run()
    first = {r.rid: r.generated for r in eng.finished}
    eng.reset()
    assert eng.finished == []
    for r in _reqs(cfg, sizes):
        eng.submit(r)
    stats = eng.run()
    assert {r.rid: r.generated for r in eng.finished} == first
    assert stats.prefill_compiles == 0


# ------------------------------------------------------- cluster modes


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_matches_single_engine(small_model, mode):
    """Both cluster modes serve bit-identical greedy streams to a plain
    engine, on however many devices this process has."""
    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=48)
    for r in _reqs(cfg, sizes):
        cl.submit(r)
    stats = cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert stats.total_requests == len(sizes)
    assert stats.total_tokens > 0 and stats.wall_seconds > 0


def test_cluster_reconfigure_carries_waiting(small_model):
    """Requests still queued at reconfigure() survive the switch (TTFT
    clock intact) and serve correctly on the new fabric."""
    cfg, m, p = small_model
    sizes = (5, 9, 13, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
    reqs = _reqs(cfg, sizes)
    for r in reqs:
        cl.submit(r)
    t_before = [r.submitted_at for r in reqs]
    rep = cl.reconfigure(Mode.MERGE)
    assert cl.mode is Mode.MERGE
    assert rep.place_seconds >= 0 and not rep.cached
    assert [r.submitted_at for r in reqs] == t_before
    cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    # warm switch back: fabric cached, nothing re-placed
    rep2 = cl.reconfigure(Mode.SPLIT)
    assert rep2.cached and rep2.bytes_moved == 0
    assert len(cl.reconfigures) == 2


def test_cluster_mid_stream_reconfigure(small_model):
    """run(reconfigure_schedule=...) drains at the switch point, re-homes,
    resumes — outputs stay bit-identical to an uninterrupted engine."""
    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48)
    arrivals = [(i * 0.002, r) for i, r in enumerate(_reqs(cfg, sizes))]
    stats = cl.run(arrivals=arrivals, reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1
    assert stats.mode == "split->merge"
    assert stats.total_requests == len(sizes)
    assert stats.wall_seconds >= stats.reconfigures[0].seconds


def test_cluster_multi_device_split_uses_every_replica(small_model):
    """With >1 device, split mode spreads tenant-less uniform requests
    across every replica (JSQ fairness at the fabric level)."""
    cfg, m, p = small_model
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (the 2-device CI cluster lane)")
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
    n = 3 * cl.n_replicas
    for r in _reqs(cfg, (8,) * n):
        cl.submit(r)
    cl.run()
    assert cl.router.assigned == [3] * cl.n_replicas
    assert len(cl.finished) == n


# ------------------------------------------- request API across the cluster


def _sampled_reqs(cfg, sizes, *, max_new=5, seed=51):
    """Seeded mixed sampling stream: reproducibility across fabrics needs
    explicit per-request seeds (engine-assigned seeds differ per replica)."""
    rng = np.random.default_rng(seed)
    kinds = [
        SamplingParams(max_new=max_new),
        SamplingParams(max_new=max_new, temperature=0.9, top_p=0.85, seed=11),
        SamplingParams(max_new=max_new, temperature=1.1, top_k=6, seed=22),
        SamplingParams(max_new=max_new, temperature=1.0, top_k=9, top_p=0.9, seed=33),
    ]
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
            params=kinds[i % len(kinds)],
        )
        for i, s in enumerate(sizes)
    ]


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_seeded_sampling_matches_single_engine(small_model, mode):
    """Seeded top-k/top-p streams are bit-reproducible across cluster
    modes: the (request seed, position) sampling keys don't care which
    fabric — or which replica — serves the request."""
    cfg, m, p = small_model
    sizes = (5, 12, 8, 17, 9)
    ref = _engine_reference(m, p, _sampled_reqs(cfg, sizes),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=48)
    for r in _sampled_reqs(cfg, sizes):
        cl.submit(r)
    cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref


def test_cluster_mid_stream_reconfigure_seeded_sampling(small_model):
    """A drain→switch→resume mid-stream reconfigure must not perturb any
    seeded sampled stream (requests re-homed across fabrics keep their
    params and seeds)."""
    cfg, m, p = small_model
    sizes = (5, 12, 8, 17, 9, 7)
    ref = _engine_reference(m, p, _sampled_reqs(cfg, sizes),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48)
    arrivals = [(i * 0.002, r) for i, r in enumerate(_sampled_reqs(cfg, sizes))]
    stats = cl.run(arrivals=arrivals, reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1


def test_cluster_tenant_default_params(small_model):
    """A request submitted without sampling config inherits its tenant's
    default SamplingParams; explicit params always win; the defaults
    survive a reconfigure (params resolve once, at first submit)."""
    cfg, m, p = small_model
    rng = np.random.default_rng(61)
    defaults = {
        "free": SamplingParams(max_new=2),
        "pro": SamplingParams(max_new=4, temperature=0.9, top_p=0.9, seed=5),
    }
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32,
                      tenant_defaults=defaults)
    mk = lambda rid, tenant, **kw: Request(
        rid=rid,
        prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
        tenant=tenant, **kw,
    )
    r_free, r_pro = mk(0, "free"), mk(1, "pro")
    r_explicit = mk(2, "free", params=SamplingParams(max_new=3))
    r_other = mk(3, "unknown")
    for r in (r_free, r_pro, r_explicit, r_other):
        cl.submit(r)
    assert r_free.params == defaults["free"]
    assert r_pro.params == defaults["pro"]
    assert r_explicit.params.max_new == 3  # explicit config wins
    assert r_other.params.max_new == 16  # no default for this tenant
    cl.reconfigure(Mode.MERGE)  # carried requests keep their resolved params
    assert r_free.params == defaults["free"]
    cl.run()
    by = {r.rid: r for r in cl.finished}
    assert len(by[0].generated) == 2
    assert len(by[1].generated) == 4
    assert len(by[2].generated) == 3


def test_cluster_cancel_follows_reconfigure(small_model):
    """A handle's cancel() reaches the request wherever it lives — here,
    after a reconfigure re-homed the queue onto the other fabric."""
    cfg, m, p = small_model
    sizes = (5, 9, 7)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32)
    reqs = _reqs(cfg, sizes)
    handles = [cl.submit(r) for r in reqs]
    cl.reconfigure(Mode.MERGE)
    handles[1].cancel()
    assert handles[1].finish_reason == "cancelled"
    cl.run()
    served = {r.rid: r.generated for r in cl.finished if r.finish_reason != "cancelled"}
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    assert served == {0: ref[0], 2: ref[2]}
    assert reqs[1].generated == []


def test_cluster_mid_stream_cancel_preserves_other_streams(small_model):
    """Cancelling one request WHILE the cluster serves (controller threads
    live) frees its slot and leaves every other seeded stream bit-identical
    — per-request sampling keys make abort invisible to neighbours."""
    import threading

    cfg, m, p = small_model
    sizes = (5, 12, 8, 17)
    ref = _engine_reference(m, p, _sampled_reqs(cfg, sizes, max_new=16),
                            batch_slots=2, max_len=64)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=64)
    reqs = _sampled_reqs(cfg, sizes, max_new=16)
    handles = [cl.submit(r) for r in reqs]
    timer = threading.Timer(0.02, handles[2].cancel)
    timer.start()
    try:
        cl.run()
    finally:
        timer.cancel()
    by = {r.rid: r for r in cl.finished}
    for rid in (0, 1, 3):
        assert by[rid].generated == ref[rid], f"neighbour stream {rid} perturbed"
    # the cancelled stream is a clean prefix (or finished before the timer)
    cut = by[2].generated
    assert cut == ref[2][: len(cut)]
    if by[2].finish_reason == "cancelled":
        assert by[2].n_generated == len(cut)


def test_cluster_tenant_defaults_apply_to_arrival_streams(small_model):
    """run(arrivals=...) takes the same request intake as submit(): tenant
    default params attach and the ownership map learns the engine (so a
    mid-stream arrival is cancellable and honours tenant policy)."""
    cfg, m, p = small_model
    rng = np.random.default_rng(71)
    defaults = {"pro": SamplingParams(max_new=3)}
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32,
                      tenant_defaults=defaults)
    req = Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
        tenant="pro",
    )
    cl.run(arrivals=[(0.0, req)])
    assert req.params == defaults["pro"]
    assert len(req.generated) == 3


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_handle_streaming_without_run(small_model, mode):
    """Pure handle-driven streaming (no cluster.run()): the iterator pumps
    the owning engine to COMPLETION — including the final chunk, whose
    values are still in flight when the request count-finishes — and the
    ownership map is pruned afterwards (no unbounded growth)."""
    cfg, m, p = small_model
    sizes = (6, 9)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=32)
    handles = [cl.submit(r) for r in _reqs(cfg, sizes)]
    assert list(handles[0].tokens()) == ref[0]
    assert handles[1].result() == ref[1]
    assert all(h.done for h in handles)
    assert len(cl._where) == 0  # streamed-to-completion requests pruned


# ------------------------------------------------------------- speculation


def _patterned_reqs(cfg, *, n=5, max_new=6, seed=61):
    """Repetitive + random prompts, greedy + seeded-sampled slots: the mix
    a drafter partially predicts."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if i % 2 == 0:
            prompt = np.tile(rng.integers(0, cfg.vocab_size, size=3), 5)
        else:
            prompt = rng.integers(0, cfg.vocab_size, size=11)
        reqs.append(Request(
            rid=i, prompt=prompt.astype(np.int32),
            params=SamplingParams(
                max_new=max_new, temperature=0.8 if i % 2 else 0.0,
                top_p=0.9 if i % 2 else 1.0, seed=80 + i,
            ),
        ))
    return reqs


@pytest.mark.parametrize("mode", [Mode.SPLIT, Mode.MERGE])
def test_cluster_speculate_matches_plain_single_engine(small_model, mode):
    """A speculative cluster (either fabric) must be bit-identical to one
    plain NON-speculative engine: acceptance is exact-match against the
    same fold_in(seed, position) draws on every replica."""
    cfg, m, p = small_model
    ref = _engine_reference(m, p, _patterned_reqs(cfg),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=mode, batch_slots=2, max_len=48,
                      speculate="ngram")
    for r in _patterned_reqs(cfg):
        cl.submit(r)
    stats = cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert stats.spec_ticks > 0
    assert stats.spec_accepted <= stats.spec_proposed


def test_cluster_mid_stream_reconfigure_speculate(small_model):
    """SPLIT->MERGE mid-stream with speculation on: re-homed requests keep
    their committed prefixes and their seeds; the drafter state is rebuilt
    per engine at admission, so the switch cannot perturb any stream."""
    cfg, m, p = small_model
    ref = _engine_reference(m, p, _patterned_reqs(cfg, n=6),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48,
                      speculate="ngram")
    arrivals = [
        (i * 0.002, r) for i, r in enumerate(_patterned_reqs(cfg, n=6))
    ]
    stats = cl.run(arrivals=arrivals,
                   reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1
    assert stats.spec_ticks > 0


# ------------------------------- supervision: control loop, admission, failure


def _seeded_reqs(cfg, n=4, *, max_new=24, seed=61):
    """Explicit per-request seeds + temperature: bit-reproducible across
    fabrics AND across a mid-stream re-homing (fold_in(seed, position))."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=6 + 3 * i).astype(np.int32),
            params=SamplingParams(
                max_new=max_new, temperature=0.9, top_p=0.85, seed=500 + i
            ),
            tenant="ab"[i % 2],
        )
        for i in range(n)
    ]


def test_engine_deadline_slice_resumes_bit_identical(small_model):
    """run(deadline_s=...) is a clean pause point: queued work stays
    queued, nothing is dropped, and resuming drains to the same tokens
    as one uninterrupted run — the invariant run_controlled's control
    intervals are built on."""
    cfg, m, p = small_model
    sizes = (5, 9, 13, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    reqs = _reqs(cfg, sizes)
    for r in reqs:
        eng.submit(r)
    eng.run(deadline_s=0.0)  # expires before admitting anything new
    assert len(eng.waiting) + sum(r.finish_reason is not None for r in reqs) > 0
    eng.run()
    assert {r.rid: r.generated for r in eng.finished} == ref


def test_cluster_run_controlled_matches_reference(small_model):
    """The closed control loop (interval slicing + observation) must be
    invisible to the served streams: bit-identical to one plain engine,
    and on one device the perfmodel never finds a switch worth paying for."""
    from repro.serve import ReconfigController

    cfg, m, p = small_model
    sizes = (5, 12, 8, 17, 9)
    ref = _engine_reference(m, p, _sampled_reqs(cfg, sizes),
                            batch_slots=2, max_len=48)
    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48,
                      devices=[jax.devices()[0]])
    ctl = ReconfigController.for_cluster(cl, interval_s=0.05)
    arrivals = [(i * 0.002, r) for i, r in enumerate(_sampled_reqs(cfg, sizes))]
    stats = cl.run_controlled(arrivals, controller=ctl)
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert stats.total_requests == len(sizes)
    assert ctl.switch_times == []  # 1 device: merge never wins
    assert len(ctl.samples) > 0


def test_cluster_run_controlled_scripted_switch(small_model):
    """A scripted decider drives the control loop's switch machinery: the
    fabric reconfigures mid-stream, the controller hears note_switched,
    and every stream stays bit-identical."""
    from repro.serve import SwitchDecision

    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17, 7)
    ref = _engine_reference(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=48)

    class Scripted:
        interval_s = 0.03
        observed = 0
        switched = []

        def observe(self, sample, *, warm_target=False):
            self.observed += 1
            if self.observed == 2:
                return SwitchDecision(
                    mode=Mode.MERGE, predicted_win_s=1.0, switch_cost_s=0.0
                )
            return None

        def note_switched(self, t, report=None):
            self.switched.append((t, report))

    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48)
    ctl = Scripted()
    arrivals = [(i * 0.02, r) for i, r in enumerate(_reqs(cfg, sizes))]
    stats = cl.run_controlled(arrivals, controller=ctl)
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert cl.mode is Mode.MERGE
    assert len(ctl.switched) == 1 and len(stats.reconfigures) == 1
    assert "merge" in stats.mode


def test_cluster_admission_sheds_under_burst(small_model):
    """An arrival burst far beyond capacity: deadline-based shedding
    rejects up front (typed, with done_at set), admitted requests finish
    normally, and the cluster counters account for every request."""
    from repro.serve import AdmissionPolicy, ReconfigController

    cfg, m, p = small_model
    cl = ServeCluster(
        m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48,
        devices=[jax.devices()[0]],
        admission=AdmissionPolicy(max_queue=4, initial_tok_per_s=50.0),
    )
    cl.prewarm()
    reqs = _reqs(cfg, (8,) * 12, max_new=8)
    for r in reqs:
        r.deadline_s = 0.05
    ctl = ReconfigController.for_cluster(cl, interval_s=0.05)
    stats = cl.run_controlled(
        [(i * 0.001, r) for i, r in enumerate(reqs)], controller=ctl
    )
    shed = [r for r in reqs if r.finish_reason == "rejected"]
    served = [r for r in reqs if r.finish_reason == "length"]
    assert len(shed) > 0 and len(served) > 0
    assert len(shed) + len(served) == len(reqs)
    assert all(r.reject_reason == "shed_deadline" for r in shed)
    assert all(r.done_at >= r.submitted_at > 0 for r in shed)
    assert all(len(r.generated) == 8 for r in served)
    assert stats.shed == len(shed) and stats.rejected == 0
    assert stats.queue_peak >= 1


def test_cluster_submit_queue_full_typed(small_model):
    """Submit-time backpressure: the bounded queue rejects with the typed
    AdmissionRejected (still a ValueError for legacy callers)."""
    from repro.serve import AdmissionPolicy, AdmissionRejected

    cfg, m, p = small_model
    cl = ServeCluster(
        m, p, mode=Mode.SPLIT, batch_slots=2, max_len=32,
        devices=[jax.devices()[0]],
        admission=AdmissionPolicy(max_queue=2),
    )
    reqs = _reqs(cfg, (6,) * 5)
    admitted = 0
    with pytest.raises(AdmissionRejected) as e:
        for r in reqs:
            cl.submit(r)
            admitted += 1
    assert e.value.reason == "queue_full"
    assert isinstance(e.value, ValueError)
    assert admitted == 2
    cl.run()
    assert len(cl.finished) == admitted


def test_cluster_replica_death_rehomes_bit_identical(small_model):
    """Kill one of two split replicas mid-decode (injected controller-
    thread stall -> straggler -> dead): its live requests re-home onto the
    survivor and every seeded stream completes bit-identical to an
    unkilled single-engine run — fold_in(seed, position) keying makes the
    continuation's draws independent of which engine draws them."""
    import threading
    import time as _time

    from repro.serve import FailurePolicy

    cfg, m, p = small_model
    reqs = _seeded_reqs(cfg)
    ref = _engine_reference(m, p, _seeded_reqs(cfg), batch_slots=2, max_len=64)

    ticks: dict[int, int] = {}
    lock = threading.Lock()

    def stall(idx: int) -> None:
        with lock:
            ticks[idx] = ticks.get(idx, 0) + 1
            n = ticks[idx]
        if idx == 1 and n == 3:
            _time.sleep(1.0)  # hung controller thread: heartbeats stop

    d0 = jax.devices()[0]
    cl = ServeCluster(
        m, p, mode=Mode.SPLIT, batch_slots=2, max_len=64,
        devices=[d0, d0],  # 2 replicas on 1 device: the 1-device CI lane
        failure=FailurePolicy(
            straggler_after=0.08, dead_after=0.25, poll=0.02, tick_hook=stall
        ),
    )
    # heartbeats fire at iteration boundaries: compiles must be off the
    # serving path or a replica mid-compile reads as dead (see FailurePolicy)
    cl.prewarm(sampling=True)
    for r in reqs:
        cl.submit(r)
    stats = cl.run()
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert all(r.finish_reason == "length" for r in reqs)
    assert stats.dead_replicas == 1
    assert stats.rehomed >= 1  # replica 1's live requests moved over
    assert stats.stragglers >= 1  # straggler fired on the way to dead
    # the dead replica stays retired: later submissions avoid it
    late = _seeded_reqs(cfg, n=2, seed=77)
    for r in late:
        r.rid += 100
        cl.submit(r)
    cl.run()
    assert all(r.finish_reason == "length" for r in late)
