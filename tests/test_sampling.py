"""Device-side fused sampler: SamplingParams validation, the smode dispatch
zoo, determinism of the (seed, position)-keyed draws, and empirical
distributions against a masked-renormalized-softmax oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import MAX_LOGIT_BIAS, SamplingParams, fused_sample
from repro.serve.sampling import SMODE_GREEDY, SMODE_GUMBEL, SMODE_MASKED

V = 32
N_DRAWS = 20_000  # >= 10k: binomial noise ~ sqrt(p/N) per bin


def _logits(seed=0, v=V):
    rng = np.random.default_rng(seed)
    return (2.0 * rng.standard_normal(v)).astype(np.float32)


def _draw(logits, p: SamplingParams, n=N_DRAWS, seed=123):
    """n independent draws from ONE request's sampler configuration: the
    per-draw key is fold_in(key(seed), pos), so distinct positions are the
    independent sample axis — exactly how a decoding stream draws."""
    b = np.broadcast_to(logits, (n, len(logits)))
    bt = np.full((n, MAX_LOGIT_BIAS), 2**30, np.int32)
    bv = np.zeros((n, MAX_LOGIT_BIAS), np.float32)
    for j, (t, val) in enumerate(p.logit_bias):
        bt[:, j] = t
        bv[:, j] = val
    toks = fused_sample(
        jnp.asarray(b),
        jnp.full(n, p.temperature, jnp.float32),
        jnp.full(n, p.top_k, jnp.int32),
        jnp.full(n, p.top_p, jnp.float32),
        jnp.full(n, seed, jnp.int32),
        jnp.arange(n, dtype=jnp.int32),
        jnp.asarray(bt), jnp.asarray(bv),
        smode=p.smode,
    )
    return np.asarray(toks)


def oracle_probs(logits, p: SamplingParams) -> np.ndarray:
    """jnp-free reference: bias + temperature scaling + top-k/top-p masks
    (same tie semantics as the device mask: >= threshold keeps), then the
    renormalized softmax over the kept set."""
    z = np.asarray(logits, np.float64).copy()
    for t, val in p.logit_bias:
        z[t] += val
    if p.temperature <= 0:
        q = np.zeros_like(z)
        q[np.argmax(z)] = 1.0
        return q
    z = z / max(p.temperature, 1e-6)
    srt = np.sort(z)[::-1]
    keep = np.ones_like(z, bool)
    if p.top_k > 0:
        keep &= z >= srt[min(p.top_k, len(z)) - 1]
    ps = np.exp(srt - srt.max())
    ps /= ps.sum()
    cum_excl = np.cumsum(ps) - ps
    n_keep = max(int((cum_excl < p.top_p).sum()), 1)
    keep &= z >= srt[n_keep - 1]
    q = np.where(keep, np.exp(z - z.max()), 0.0)
    return q / q.sum()


def _tv(counts, probs):
    freq = counts / counts.sum()
    return 0.5 * np.abs(freq - probs).sum()


# ------------------------------------------------------------------ params


def test_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(max_new=0)
    with pytest.raises(ValueError):
        SamplingParams(logit_bias=tuple((i, 1.0) for i in range(MAX_LOGIT_BIAS + 1)))
    with pytest.raises(ValueError):
        SamplingParams(seed=2**31)  # must fit the device-resident int32 row
    assert SamplingParams(seed=2**31 - 1).seed == 2**31 - 1
    # mapping-style logit_bias normalizes to sorted-insertion tuple pairs
    p = SamplingParams(temperature=1.0, logit_bias={3: 2.0})
    assert p.logit_bias == ((3, 2.0),)
    assert SamplingParams(stop=[np.int32(7)]).stop == (7,)


def test_smode_classification():
    assert SamplingParams().smode == SMODE_GREEDY
    assert SamplingParams(temperature=0.7).smode == SMODE_GUMBEL
    assert SamplingParams(temperature=0.7, top_k=5).smode == SMODE_MASKED
    assert SamplingParams(temperature=0.7, top_p=0.9).smode == SMODE_MASKED
    # bias applies even to greedy decisions -> needs the masked variant
    assert SamplingParams(logit_bias=((1, 5.0),)).smode == SMODE_MASKED
    # params are frozen and hashable (a finite dispatch zoo can key on them)
    assert hash(SamplingParams(top_k=5, temperature=1.0)) == hash(
        SamplingParams(top_k=5, temperature=1.0)
    )


# ----------------------------------------------------------- exact behavior


def test_greedy_is_argmax():
    lg = _logits(1)
    toks = _draw(lg, SamplingParams(), n=8)
    assert (toks == np.argmax(lg)).all()


def test_top_k_one_is_argmax_at_any_temperature():
    lg = _logits(2)
    toks = _draw(lg, SamplingParams(temperature=2.5, top_k=1), n=64)
    assert (toks == np.argmax(lg)).all()


def test_logit_bias_forces_and_bans():
    lg = _logits(3)
    worst = int(np.argmin(lg))
    best = int(np.argmax(lg))
    forced = _draw(lg, SamplingParams(temperature=1.0, logit_bias=((worst, 1e9),)), n=64)
    assert (forced == worst).all()
    banned = _draw(
        lg, SamplingParams(temperature=1.0, top_k=1, logit_bias=((best, -1e9),)), n=64
    )
    assert (banned != best).all() and (banned == np.argsort(lg)[-2]).all()


def test_seeded_draws_deterministic_and_position_keyed():
    lg = _logits(4)
    p = SamplingParams(temperature=1.0, top_p=0.9, seed=5)
    a = _draw(lg, p, n=256, seed=5)
    b = _draw(lg, p, n=256, seed=5)
    assert (a == b).all()  # same (seed, pos) -> same draw, always
    c = _draw(lg, p, n=256, seed=6)
    assert (a != c).any()  # a different request seed is a different stream


def test_gumbel_and_masked_variants_agree_when_mask_is_off():
    """A wide (smode 2) dispatch with top_k=0, top_p=1 and no bias must
    draw exactly what the narrow gumbel variant draws — this is what lets
    a mixed batch run the widest variant any slot needs without perturbing
    the narrower slots."""
    lg = _logits(5)
    n = 512
    b = jnp.asarray(np.broadcast_to(lg, (n, V)))
    temps = jnp.full(n, 0.8, jnp.float32)
    ks = jnp.zeros(n, jnp.int32)
    ps = jnp.ones(n, jnp.float32)
    seeds = jnp.full(n, 9, jnp.int32)
    pos = jnp.arange(n, dtype=jnp.int32)
    bt = jnp.full((n, MAX_LOGIT_BIAS), 2**30, jnp.int32)
    bv = jnp.zeros((n, MAX_LOGIT_BIAS), jnp.float32)
    narrow = fused_sample(b, temps, ks, ps, seeds, pos, bt, bv, smode=SMODE_GUMBEL)
    wide = fused_sample(b, temps, ks, ps, seeds, pos, bt, bv, smode=SMODE_MASKED)
    assert (np.asarray(narrow) == np.asarray(wide)).all()


# ------------------------------------------------------- empirical vs oracle


@pytest.mark.parametrize(
    "p",
    [
        SamplingParams(temperature=0.8),
        SamplingParams(temperature=0.8, top_k=4),
        SamplingParams(temperature=1.2, top_p=0.7),
        SamplingParams(temperature=0.9, top_k=8, top_p=0.85),
        SamplingParams(temperature=1.0, top_p=0.8, logit_bias=((0, 3.0), (7, -2.0))),
    ],
    ids=["temp", "top_k", "top_p", "top_k+top_p", "top_p+bias"],
)
def test_empirical_distribution_matches_oracle(p):
    lg = _logits(7)
    toks = _draw(lg, p)
    probs = oracle_probs(lg, p)
    # every draw inside the kept set, none outside
    assert probs[toks].min() > 0
    counts = np.bincount(toks, minlength=V).astype(np.float64)
    assert _tv(counts, probs) < 0.02, (_tv(counts, probs), p)
