"""Autotuner: JSON cache round-trip, shape-bucket collisions, and parity of
tuned vs default block configs through the ops dispatch layer."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref
from repro.kernels.autotune import Autotuner, bucket_shape, cache_key


@pytest.fixture
def tuner_path(tmp_path):
    return str(tmp_path / "autotune.json")


@pytest.fixture
def installed_tuner(tuner_path):
    """A tmp-backed tuner installed as the process-global one."""
    t = Autotuner(tuner_path, sweep=False)
    autotune.set_tuner(t)
    yield t
    autotune.set_tuner(None)


def test_cache_round_trip_no_resweep(tuner_path):
    calls = []

    def measure(cfg):
        calls.append(cfg)
        return float(cfg["block"])  # smallest candidate wins

    t = Autotuner(tuner_path, sweep=True)
    cfg = t.get("axpy", (4, 1000), "float32", "interpret", measure=measure)
    assert cfg == {"block": 256}
    assert len(calls) == len(autotune.CANDIDATES["axpy"])
    assert t.sweeps_run == 1
    assert os.path.exists(tuner_path)

    # fresh tuner over the same file: hit from disk, measure NEVER called
    def boom(cfg):
        raise AssertionError("re-sweep on a cache hit")

    t2 = Autotuner(tuner_path, sweep=True)
    assert t2.get("axpy", (4, 1000), "float32", "interpret", measure=boom) == {
        "block": 256
    }
    assert t2.sweeps_run == 0
    assert len(json.load(open(tuner_path))) == 1


def test_shape_bucket_collision(tuner_path):
    t = Autotuner(tuner_path, sweep=False)
    win = {"block_m": 64, "block_n": 64, "block_k": 64}
    t.store("matmul", (100, 70, 130), "float32", "interpret", win)
    # (100, 70, 130) and (128, 128, 200) share the (128, 128, 256) bucket
    assert bucket_shape((100, 70, 130)) == bucket_shape((128, 128, 200))
    assert t.lookup("matmul", (128, 128, 200), "float32", "interpret") == win
    # a different bucket, dtype, or backend is a distinct cell
    assert t.lookup("matmul", (300, 70, 130), "float32", "interpret") is None
    assert t.lookup("matmul", (100, 70, 130), "bfloat16", "interpret") is None
    assert t.lookup("matmul", (100, 70, 130), "float32", "pallas") is None


def test_cache_key_is_versioned_and_stable():
    k1 = cache_key("matmul", (100, 70, 130), "float32", "interpret")
    assert k1 == cache_key("matmul", (128, 128, 256), np.float32, "interpret")
    assert k1.startswith(f"v{autotune._SCHEMA_VERSION}|matmul|")


def test_miss_without_sweep_returns_default_and_writes_nothing(tuner_path):
    t = Autotuner(tuner_path, sweep=False)
    cfg = t.get("matmul", (64, 64, 64), "float32", "interpret")
    assert cfg == autotune.DEFAULTS["matmul"]
    assert not os.path.exists(tuner_path)


def test_corrupt_cache_is_cold_not_fatal(tuner_path):
    with open(tuner_path, "w") as f:
        f.write("{not json")
    t = Autotuner(tuner_path, sweep=False)
    assert t.lookup("matmul", (64, 64, 64), "float32", "interpret") is None


def test_tuned_vs_default_parity_matmul(installed_tuner, monkeypatch, rng):
    """A tuned (non-default) block plan must be USED by ops.matmul and still
    match the oracle bit-for-tolerance."""
    tuned = {"block_m": 16, "block_n": 16, "block_k": 16}
    installed_tuner.store("matmul", (40, 24, 56), "float32", "interpret", tuned)

    seen = {}
    orig = ops._matmul_k.matmul

    def spy(a, b, **kw):
        seen.update(kw)
        return orig(a, b, **kw)

    monkeypatch.setattr(ops._matmul_k, "matmul", spy)
    a = jnp.asarray(rng.standard_normal((40, 24)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((24, 56)), jnp.float32)
    out_tuned = ops.matmul(a, b, mode="interpret")
    assert (seen["block_m"], seen["block_n"], seen["block_k"]) == (16, 16, 16)
    out_default = ops.matmul(a, b, mode="interpret", block=32)
    expect = ref.matmul(a, b)
    np.testing.assert_allclose(np.asarray(out_tuned), np.asarray(expect), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_default), np.asarray(expect), rtol=2e-4, atol=2e-4)


def test_tuned_vs_default_parity_flash(installed_tuner, monkeypatch, rng):
    b, h, s, hd = 2, 2, 48, 16
    tuned = {"block_q": 16, "block_k": 16}
    installed_tuner.store(
        "flash_attention", (b * h, s, hd), "float32", "interpret", tuned
    )

    seen = {}
    orig = ops._flash_k.flash_attention

    def spy(q, k, v, **kw):
        seen.update(kw)
        return orig(q, k, v, **kw)

    monkeypatch.setattr(ops._flash_k, "flash_attention", spy)
    q = jnp.asarray(rng.standard_normal((b, h, s, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, hd)), jnp.float32)
    out_tuned = ops.flash_attention(q, k, v, causal=True, mode="interpret")
    assert (seen["block_q"], seen["block_k"]) == (16, 16)
    out_default = ops.flash_attention(q, k, v, causal=True, mode="interpret", block=48)
    expect = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out_tuned), np.asarray(expect), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_default), np.asarray(expect), rtol=2e-4, atol=2e-4)


def test_sweep_picks_measured_winner_and_persists(tuner_path):
    t = Autotuner(tuner_path, sweep=True)
    # cost function prefers block_rows == 64
    cfg = t.get(
        "softmax", (200, 128), "float32", "interpret",
        measure=lambda c: abs(c["block_rows"] - 64),
    )
    assert cfg == {"block_rows": 64}
    t2 = Autotuner(tuner_path, sweep=False)
    assert t2.lookup("softmax", (200, 128), "float32", "interpret") == cfg


def test_env_var_cache_path(monkeypatch, tmp_path):
    p = str(tmp_path / "custom" / "cache.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", p)
    t = Autotuner()
    assert t.path == p
    t.store("dotp", (1, 4096), "float32", "interpret", {"block": 512})
    assert os.path.exists(p)
