"""Serving engine: greedy output equals manual full-forward argmax decoding;
continuous batching bookkeeping; the SamplingParams request lifecycle
(streaming handles, cancellation, stop tokens, seeded sampling invariance)."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import LM
from repro.serve import Request, SamplingParams, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def _manual_greedy(cfg, m, p, prompt, n_new):
    toks = list(int(t) for t in prompt)
    for _ in range(n_new):
        logits, _ = jax.jit(m.forward)(
            p, {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_manual_greedy(small_model):
    cfg, m, p = small_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    expect = _manual_greedy(cfg, m, p, prompt, 6)

    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, params=SamplingParams(max_new=6)))
    eng.run()
    assert eng.finished[0].generated == expect


def test_engine_batched_isolation(small_model):
    """Two different prompts decoded together must match their solo runs."""
    cfg, m, p = small_model
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    e1 = _manual_greedy(cfg, m, p, p1, 5)
    e2 = _manual_greedy(cfg, m, p, p2, 5)

    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    eng.submit(Request(rid=1, prompt=p1, params=SamplingParams(max_new=5)))
    eng.submit(Request(rid=2, prompt=p2, params=SamplingParams(max_new=5)))
    eng.run()
    got = {r.rid: r.generated for r in eng.finished}
    assert got[1] == e1
    assert got[2] == e2


def _run_engine(m, p, prompts, *, max_new=6, slots=2, max_len=32,
                temperatures=None, sampling=None, **kw):
    eng = ServeEngine(m, p, batch_slots=slots, max_len=max_len, **kw)
    for i, pr in enumerate(prompts):
        if sampling is not None:
            sp = sampling[i]
        else:
            sp = SamplingParams(
                max_new=max_new,
                temperature=0.0 if temperatures is None else temperatures[i],
            )
        eng.submit(Request(rid=i, prompt=pr, params=sp))
    stats = eng.run()
    return {r.rid: r.generated for r in eng.finished}, stats


def test_unified_bit_identical_to_legacy_greedy(small_model):
    """Unified ragged dispatch and the legacy prefill+insert engine must
    produce bit-identical greedy token streams on the same ragged stream,
    including mid-stream admissions (8 requests through 3 slots)."""
    cfg, m, p = small_model
    rng = np.random.default_rng(11)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (5, 23, 11, 31, 8, 17, 26, 3)
    ]
    legacy, _ = _run_engine(m, p, prompts, slots=3, max_len=64, unified=False)
    uni, _ = _run_engine(m, p, prompts, slots=3, max_len=64, unified=True)
    assert legacy == uni


def test_chunked_vs_unchunked_equivalence(small_model):
    """Output must not depend on either chunking knob under mid-stream
    admissions: prefill budget (packed chunk size) and decode chunk depth
    (k forced to 1) are pure scheduling choices."""
    cfg, m, p = small_model
    rng = np.random.default_rng(12)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (19, 7, 27, 13, 22)
    ]
    base, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                          unified=True, prefill_budget=64)  # one-shot prefill
    chunked, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                             unified=True, prefill_budget=5)
    assert base == chunked
    legacy, _ = _run_engine(m, p, prompts, slots=2, max_len=48, unified=False)
    legacy_k1, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                               unified=False, max_chunk=1)
    assert legacy == legacy_k1


@pytest.mark.parametrize("unified", [False, True])
def test_prompt_at_capacity_boundary(small_model, unified):
    """len(prompt) == max_len - 1: one decode write still fits, so the
    request yields exactly min(max_new, 2) tokens on both engines."""
    cfg, m, p = small_model
    rng = np.random.default_rng(13)
    max_len = 32
    prompt = rng.integers(0, cfg.vocab_size, size=max_len - 1).astype(np.int32)
    got, stats = _run_engine(m, p, [prompt], max_new=6, max_len=max_len,
                             unified=unified)
    assert len(got[0]) == 2
    assert stats.total_requests == 1


@pytest.mark.parametrize("unified", [False, True])
def test_max_new_one(small_model, unified):
    """max_new=1: exactly one token (the prefill sample), then finish —
    the slot is never occupied by a decode that can't run."""
    cfg, m, p = small_model
    rng = np.random.default_rng(14)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 9, 12)
    ]
    got, stats = _run_engine(m, p, prompts, max_new=1, unified=unified)
    assert all(len(v) == 1 for v in got.values())
    assert stats.total_requests == 3
    expect = {
        i: _manual_greedy(cfg, m, p, pr, 1) for i, pr in enumerate(prompts)
    }
    assert got == expect


def test_mixed_greedy_and_temperature_slots(small_model):
    """Greedy and temperature requests share packed ticks and decode chunks;
    the greedy streams must still match their solo runs exactly."""
    cfg, m, p = small_model
    rng = np.random.default_rng(15)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 10, 8, 12)
    ]
    temps = [0.0, 0.9, 0.0, 1.3]
    got, _ = _run_engine(m, p, prompts, max_new=5, slots=2, unified=True,
                         temperatures=temps)
    assert all(len(v) == 5 for v in got.values())
    for i in (0, 2):  # greedy slots: exact match vs solo manual decode
        assert got[i] == _manual_greedy(cfg, m, p, prompts[i], 5)
    for i in (1, 3):  # temperature slots: valid tokens
        assert all(0 <= t < cfg.vocab_size for t in got[i])


def test_stats_latency_tracking(small_model):
    """TTFT/TPOT per-request samples and percentile properties."""
    cfg, m, p = small_model
    rng = np.random.default_rng(16)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 14, 9)
    ]
    for unified in (False, True):
        _, stats = _run_engine(m, p, prompts, max_new=4, unified=unified)
        assert len(stats.ttfts) == 3
        assert len(stats.tpots) == 3
        assert stats.ttft_p99 >= stats.ttft_p50 > 0
        assert stats.tpot_p99 >= stats.tpot_p50 > 0


def test_arrival_schedule(small_model):
    """Open-loop arrivals: requests submitted once the run clock passes
    their offsets; everything drains and TTFT excludes pre-arrival time."""
    cfg, m, p = small_model
    rng = np.random.default_rng(17)
    arrivals = [
        (i * 0.003, Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=5 + 3 * i).astype(np.int32),
            params=SamplingParams(max_new=3),
        ))
        for i in range(4)
    ]
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    stats = eng.run(arrivals=arrivals)
    assert stats.total_requests == 4
    assert sorted(r.rid for r in eng.finished) == [0, 1, 2, 3]
    assert all(len(r.generated) == 3 for r in eng.finished)


def test_prewarm_covers_all_dispatch_variants(small_model):
    """After prewarm(), no compile may land inside the serving region —
    including the max_len-capped prompt bucket a non-pow2 max_len
    introduces (96 here) and sub-8 prompt buckets."""
    cfg, m, p = small_model
    rng = np.random.default_rng(18)
    eng = ServeEngine(m, p, batch_slots=2, max_len=96, unified=True,
                      prefill_budget=96)
    eng.prewarm()
    for i, s in enumerate((3, 70, 90)):  # buckets 4, 96, 96
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
            params=SamplingParams(max_new=3),
        ))
    stats = eng.run()
    assert stats.total_requests == 3
    assert stats.prefill_compiles == 0, stats.prefill_compiles


def test_continuous_batching_reuses_slots(small_model):
    cfg, m, p = small_model
    rng = np.random.default_rng(3)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    for i in range(5):
        eng.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
                params=SamplingParams(max_new=4),
            )
        )
    stats = eng.run()
    assert stats.total_requests == 5
    # first token of each request comes from prefill; engine ticks decode the rest
    assert stats.total_tokens == 5 * 3
    assert all(len(r.generated) == 4 for r in eng.finished)
    # with 2 slots and 5 requests, ticks must exceed one request's decode span
    assert stats.ticks >= 3 * 3 - 2
    assert all(r.done_at is not None for r in eng.finished)


# ===================================================================== the
# SamplingParams request lifecycle: seeded sampling invariance, stop tokens,
# streaming handles, cancellation, prewarmed sampler variants, shims
# =========================================================================


def _seeded_params(n, max_new=6):
    """A mixed seeded stream: greedy, temperature, top-k, top-p, combined."""
    kinds = [
        SamplingParams(max_new=max_new),
        SamplingParams(max_new=max_new, temperature=0.8, seed=101),
        SamplingParams(max_new=max_new, temperature=1.1, top_k=7, seed=202),
        SamplingParams(max_new=max_new, temperature=0.9, top_p=0.85, seed=303),
        SamplingParams(max_new=max_new, temperature=1.0, top_k=9, top_p=0.9, seed=404),
    ]
    return [kinds[i % len(kinds)] for i in range(n)]


def test_seeded_sampling_invariant_across_chunks_and_engines(small_model):
    """Seeded top-k/top-p streams are bit-reproducible across every decode
    chunk depth, across prefill budgets, and across the legacy/unified
    engines: every draw's PRNG key is (request seed, position), never a
    shared chain — the acceptance criterion of the SamplingParams redesign."""
    cfg, m, p = small_model
    rng = np.random.default_rng(31)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (5, 14, 9, 21, 7)
    ]
    sampling = _seeded_params(len(prompts))
    ref, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                         sampling=sampling, unified=True)
    assert all(len(v) == 6 for v in ref.values())
    for chunk in (1, 2, 4):
        got, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                             sampling=sampling, unified=True, max_chunk=chunk)
        assert got == ref, f"max_chunk={chunk} changed a seeded stream"
    budget, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                            sampling=sampling, unified=True, prefill_budget=6)
    assert budget == ref, "ragged chunked prefill changed a seeded stream"
    legacy, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                            sampling=sampling, unified=False)
    assert legacy == ref, "legacy host-path sampling diverged from device path"


def test_seeded_sampling_batch_composition_independent(small_model):
    """A seeded request's stream must not depend on its batch neighbours:
    solo run == batched run for every seeded request."""
    cfg, m, p = small_model
    rng = np.random.default_rng(32)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 11, 8)
    ]
    sampling = _seeded_params(5)[1:4]  # temperature, top-k, top-p (all seeded)
    batched, _ = _run_engine(m, p, prompts, slots=2, max_len=32,
                             sampling=sampling, unified=True)
    for i, (pr, sp) in enumerate(zip(prompts, sampling)):
        solo, _ = _run_engine(m, p, [pr], slots=2, max_len=32,
                              sampling=[sp], unified=True)
        assert solo[0] == batched[i]


@pytest.mark.parametrize("unified", [False, True])
def test_stop_token_mid_stream(small_model, unified):
    """A stop token terminates the stream AT the stop token: it is emitted,
    counted into n_generated, and nothing after it ever becomes visible —
    regardless of decode chunk depth (stop is found at harvest, the
    overrun chunk is discarded)."""
    cfg, m, p = small_model
    rng = np.random.default_rng(33)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    expect = _manual_greedy(cfg, m, p, prompt, 8)
    stop_tok = expect[3]  # stops the greedy stream at its 4th token
    for max_chunk in (1, 8):
        got, stats = _run_engine(
            m, p, [prompt], max_len=32, unified=unified, max_chunk=max_chunk,
            sampling=[SamplingParams(max_new=8, stop=(stop_tok,))],
        )
        assert got[0] == expect[:4]
        # throughput accounting refunds the discarded overrun chunk: only
        # the 3 EMITTED decode tokens count (the first token rides
        # admission and is never in total_tokens), whatever the chunk depth
        assert stats.total_tokens == 3, (max_chunk, stats.total_tokens)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32, unified=unified)
    h = eng.submit(Request(rid=0, prompt=prompt,
                           params=SamplingParams(max_new=8, stop=(stop_tok,))))
    eng.run()
    assert h.finish_reason == "stop"
    assert h.request.n_generated == 4 == len(h.request.generated)


@pytest.mark.parametrize("unified", [False, True])
def test_stop_token_off_by_one_regression(small_model, unified):
    """Pin the boundary bookkeeping: stop-on-first-token and max_new=1 both
    yield EXACTLY one emitted, counted token — the stop token counts into
    n_generated the same way a max_new boundary token does."""
    cfg, m, p = small_model
    rng = np.random.default_rng(34)
    prompt = rng.integers(0, cfg.vocab_size, size=7).astype(np.int32)
    first = _manual_greedy(cfg, m, p, prompt, 1)[0]

    def serve(params):
        eng = ServeEngine(m, p, batch_slots=2, max_len=32, unified=unified)
        h = eng.submit(Request(rid=0, prompt=prompt, params=params))
        stats = eng.run()
        return h, stats

    # stop on the very first token, max_new far away
    h, stats = serve(SamplingParams(max_new=8, stop=(first,)))
    assert h.request.generated == [first]
    assert h.request.n_generated == 1
    assert h.finish_reason == "stop"
    assert stats.total_requests == 1
    # max_new=1 AND stop on the same (first) token: still one token, and
    # the value-dependent reason wins the tie deterministically
    h, stats = serve(SamplingParams(max_new=1, stop=(first,)))
    assert h.request.generated == [first]
    assert h.request.n_generated == 1
    assert h.finish_reason == "stop"
    # max_new=1 with a never-matching stop: the length boundary
    h, _ = serve(SamplingParams(max_new=1, stop=(cfg.vocab_size + 1,)))
    assert h.request.generated == [first]
    assert h.request.n_generated == 1
    assert h.finish_reason == "length"


def test_streaming_handle_yields_full_stream(small_model):
    """submit() -> RequestHandle: iterating the handle drives the engine
    and yields exactly the tokens run() would produce, incrementally."""
    cfg, m, p = small_model
    rng = np.random.default_rng(35)
    p1 = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
    e1 = _manual_greedy(cfg, m, p, p1, 6)
    e2 = _manual_greedy(cfg, m, p, p2, 6)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    h1 = eng.submit(Request(rid=1, prompt=p1, params=SamplingParams(max_new=6)))
    h2 = eng.submit(Request(rid=2, prompt=p2, params=SamplingParams(max_new=6)))
    streamed = []
    for tok in h1:  # pumps engine.step() under the hood
        streamed.append(tok)
    assert streamed == e1
    assert h1.done and h1.finish_reason == "length"
    assert h2.result() == e2  # h2 decoded alongside h1; result() drains it


def test_cancel_frees_slot_without_perturbing_neighbours(small_model):
    """Mid-stream cancellation: the cancelled slot frees (and is reusable),
    while every other request's stream stays bit-identical to a run where
    the cancelled request finished normally — per-request sampling keys
    mean a neighbour's abort can never reshuffle anyone's draws."""
    cfg, m, p = small_model
    rng = np.random.default_rng(36)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 10, 8)
    ]
    sampling = [
        SamplingParams(max_new=12),
        SamplingParams(max_new=12, temperature=0.9, top_p=0.9, seed=77),
        SamplingParams(max_new=12, temperature=1.0, top_k=5, seed=88),
    ]
    ref, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                         sampling=sampling, unified=True)

    eng = ServeEngine(m, p, batch_slots=2, max_len=48, unified=True)
    handles = [
        eng.submit(Request(rid=i, prompt=pr, params=sp))
        for i, (pr, sp) in enumerate(zip(prompts, sampling))
    ]
    it = handles[0].tokens()
    first3 = [next(it) for _ in range(3)]
    handles[1].cancel()  # rid=1 is mid-decode in the other slot right now
    rest = list(it)
    assert first3 + rest == ref[0]
    assert handles[1].finish_reason == "cancelled"
    assert handles[1].done
    # the freed slot was reused: rid=2 still serves, stream unchanged
    assert handles[2].result() == ref[2]
    # the cancelled stream is a prefix of its uncancelled self
    cut = handles[1].request.generated
    assert cut == ref[1][: len(cut)]
    assert eng.stream_stats.cancelled == 1


def test_cancel_waiting_request_never_admits(small_model):
    cfg, m, p = small_model
    rng = np.random.default_rng(37)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    h = eng.submit(Request(rid=0, prompt=prompt, params=SamplingParams(max_new=4)))
    h.cancel()  # engine idle: applied immediately, straight from the queue
    assert h.done and h.finish_reason == "cancelled"
    assert h.request.generated == []
    stats = eng.run()  # nothing left to do
    assert stats.total_requests == 0 and stats.ticks == 0
    assert list(h.tokens()) == []


def test_prewarm_sampling_covers_every_sampler_variant(small_model):
    """After prewarm(sampling=True), a mixed greedy/temperature/top-k/top-p
    stream must hit ZERO fresh compiles in any dispatch program — the
    sampler variants are part of the compiled zoo, built off the hot path."""
    cfg, m, p = small_model
    rng = np.random.default_rng(38)
    eng = ServeEngine(m, p, batch_slots=2, max_len=64, unified=True,
                      prefill_budget=16)
    eng.prewarm(sampling=True)
    progs = (eng._tick, eng._packed, eng._admit_prog, eng._sample1)
    sizes = [pr._cache_size() for pr in progs]
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (5, 20, 9, 13)  # 20 > budget: ragged packed tier too
    ]
    for i, (pr, sp) in enumerate(zip(prompts, _seeded_params(len(prompts), 4))):
        eng.submit(Request(rid=i, prompt=pr, params=sp))
    stats = eng.run()
    assert stats.total_requests == 4
    assert stats.prefill_compiles == 0
    assert [pr._cache_size() for pr in progs] == sizes, "compile landed mid-serving"


def test_deprecated_kwargs_shim(small_model):
    """The pre-SamplingParams surface stays working: bare max_new= and
    temperature= kwargs warn DeprecationWarning and build the equivalent
    params; mixing them with params= is an error."""
    prompt = np.zeros(4, np.int32)
    with pytest.warns(DeprecationWarning):
        r = Request(rid=0, prompt=prompt, max_new=5, temperature=0.7)
    assert r.params == SamplingParams(max_new=5, temperature=0.7)
    assert r.max_new == 5 and r.temperature == 0.7  # mirrors stay readable
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the new surface must not warn
        r2 = Request(rid=1, prompt=prompt, params=SamplingParams(max_new=3))
    assert r2.max_new == 3 and r2.temperature == 0.0
    with pytest.raises(ValueError):
        Request(rid=2, prompt=prompt, max_new=5, params=SamplingParams())


def test_stream_then_run_stats_refund_lands_on_counting_stats(small_model):
    """A chunk dispatched under step()-driven streaming but harvested
    inside a later run() refunds its discarded post-stop values against
    the stats that COUNTED it (the entry carries its stats object) — the
    run's own counter must never go negative, and the combined counters
    equal exactly the emitted decode tokens."""
    cfg, m, p = small_model
    rng = np.random.default_rng(39)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    expect = _manual_greedy(cfg, m, p, prompt, 4)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    h = eng.submit(Request(rid=0, prompt=prompt,
                           params=SamplingParams(max_new=12, stop=(expect[2],))))
    it = h.tokens()
    assert next(it) == expect[0]  # dispatched+harvested under stream stats
    stats = eng.run()  # drains the in-flight overrun chunk under run stats
    assert h.request.generated == expect[:3]
    assert h.finish_reason == "stop"
    assert stats.total_tokens >= 0
    # 3 emitted tokens, first rode admission: 2 countable decode tokens
    assert eng.stream_stats.total_tokens + stats.total_tokens == 2


def test_speculate_streaming_handle_matches_manual_greedy(small_model):
    """The RequestHandle iterator drives the SPECULATIVE engine the same
    way it drives the plain one: incremental tokens equal manual greedy
    decoding, arriving a committed run at a time."""
    cfg, m, p = small_model
    base = np.array([6, 1, 9], np.int32)
    prompt = np.tile(base, 5).astype(np.int32)
    expect = _manual_greedy(cfg, m, p, prompt, 8)
    eng = ServeEngine(m, p, batch_slots=2, max_len=48, speculate="ngram")
    h = eng.submit(Request(rid=0, prompt=prompt, params=SamplingParams(max_new=8)))
    assert list(h) == expect
    assert h.done and h.finish_reason == "length"
    assert eng.stream_stats.spec_ticks > 0


# ===================== multi-architecture serving (MLA + SSM) ==============
# The unified packed engine serves three cache disciplines: positional GQA
# KV (covered above), compressed MLA latents, and constant-size SSM
# recurrent state. The pins below hold the MLA/SSM paths to the same bar as
# the dense one: packed == legacy bit-identical greedy streams under
# mid-stream admissions, chunking, and cancellation.


@pytest.fixture(scope="module")
def mla_model():
    cfg = get_arch("minicpm3-4b").reduced()  # dense + MLA latents
    m = LM(cfg)
    p = m.init(jax.random.key(3))
    return cfg, m, p


@pytest.fixture(scope="module")
def ssm_model():
    cfg = get_arch("falcon-mamba-7b").reduced()  # pure mamba1
    m = LM(cfg)
    p = m.init(jax.random.key(4))
    return cfg, m, p


@pytest.mark.parametrize("which", ["mla_model", "ssm_model"])
def test_multiarch_matches_manual_greedy(request, which):
    """The packed engine's greedy stream equals manual full-forward argmax
    decoding — correctness against the model itself, not just engine
    self-consistency."""
    cfg, m, p = request.getfixturevalue(which)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    expect = _manual_greedy(cfg, m, p, prompt, 5)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    assert eng.unified  # both families default onto the packed tier now
    eng.submit(Request(rid=0, prompt=prompt, params=SamplingParams(max_new=5)))
    eng.run()
    assert eng.finished[0].generated == expect


@pytest.mark.parametrize("which", ["mla_model", "ssm_model"])
def test_multiarch_packed_bit_identical_to_legacy(request, which):
    """Packed vs legacy prefill+insert, 8 ragged requests through 3 slots
    (mid-stream admissions), plus decode chunk depths {1,2,4,8}: pure
    scheduling choices, bit-identical greedy streams."""
    cfg, m, p = request.getfixturevalue(which)
    rng = np.random.default_rng(13)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (5, 23, 11, 31, 8, 17, 26, 3)
    ]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)  # legacy-tier note
        legacy, _ = _run_engine(m, p, prompts, slots=3, max_len=64,
                                unified=False)
    uni, _ = _run_engine(m, p, prompts, slots=3, max_len=64, unified=True)
    assert legacy == uni
    for mc in (1, 2, 4, 8):
        alt, _ = _run_engine(m, p, prompts, slots=3, max_len=64,
                             unified=True, max_chunk=mc)
        assert alt == uni


@pytest.mark.parametrize("which", ["mla_model", "ssm_model"])
def test_multiarch_cancel_preserves_neighbours(request, which):
    """Mid-stream cancellation on the MLA/SSM packed paths: neighbours'
    greedy streams stay bit-identical to an uncancelled run (for SSM this
    pins the inactive-slot state masking — a decode chunk must not touch a
    cancelled or mid-prefill slot's recurrent state)."""
    cfg, m, p = request.getfixturevalue(which)
    rng = np.random.default_rng(17)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 10, 8)
    ]
    ref, _ = _run_engine(m, p, prompts, slots=2, max_len=48, max_new=12)
    eng = ServeEngine(m, p, batch_slots=2, max_len=48)
    handles = [
        eng.submit(Request(rid=i, prompt=pr, params=SamplingParams(max_new=12)))
        for i, pr in enumerate(prompts)
    ]
    it = handles[0].tokens()
    first3 = [next(it) for _ in range(3)]
    handles[1].cancel()  # rid=1 is mid-decode in the other slot
    rest = list(it)
    assert first3 + rest == ref[0]
    assert handles[1].finish_reason == "cancelled"
    assert handles[2].result() == ref[2]  # freed slot reused, stream intact
    cut = handles[1].request.generated
    assert cut == ref[1][: len(cut)]


def test_ssm_serving_constant_memory_no_blocks(ssm_model):
    """SSM serving is the capacity flex: no block pool, and the resident
    state bytes are independent of max_len AND of how much has been
    served — recurrent state has no length axis to grow along."""
    cfg, m, p = ssm_model
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    tall = ServeEngine(m, p, batch_slots=2, max_len=256)
    assert eng.pool is None and tall.pool is None  # zero KV blocks
    assert eng.kv_bytes_resident() == tall.kv_bytes_resident()
    before = eng.kv_bytes_resident()
    assert before > 0
    rng = np.random.default_rng(23)
    for i, s in enumerate((5, 19, 9, 14)):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
            params=SamplingParams(max_new=6),
        ))
    eng.run()
    assert len(eng.finished) == 4
    assert eng.kv_bytes_resident() == before  # constant through serving


@pytest.mark.parametrize("which", ["mla_model", "ssm_model"])
def test_multiarch_rejects_paged_and_quantized(request, which):
    """MLA latents and SSM state have no positional KV rows to page or
    row-quantize: both knobs raise typed errors naming the family."""
    cfg, m, p = request.getfixturevalue(which)
    with pytest.raises(ValueError, match="no positional KV"):
        ServeEngine(m, p, batch_slots=2, max_len=32, kv_dtype="int8")
    with pytest.raises(ValueError, match="no positional KV|no paged path"):
        ServeEngine(m, p, batch_slots=2, max_len=32, kv_block_size=8)


def test_ssm_speculate_rejected(ssm_model):
    """Rejected draft tokens would need recurrent-state rollback, which
    the constant-memory cache cannot do — typed error at engine init."""
    cfg, m, p = ssm_model
    with pytest.raises(ValueError, match="cannot speculate"):
        ServeEngine(m, p, batch_slots=2, max_len=32, speculate="ngram")


def test_hybrid_legacy_tier_warning_and_unified_rejection():
    """A family with no packed path: unified=True is a typed error that
    names the escape hatch, unified=False serves with a one-time
    RuntimeWarning naming the cost (admissions block the decode slots)."""
    import repro.serve.engine as engine_mod

    cfg = get_arch("zamba2-2.7b").reduced()  # hybrid: attention + mamba2
    m = LM(cfg)
    p = m.init(jax.random.key(5))
    with pytest.raises(ValueError, match="no packed path"):
        ServeEngine(m, p, batch_slots=2, max_len=32, unified=True)
    engine_mod._LEGACY_WARNED.discard("hybrid")
    with pytest.warns(RuntimeWarning, match="legacy prefill"):
        ServeEngine(m, p, batch_slots=2, max_len=32, unified=False)
    with warnings.catch_warnings():  # once per family, not per engine
        warnings.simplefilter("error", RuntimeWarning)
        ServeEngine(m, p, batch_slots=2, max_len=32, unified=False)
