"""Serving engine: greedy output equals manual full-forward argmax decoding;
continuous batching bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import LM
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def _manual_greedy(cfg, m, p, prompt, n_new):
    toks = list(int(t) for t in prompt)
    for _ in range(n_new):
        logits, _ = jax.jit(m.forward)(
            p, {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_manual_greedy(small_model):
    cfg, m, p = small_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    expect = _manual_greedy(cfg, m, p, prompt, 6)

    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    eng.run()
    assert eng.finished[0].generated == expect


def test_engine_batched_isolation(small_model):
    """Two different prompts decoded together must match their solo runs."""
    cfg, m, p = small_model
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    e1 = _manual_greedy(cfg, m, p, p1, 5)
    e2 = _manual_greedy(cfg, m, p, p2, 5)

    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    eng.submit(Request(rid=1, prompt=p1, max_new=5))
    eng.submit(Request(rid=2, prompt=p2, max_new=5))
    eng.run()
    got = {r.rid: r.generated for r in eng.finished}
    assert got[1] == e1
    assert got[2] == e2


def test_continuous_batching_reuses_slots(small_model):
    cfg, m, p = small_model
    rng = np.random.default_rng(3)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    for i in range(5):
        eng.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
                max_new=4,
            )
        )
    stats = eng.run()
    assert stats.total_requests == 5
    # first token of each request comes from prefill; engine ticks decode the rest
    assert stats.total_tokens == 5 * 3
    assert all(len(r.generated) == 4 for r in eng.finished)
    # with 2 slots and 5 requests, ticks must exceed one request's decode span
    assert stats.ticks >= 3 * 3 - 2
    assert all(r.done_at is not None for r in eng.finished)
