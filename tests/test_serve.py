"""Serving engine: greedy output equals manual full-forward argmax decoding;
continuous batching bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import LM
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def _manual_greedy(cfg, m, p, prompt, n_new):
    toks = list(int(t) for t in prompt)
    for _ in range(n_new):
        logits, _ = jax.jit(m.forward)(
            p, {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_manual_greedy(small_model):
    cfg, m, p = small_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    expect = _manual_greedy(cfg, m, p, prompt, 6)

    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    eng.run()
    assert eng.finished[0].generated == expect


def test_engine_batched_isolation(small_model):
    """Two different prompts decoded together must match their solo runs."""
    cfg, m, p = small_model
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    e1 = _manual_greedy(cfg, m, p, p1, 5)
    e2 = _manual_greedy(cfg, m, p, p2, 5)

    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    eng.submit(Request(rid=1, prompt=p1, max_new=5))
    eng.submit(Request(rid=2, prompt=p2, max_new=5))
    eng.run()
    got = {r.rid: r.generated for r in eng.finished}
    assert got[1] == e1
    assert got[2] == e2


def _run_engine(m, p, prompts, *, max_new=6, slots=2, max_len=32,
                temperatures=None, **kw):
    eng = ServeEngine(m, p, batch_slots=slots, max_len=max_len, **kw)
    for i, pr in enumerate(prompts):
        eng.submit(Request(
            rid=i, prompt=pr, max_new=max_new,
            temperature=0.0 if temperatures is None else temperatures[i],
        ))
    stats = eng.run()
    return {r.rid: r.generated for r in eng.finished}, stats


def test_unified_bit_identical_to_legacy_greedy(small_model):
    """Unified ragged dispatch and the legacy prefill+insert engine must
    produce bit-identical greedy token streams on the same ragged stream,
    including mid-stream admissions (8 requests through 3 slots)."""
    cfg, m, p = small_model
    rng = np.random.default_rng(11)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (5, 23, 11, 31, 8, 17, 26, 3)
    ]
    legacy, _ = _run_engine(m, p, prompts, slots=3, max_len=64, unified=False)
    uni, _ = _run_engine(m, p, prompts, slots=3, max_len=64, unified=True)
    assert legacy == uni


def test_chunked_vs_unchunked_equivalence(small_model):
    """Output must not depend on either chunking knob under mid-stream
    admissions: prefill budget (packed chunk size) and decode chunk depth
    (k forced to 1) are pure scheduling choices."""
    cfg, m, p = small_model
    rng = np.random.default_rng(12)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (19, 7, 27, 13, 22)
    ]
    base, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                          unified=True, prefill_budget=64)  # one-shot prefill
    chunked, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                             unified=True, prefill_budget=5)
    assert base == chunked
    legacy, _ = _run_engine(m, p, prompts, slots=2, max_len=48, unified=False)
    legacy_k1, _ = _run_engine(m, p, prompts, slots=2, max_len=48,
                               unified=False, max_chunk=1)
    assert legacy == legacy_k1


@pytest.mark.parametrize("unified", [False, True])
def test_prompt_at_capacity_boundary(small_model, unified):
    """len(prompt) == max_len - 1: one decode write still fits, so the
    request yields exactly min(max_new, 2) tokens on both engines."""
    cfg, m, p = small_model
    rng = np.random.default_rng(13)
    max_len = 32
    prompt = rng.integers(0, cfg.vocab_size, size=max_len - 1).astype(np.int32)
    got, stats = _run_engine(m, p, [prompt], max_new=6, max_len=max_len,
                             unified=unified)
    assert len(got[0]) == 2
    assert stats.total_requests == 1


@pytest.mark.parametrize("unified", [False, True])
def test_max_new_one(small_model, unified):
    """max_new=1: exactly one token (the prefill sample), then finish —
    the slot is never occupied by a decode that can't run."""
    cfg, m, p = small_model
    rng = np.random.default_rng(14)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 9, 12)
    ]
    got, stats = _run_engine(m, p, prompts, max_new=1, unified=unified)
    assert all(len(v) == 1 for v in got.values())
    assert stats.total_requests == 3
    expect = {
        i: _manual_greedy(cfg, m, p, pr, 1) for i, pr in enumerate(prompts)
    }
    assert got == expect


def test_mixed_greedy_and_temperature_slots(small_model):
    """Greedy and temperature requests share packed ticks and decode chunks;
    the greedy streams must still match their solo runs exactly."""
    cfg, m, p = small_model
    rng = np.random.default_rng(15)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 10, 8, 12)
    ]
    temps = [0.0, 0.9, 0.0, 1.3]
    got, _ = _run_engine(m, p, prompts, max_new=5, slots=2, unified=True,
                         temperatures=temps)
    assert all(len(v) == 5 for v in got.values())
    for i in (0, 2):  # greedy slots: exact match vs solo manual decode
        assert got[i] == _manual_greedy(cfg, m, p, prompts[i], 5)
    for i in (1, 3):  # temperature slots: valid tokens
        assert all(0 <= t < cfg.vocab_size for t in got[i])


def test_stats_latency_tracking(small_model):
    """TTFT/TPOT per-request samples and percentile properties."""
    cfg, m, p = small_model
    rng = np.random.default_rng(16)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        for s in (6, 14, 9)
    ]
    for unified in (False, True):
        _, stats = _run_engine(m, p, prompts, max_new=4, unified=unified)
        assert len(stats.ttfts) == 3
        assert len(stats.tpots) == 3
        assert stats.ttft_p99 >= stats.ttft_p50 > 0
        assert stats.tpot_p99 >= stats.tpot_p50 > 0


def test_arrival_schedule(small_model):
    """Open-loop arrivals: requests submitted once the run clock passes
    their offsets; everything drains and TTFT excludes pre-arrival time."""
    cfg, m, p = small_model
    rng = np.random.default_rng(17)
    arrivals = [
        (i * 0.003, Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=5 + 3 * i).astype(np.int32),
            max_new=3,
        ))
        for i in range(4)
    ]
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    stats = eng.run(arrivals=arrivals)
    assert stats.total_requests == 4
    assert sorted(r.rid for r in eng.finished) == [0, 1, 2, 3]
    assert all(len(r.generated) == 3 for r in eng.finished)


def test_prewarm_covers_all_dispatch_variants(small_model):
    """After prewarm(), no compile may land inside the serving region —
    including the max_len-capped prompt bucket a non-pow2 max_len
    introduces (96 here) and sub-8 prompt buckets."""
    cfg, m, p = small_model
    rng = np.random.default_rng(18)
    eng = ServeEngine(m, p, batch_slots=2, max_len=96, unified=True,
                      prefill_budget=96)
    eng.prewarm()
    for i, s in enumerate((3, 70, 90)):  # buckets 4, 96, 96
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
            max_new=3,
        ))
    stats = eng.run()
    assert stats.total_requests == 3
    assert stats.prefill_compiles == 0, stats.prefill_compiles


def test_continuous_batching_reuses_slots(small_model):
    cfg, m, p = small_model
    rng = np.random.default_rng(3)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32)
    for i in range(5):
        eng.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
                max_new=4,
            )
        )
    stats = eng.run()
    assert stats.total_requests == 5
    # first token of each request comes from prefill; engine ticks decode the rest
    assert stats.total_tokens == 5 * 3
    assert all(len(r.generated) == 4 for r in eng.finished)
    # with 2 slots and 5 requests, ticks must exceed one request's decode span
    assert stats.ticks >= 3 * 3 - 2
    assert all(r.done_at is not None for r in eng.finished)
