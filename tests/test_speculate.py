"""Speculative decoding: drafter units (n-gram cyclic extension, the
truncated-self model drafter), the spec_verify acceptance oracle, and the
engine-level invariant that matters — a seeded stream with speculation ON
is bit-identical to the same stream with speculation OFF, across dense and
block-paged caches, chunk sizes, stop tokens, cancellation and tenant
opt-outs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import LM
from repro.serve import (
    ModelDrafter,
    NGramDrafter,
    Request,
    SamplingParams,
    ServeEngine,
    SpeculateConfig,
)
from repro.serve.sampling import SMODE_GREEDY, SMODE_MASKED, fused_sample, spec_verify


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


# ------------------------------------------------------------- NGramDrafter


def _ngram(vocab=256, max_n=8):
    d = NGramDrafter(max_n=max_n)
    d.setup(None, 4, 64, vocab)
    return d


@pytest.mark.parametrize("vocab", [256, 512])  # bytes path / int path
def test_ngram_cyclic_extension(vocab):
    """A period-3 cycle unrolls to the FULL requested depth: each proposal
    joins the working context before the next lookup, so the match region
    grows with the proposals instead of truncating at the context end."""
    d = _ngram(vocab)
    ctx = np.array([1, 2, 3, 1, 2, 3, 1, 2], np.int64)
    (props,) = d.propose([ctx], np.array([6]))
    assert props == [3, 1, 2, 3, 1, 2]


def test_ngram_no_match_proposes_nothing():
    d = _ngram()
    (props,) = d.propose([np.array([5, 6, 7, 8], np.int64)], np.array([4]))
    assert props == []


def test_ngram_prefers_longest_suffix():
    """The 2-gram [1, 2] -> 9 must win over the more recent 1-gram
    continuation [2] -> 3."""
    d = _ngram()
    ctx = np.array([1, 2, 9, 5, 2, 3, 1, 2], np.int64)
    (props,) = d.propose([ctx], np.array([1]))
    assert props == [9]


def test_ngram_byte_and_int_paths_agree():
    rng = np.random.default_rng(0)
    ctx = rng.integers(0, 200, size=40).astype(np.int64)
    ctx[-6:] = ctx[4:10]  # plant a suffix match
    db, di = _ngram(256), _ngram(50_000)
    assert db.propose([ctx], np.array([8])) == di.propose([ctx], np.array([8]))


def test_ngram_skips_depth_zero_and_none_slots():
    d = _ngram()
    ctx = np.array([1, 2, 1, 2], np.int64)
    out = d.propose([ctx, None, ctx], np.array([0, 4, 2]))
    assert out == [[], [], [1, 2]]


# ------------------------------------------------------------- spec_verify


@pytest.mark.parametrize("smode", [SMODE_GREEDY, SMODE_MASKED])
def test_spec_verify_matches_per_row_sequential_sampling(smode):
    """Oracle: the packed verify targets must equal one-row fused_sample
    calls at each (slot, offset), and n_accept must be the leading
    exact-match run — including a temp-0 row inside a sampled dispatch,
    depth masking, and an inactive slot."""
    rng = np.random.default_rng(3)
    b, k, V = 3, 4, 64
    w = k + 1
    logits = jnp.asarray(rng.normal(size=(b * w, V)).astype(np.float32))
    temps = jnp.asarray([0.9, 0.0, 0.7], jnp.float32)
    top_k = jnp.asarray([0, 0, 5], jnp.int32)
    top_p = jnp.asarray([0.9, 1.0, 1.0], jnp.float32)
    seeds = jnp.asarray([11, 12, 13], jnp.int32)
    pos0 = jnp.asarray([6, 3, 9], jnp.int32)
    depth = jnp.asarray([4, 2, 0], jnp.int32)
    active = jnp.asarray([1, 1, 0], jnp.int32)
    btok = jnp.full((b, 8), 2**30, jnp.int32)
    bval = jnp.zeros((b, 8), jnp.float32)
    btok = btok.at[0, 0].set(3)
    bval = bval.at[0, 0].set(5.0)

    ref = np.zeros((b, w), np.int32)
    for i in range(b):
        for j in range(w):
            ref[i, j] = int(fused_sample(
                logits[i * w + j][None], temps[i:i + 1], top_k[i:i + 1],
                top_p[i:i + 1], seeds[i:i + 1], pos0[i:i + 1] + j,
                btok[i:i + 1], bval[i:i + 1], smode=smode,
            )[0])

    # drafts: slot 0 matches the first 2 targets then diverges; slot 1
    # matches all of its (depth-masked) 2; slot 2 is inactive
    drafts = np.zeros((b, k), np.int32)
    drafts[0, :2] = ref[0, :2]
    drafts[0, 2] = (ref[0, 2] + 1) % V
    drafts[1, :2] = ref[1, :2]
    targets, n_acc, commit = spec_verify(
        logits, jnp.asarray(drafts), depth, active, temps, top_k, top_p,
        seeds, pos0, btok, bval, smode=smode,
    )
    np.testing.assert_array_equal(np.asarray(targets), ref)
    assert list(np.asarray(n_acc)) == [2, 2, 0]
    assert list(np.asarray(commit)) == [3, 3, 0]


def test_spec_verify_depth_zero_commits_one():
    """k=0 (a pure decode dispatch through the verify program) commits
    exactly the one sequential token per active slot."""
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32))
    z = jnp.zeros(2, jnp.int32)
    targets, n_acc, commit = spec_verify(
        logits, jnp.zeros((2, 0), jnp.int32), z, jnp.asarray([1, 0], jnp.int32),
        jnp.zeros(2, jnp.float32), z, jnp.ones(2, jnp.float32), z, z,
        jnp.full((2, 8), 2**30, jnp.int32), jnp.zeros((2, 8), jnp.float32),
        smode=SMODE_GREEDY,
    )
    assert targets.shape == (2, 1)
    assert list(np.asarray(commit)) == [1, 0]


# ------------------------------------------------------------ ModelDrafter


def test_model_drafter_matches_draft_model_greedy(small_model):
    """Proposals must equal the shallow draft model's own sequential greedy
    continuation — including across INCREMENTAL propose calls, where the
    second call only feeds the catch-up suffix into the draft cache."""
    cfg, m, p = small_model
    d = ModelDrafter.truncated(m, p, n_layers=1)
    assert d.model.cfg.n_layers == 1
    from repro.serve.backend import resolve_backend

    d.setup(resolve_backend(None), 2, 64, cfg.vocab_size)

    rng = np.random.default_rng(5)
    ctx = rng.integers(0, cfg.vocab_size, size=10).astype(np.int64)

    def draft_greedy(toks, n):
        out = list(int(t) for t in toks)
        for _ in range(n):
            logits, _ = jax.jit(d.model.forward)(
                d._params_in, {"tokens": jnp.asarray(out, jnp.int32)[None]}
            )
            out.append(int(jnp.argmax(logits[0, -1])))
        return out[len(toks):]

    props = d.propose([ctx, None], np.array([4, 0]))
    assert props[0] == draft_greedy(ctx, 4)
    assert props[1] == []
    # commit 2 of those tokens, extend the context, propose again: the
    # catch-up feeds only the 2 new tokens (fed pointer advanced)
    ctx2 = np.concatenate([ctx, np.asarray(props[0][:2], np.int64)])
    props2 = d.propose([ctx2, None], np.array([3, 0]))
    assert props2[0] == draft_greedy(ctx2, 3)
    assert int(d.fed[0]) == len(ctx2)


def test_model_drafter_slot_reuse_resets_cleanly(small_model):
    """reset_slot + a shorter context (slot handed to a new request) must
    refeed from scratch and still match the draft model's greedy."""
    cfg, m, p = small_model
    from repro.serve.backend import resolve_backend

    d = ModelDrafter.truncated(m, p, n_layers=1)
    d.setup(resolve_backend(None), 1, 64, cfg.vocab_size)
    rng = np.random.default_rng(6)
    long = rng.integers(0, cfg.vocab_size, size=20).astype(np.int64)
    short = rng.integers(0, cfg.vocab_size, size=7).astype(np.int64)
    d.propose([long], np.array([2]))
    d.reset_slot(0)
    (props,) = d.propose([short], np.array([3]))
    d2 = ModelDrafter.truncated(m, p, n_layers=1)
    d2.setup(resolve_backend(None), 1, 64, cfg.vocab_size)
    (fresh,) = d2.propose([short], np.array([3]))
    assert props == fresh


# ----------------------------------------------------- engine bit-identity


def _spec_requests(cfg, *, max_new=10, n=5):
    """A mixed stream: repetitive prompts (the drafter's best case),
    random prompts (its worst case), greedy and seeded-sampled slots."""
    rng = np.random.default_rng(9)
    reqs = []
    for i in range(n):
        if i % 2 == 0:
            base = rng.integers(0, cfg.vocab_size, size=3)
            prompt = np.tile(base, 6).astype(np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
        sampled = i % 3 != 0
        reqs.append(Request(
            rid=i, prompt=prompt,
            params=SamplingParams(
                max_new=max_new,
                temperature=0.8 if sampled else 0.0,
                top_p=0.9 if sampled else 1.0,
                seed=70 + i,
            ),
        ))
    return reqs


def _run(m, cfg, p, reqs, **kw):
    eng = ServeEngine(m, p, batch_slots=2, max_len=64, **kw)
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    return {r.rid: list(r.generated) for r in eng.finished}, stats, eng


@pytest.mark.parametrize("spec_kw", [
    {"speculate": "ngram"},
    {"speculate": "ngram", "kv_block_size": 16},
    {"speculate": "ngram", "kv_block_size": 16, "prefix_cache": True},
    {"speculate": "draft"},
], ids=["ngram-dense", "ngram-paged", "ngram-paged-prefix", "draft-dense"])
def test_spec_bit_identical_to_spec_off(small_model, spec_kw):
    cfg, m, p = small_model
    ref, _, _ = _run(m, cfg, p, _spec_requests(cfg))
    got, stats, _ = _run(m, cfg, p, _spec_requests(cfg), **spec_kw)
    assert got == ref
    assert stats.spec_ticks > 0
    assert stats.spec_proposed > 0
    assert 0.0 <= stats.spec_acceptance <= 1.0


@pytest.mark.parametrize("max_chunk", [1, 2, 8])
def test_spec_identity_across_chunk_sizes(small_model, max_chunk):
    """Speculation composes with every max_chunk: the spec-off reference at
    that chunk size and the speculative run must agree token for token
    (chunking invariance and speculation invariance stack)."""
    cfg, m, p = small_model
    ref, _, _ = _run(m, cfg, p, _spec_requests(cfg, n=3), max_chunk=max_chunk)
    got, _, _ = _run(
        m, cfg, p, _spec_requests(cfg, n=3),
        max_chunk=max_chunk, speculate="ngram",
    )
    assert got == ref


def test_spec_stop_token_stops_at_exact_position(small_model):
    """A stop token landing inside an accepted run must terminate the
    stream at EXACTLY the token the sequential engine stops at (overrun
    values refunded, not emitted)."""
    cfg, m, p = small_model
    base = np.array([4, 9, 2], np.int32)
    prompt = np.tile(base, 6).astype(np.int32)
    probe = Request(rid=0, prompt=prompt, params=SamplingParams(max_new=10))
    eng = ServeEngine(m, p, batch_slots=2, max_len=64)
    eng.submit(probe)
    eng.run()
    stop = probe.generated[4]
    mk = lambda: [Request(
        rid=0, prompt=prompt,
        params=SamplingParams(max_new=10, stop=(int(stop),)),
    )]
    ref, _, _ = _run(m, cfg, p, mk())
    got, _, _ = _run(m, cfg, p, mk(), speculate="ngram")
    assert got == ref
    assert got[0][-1] == stop


def test_spec_cancel_preserves_neighbour_stream(small_model):
    """Cancelling one speculated stream mid-flight must not perturb its
    neighbour slot (per-slot depth masking + per-request PRNG keys)."""
    cfg, m, p = small_model
    rng = np.random.default_rng(13)
    pa = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    pb = np.tile(rng.integers(0, cfg.vocab_size, size=4), 4).astype(np.int32)
    sp = lambda: SamplingParams(max_new=12, temperature=0.7, seed=31)
    solo, _, _ = _run(
        m, cfg, p,
        [Request(rid=1, prompt=pb, params=sp())], speculate="ngram",
    )

    eng = ServeEngine(m, p, batch_slots=2, max_len=64, speculate="ngram")
    victim = eng.submit(Request(
        rid=0, prompt=pa, params=SamplingParams(max_new=12),
    ))
    eng.submit(Request(rid=1, prompt=pb, params=sp()))
    got = []
    for tok in victim:
        got.append(tok)
        if len(got) == 3:
            victim.cancel()
    eng.run()
    streams = {r.rid: list(r.generated) for r in eng.finished}
    assert streams[1] == solo[1]
    assert next(r for r in eng.finished if r.rid == 0).finish_reason == "cancelled"


def test_spec_tenant_opt_out(small_model):
    """A tenant with speculation disabled rides the verify dispatch at
    depth 0 — zero proposals for its slots, streams unchanged."""
    cfg, m, p = small_model
    reqs = lambda: [
        Request(
            rid=i, prompt=np.tile(np.array([3, 8], np.int32), 6),
            params=SamplingParams(max_new=8), tenant="b",
        )
        for i in range(3)
    ]
    ref, _, _ = _run(m, cfg, p, reqs())
    spec = SpeculateConfig(mode="ngram", tenants={"b": False})
    got, stats, _ = _run(m, cfg, p, reqs(), speculate=spec)
    assert got == ref
    assert stats.spec_proposed == 0
    assert stats.spec_ticks > 0


def test_spec_adaptive_depth_thresholds(small_model):
    """The acceptance EWMA maps onto the compiled {1, 2, 4, 8} depth zoo
    (no new shapes from adapting); a fixed-depth config always asks for
    the full k."""
    cfg, m, p = small_model
    eng = ServeEngine(m, p, batch_slots=2, max_len=64, speculate="ngram")
    for ewma, want in [(1.0, 8), (0.8, 8), (0.5, 4), (0.3, 2), (0.05, 1)]:
        eng._spec_ewma[0] = ewma
        assert eng._spec_depth(0) == want, ewma
    fixed = ServeEngine(
        m, p, batch_slots=2, max_len=64,
        speculate=SpeculateConfig(mode="ngram", adaptive=False),
    )
    fixed._spec_ewma[0] = 0.0
    assert fixed._spec_depth(0) == fixed.spec_k


def test_spec_adaptive_ewma_decays_on_rejection(small_model):
    """Rejected drafts must pull the proposing slot's EWMA below its
    optimistic start (shrinking later depths), and the stream itself stays
    bit-identical regardless."""
    cfg, m, p = small_model
    ref, _, _ = _run(m, cfg, p, _spec_requests(cfg, n=4))
    got, stats, eng = _run(m, cfg, p, _spec_requests(cfg, n=4),
                           speculate="ngram")
    assert got == ref
    assert stats.spec_accepted < stats.spec_proposed  # some rejections
    assert float(eng._spec_ewma.min()) < 1.0


def test_spec_prewarm_covers_every_verify_shape(small_model):
    """After prewarm(sampling=True) a mixed speculative run must hit zero
    runtime compiles: the verify depth ladder x smode zoo is finite."""
    cfg, m, p = small_model
    eng = ServeEngine(m, p, batch_slots=2, max_len=64, speculate="ngram")
    eng.prewarm(sampling=True)
    for r in _spec_requests(cfg, n=4):
        eng.submit(r)
    stats = eng.run()
    assert stats.prefill_compiles == 0
    assert stats.spec_ticks > 0


def test_spec_requires_unified_engine(small_model):
    cfg, m, p = small_model
    with pytest.raises(ValueError, match="unified"):
        ServeEngine(m, p, batch_slots=2, max_len=64, unified=False,
                    speculate="ngram")


def test_speculate_config_parse():
    assert SpeculateConfig.parse("off") is None
    assert SpeculateConfig.parse("ngram").mode == "ngram"
    d = SpeculateConfig.parse("draft:codeqwen1.5-7b")
    assert (d.mode, d.draft_arch) == ("draft", "codeqwen1.5-7b")
    assert SpeculateConfig.parse("ngram", k=4).k == 4
    with pytest.raises(ValueError):
        SpeculateConfig.parse("banana")
    with pytest.raises(ValueError):
        SpeculateConfig(mode="ngram", k=0)
