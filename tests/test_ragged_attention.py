"""Packed variable-length (ragged) attention: interpret-mode Pallas kernel
vs the jnp oracle for pure-decode, pure-prefill-chunk, and mixed packs
(GQA grouping, per-slot lengths, sliding windows, bucket padding), and the
packed model step vs the full prefill path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.kernels import ops, ref
from repro.models import LM

F32 = jnp.float32


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _rand(rng, shape, dtype=F32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _cache(rng, b, s_max, kv, d):
    return _rand(rng, (b, s_max, kv, d)), _rand(rng, (b, s_max, kv, d))


def _check(q, k, v, tok_slot, tok_pos, *, window=0, block_s=16, n_real=None):
    """interpret-mode kernel vs oracle on the [T, H, d] dispatch layout.
    ``n_real`` limits the comparison to the pack's real tokens — bucket
    padding rows (pos >= S_max) are contractually ignored by callers."""
    got = ops.ragged_attention(
        q, k, v, tok_slot, tok_pos, window=window,
        mode="interpret", block_s=block_s,
    )
    want = ops.ragged_attention(q, k, v, tok_slot, tok_pos, window=window, mode="ref")
    n = len(tok_slot) if n_real is None else n_real
    np.testing.assert_allclose(
        np.asarray(got)[:n], np.asarray(want)[:n], rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("h,kv", [(4, 4), (6, 2)])  # MHA and GQA grouping
def test_pure_decode_pack_matches_oracle(rng, h, kv):
    b, s_max, d = 3, 40, 16
    k, v = _cache(rng, b, s_max, kv, d)
    q = _rand(rng, (b, h, d))
    tok_slot = jnp.arange(b, dtype=jnp.int32)
    tok_pos = jnp.asarray([5, 17, 33], jnp.int32)  # ragged per-slot lengths
    _check(q, k, v, tok_slot, tok_pos)


def test_pure_decode_pack_matches_decode_attention(rng):
    """A pack of one token per slot at cur_len IS batched decode attention."""
    b, s_max, h, kv, d = 3, 40, 4, 2, 16
    k, v = _cache(rng, b, s_max, kv, d)
    q = _rand(rng, (b, h, d))
    cur = jnp.asarray([5, 17, 33], jnp.int32)
    got = ops.ragged_attention(
        q, k, v, jnp.arange(b, dtype=jnp.int32), cur, mode="ref"
    )
    want = ops.decode_attention(q, k, v, cur, mode="ref")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2)])
def test_prefill_chunk_pack_matches_oracle(rng, h, kv):
    """A prefill chunk: consecutive positions of one slot, mid-cache."""
    b, s_max, d = 2, 48, 16
    k, v = _cache(rng, b, s_max, kv, d)
    t = 9
    q = _rand(rng, (t, h, d))
    tok_slot = jnp.full((t,), 1, jnp.int32)
    tok_pos = jnp.arange(12, 12 + t, dtype=jnp.int32)
    _check(q, k, v, tok_slot, tok_pos)


@pytest.mark.parametrize("window", [0, 8])
def test_mixed_pack_matches_oracle(rng, window):
    """Decode singletons + a prefill chunk + bucket padding in one pack."""
    b, s_max, h, kv, d = 3, 40, 4, 2, 16
    k, v = _cache(rng, b, s_max, kv, d)
    # slots 0/2 decode at their cur_len; slot 1 prefills positions 4..9;
    # two padding tokens point at slot 0 past max_len
    tok_slot = jnp.asarray([0, 2, 1, 1, 1, 1, 1, 1, 0, 0], jnp.int32)
    tok_pos = jnp.asarray([7, 21, 4, 5, 6, 7, 8, 9, s_max, s_max], jnp.int32)
    q = _rand(rng, (len(tok_slot), h, d))
    _check(q, k, v, tok_slot, tok_pos, window=window, n_real=8)


def test_prefill_chunk_is_causally_exact(rng):
    """Chunked packed attention over a scattered cache equals one-shot full
    causal attention over the same sequence."""
    from repro.models.attention import dense_attention

    s, h, kv, d = 12, 4, 2, 16
    s_max = 32
    kseq = _rand(rng, (1, s, kv, d))
    vseq = _rand(rng, (1, s, kv, d))
    q = _rand(rng, (1, s, h, d))
    want = dense_attention(q, kseq, vseq, causal=True)  # [1, S, H, d]

    kc = jnp.zeros((2, s_max, kv, d), F32).at[1, :s].set(kseq[0])
    vc = jnp.zeros((2, s_max, kv, d), F32).at[1, :s].set(vseq[0])
    got = jnp.concatenate([
        ops.ragged_attention(
            q[0, st : st + 4], kc, vc,
            jnp.full((min(4, s - st),), 1, jnp.int32),
            jnp.arange(st, min(st + 4, s), dtype=jnp.int32),
            mode="ref",
        )
        for st in range(0, s, 4)
    ])  # three chunks of 4
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want[0]), rtol=2e-4, atol=2e-4
    )


def test_ref_oracle_padding_rows_are_finite(rng):
    """Bucket-padding tokens (pos >= S_max) must not poison the pack."""
    b, s_max, h, kv, d = 2, 16, 4, 2, 8
    k, v = _cache(rng, b, s_max, kv, d)
    q = _rand(rng, (3, h, d))
    out = ops.ragged_attention(
        q, k, v,
        jnp.asarray([0, 1, 0], jnp.int32),
        jnp.asarray([3, 5, s_max], jnp.int32),
        mode="ref",
    )
    assert bool(jnp.isfinite(out).all())


def test_packed_step_matches_prefill_and_decode():
    """LM.packed_step chunked over a prompt reproduces the full prefill's
    cache and last-token logits, then decodes like decode_step."""
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=7).astype(np.int32)
    b, s_max = 2, 24

    logits_ref, one_cache = jax.jit(lambda pp, bb: m.prefill(pp, bb, s_max))(
        p, {"tokens": jnp.asarray(prompt)[None]}
    )
    cache = m.init_cache(b, s_max)
    step = jax.jit(m.packed_step)
    last = None
    for st in range(0, len(prompt), 3):
        chunk = prompt[st : st + 3]
        logits, cache = step(
            p, cache, jnp.asarray(chunk),
            jnp.full((len(chunk),), 1, jnp.int32),
            jnp.arange(st, st + len(chunk), dtype=jnp.int32),
        )
        last = logits[-1]
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_ref[0, len(prompt) - 1]),
        rtol=1e-4, atol=1e-4,
    )
    # the scattered cache row equals the prefill cache
    for key in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(cache[key][:, 1, : len(prompt)]),
            np.asarray(one_cache[key][:, 0, : len(prompt)]),
            rtol=1e-5, atol=1e-5,
        )
