"""Quantized serving: int8 KV cache (in-kernel dequant, per-(position, head)
scales resident in the cache pytree), int8 weight serving, and dtype-aware
byte accounting.

These are the ENFORCEABLE invariants behind the report-only ``_quant_``
bench rows (see benchmarks/check_regression.py): the f32 lane is bit-exact,
int8 quality stays inside the TV / greedy-agreement gates, capacity really
is byte-accounted, and the quantized cache composes with every serving
feature (paged pool, prefix COW, speculation, split/merge reconfigure).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_sampling import N_DRAWS, _draw, _tv, oracle_probs

from repro.configs import get_arch
from repro.core.modes import Mode
from repro.dist.compression import dequantize_rows, quantize_rows
from repro.kernels import ops
from repro.kernels.autotune import cache_key
from repro.models import LM
from repro.models.quant import is_quantized, quantize_params, qweight
from repro.serve import Request, SamplingParams, ServeCluster, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


def _reqs(cfg, sizes, *, max_new=4, seed=21, prefix=None, **pkw):
    """Fresh Request objects each call (requests are mutated in-flight), so
    the same (sizes, seed) always replays the identical stream."""
    rng = np.random.default_rng(seed)
    out = []
    for i, s in enumerate(sizes):
        prompt = rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt]).astype(np.int32)
        out.append(
            Request(rid=i, prompt=prompt, params=SamplingParams(max_new=max_new, **pkw))
        )
    return out


def _serve(m, p, reqs, **kw):
    eng = ServeEngine(m, p, **kw)
    for r in reqs:
        eng.submit(r)
    stats = eng.run()
    return {r.rid: r.generated for r in eng.finished}, stats, eng


# --------------------------------------------------- row-quant primitive


def test_quantize_rows_error_bound_and_sign():
    rng = np.random.default_rng(0)
    x = jnp.asarray(3.0 * rng.standard_normal((5, 7, 16)), jnp.float32)
    q, s = quantize_rows(x, jnp.int8)
    assert q.dtype == jnp.int8 and s.shape == (5, 7) and s.dtype == jnp.float32
    deq = np.asarray(dequantize_rows(q, s))
    err = np.abs(deq - np.asarray(x))
    bound = np.asarray(s)[..., None] / 2 + 1e-6  # round-to-nearest half-ULP
    assert (err <= bound).all(), err.max()
    # symmetric codebook: sign survives wherever |x| clears one step
    big = np.abs(np.asarray(x)) > np.asarray(s)[..., None]
    assert (np.sign(deq)[big] == np.sign(np.asarray(x))[big]).all()


def test_quantize_rows_zero_row_safe():
    x = jnp.zeros((3, 4, 8), jnp.float32)
    q, s = quantize_rows(x, jnp.int8)
    assert (np.asarray(s) > 0).all()  # amax=0 rows fall back to scale=1/127*?
    assert (np.asarray(dequantize_rows(q, s)) == 0).all()


# ----------------------------------------------- kernels: in-kernel dequant


def _quant_kv(rng, b, s, kv, d):
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    kq, ks = quantize_rows(k, jnp.int8)
    vq, vs = quantize_rows(v, jnp.int8)
    return (kq, ks, vq, vs)


def test_decode_attention_q8_matches_dequant_oracle():
    rng = np.random.default_rng(1)
    b, s, kv, g, d = 2, 32, 2, 2, 16
    q = jnp.asarray(rng.standard_normal((b, kv * g, d)), jnp.float32)
    kq, ks, vq, vs = _quant_kv(rng, b, s, kv, d)
    cur = jnp.asarray([7, 29], jnp.int32)
    ref_q8 = ops.decode_attention(q, kq, vq, cur, mode="ref", k_scale=ks, v_scale=vs)
    ref_deq = ops.decode_attention(
        q, dequantize_rows(kq, ks), dequantize_rows(vq, vs), cur, mode="ref"
    )
    np.testing.assert_allclose(ref_q8, ref_deq, rtol=1e-6, atol=1e-6)
    got = ops.decode_attention(
        q, kq, vq, cur, mode="interpret", block_s=16, k_scale=ks, v_scale=vs
    )
    np.testing.assert_allclose(got, ref_q8, rtol=2e-4, atol=2e-4)


def test_ragged_attention_q8_matches_dequant_oracle():
    rng = np.random.default_rng(2)
    b, s, kv, g, d, t = 3, 32, 2, 2, 16, 10
    q = jnp.asarray(rng.standard_normal((t, kv * g, d)), jnp.float32)
    kq, ks, vq, vs = _quant_kv(rng, b, s, kv, d)
    slots = jnp.asarray(rng.integers(0, b, size=t), jnp.int32)
    poss = jnp.asarray(rng.integers(0, s, size=t), jnp.int32)
    ref_q8 = ops.ragged_attention(
        q, kq, vq, slots, poss, mode="ref", k_scale=ks, v_scale=vs
    )
    ref_deq = ops.ragged_attention(
        q, dequantize_rows(kq, ks), dequantize_rows(vq, vs), slots, poss, mode="ref"
    )
    np.testing.assert_allclose(ref_q8, ref_deq, rtol=1e-6, atol=1e-6)
    got = ops.ragged_attention(
        q, kq, vq, slots, poss, mode="interpret", block_s=16, k_scale=ks, v_scale=vs
    )
    np.testing.assert_allclose(got, ref_q8, rtol=2e-4, atol=2e-4)


def test_paged_attention_q8_matches_dequant_oracle():
    rng = np.random.default_rng(3)
    nb, bs, kv, g, d, b = 8, 8, 2, 2, 16, 2
    q = jnp.asarray(rng.standard_normal((b, kv * g, d)), jnp.float32)
    kq, ks, vq, vs = _quant_kv(rng, nb, bs, kv, d)  # pool layout [NB, bs, KV, d]
    tables = jnp.arange(nb, dtype=jnp.int32).reshape(b, nb // b)
    cur = jnp.asarray([9, 27], jnp.int32)
    ref_q8 = ops.paged_decode_attention(
        q, kq, vq, cur, tables, mode="ref", k_scale=ks, v_scale=vs
    )
    ref_deq = ops.paged_decode_attention(
        q, dequantize_rows(kq, ks), dequantize_rows(vq, vs), cur, tables, mode="ref"
    )
    np.testing.assert_allclose(ref_q8, ref_deq, rtol=1e-6, atol=1e-6)
    got = ops.paged_decode_attention(
        q, kq, vq, cur, tables, mode="interpret", k_scale=ks, v_scale=vs
    )
    np.testing.assert_allclose(got, ref_q8, rtol=2e-4, atol=2e-4)


def test_matmul_q8_matches_ref():
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.standard_normal((48, 40)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((40, 24)), jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.where(amax > 0, amax, 1.0) / 127.0
    q8 = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    ref_out = ops.matmul_q8(a, q8, scale, mode="ref")
    np.testing.assert_allclose(
        ref_out, a @ (q8.astype(jnp.float32) * scale), rtol=1e-5, atol=1e-5
    )
    got = ops.matmul_q8(a, q8, scale, mode="interpret", block=16)
    np.testing.assert_allclose(got, ref_out, rtol=2e-4, atol=2e-4)


def test_autotune_cache_key_kv_dtype_component():
    base = cache_key("decode_attention", (2, 64, 2, 16), jnp.float32, "cpu")
    q8 = cache_key(
        "decode_attention", (2, 64, 2, 16), jnp.float32, "cpu", kv_dtype=jnp.int8
    )
    assert base != q8 and q8 == base + "|kvint8"  # old keys unchanged


# ------------------------------------------- engine: f32 identity lane


def test_engine_kv_f32_lane_bit_identical(small_model):
    """kv_dtype='f32' keeps the full scale machinery (scale leaves, chunked
    admission, quantize_rows identity lane) yet streams bit-identically to
    the plain scale-less engine."""
    cfg, m, p = small_model
    sizes = (5, 11, 8, 14)
    base, _, _ = _serve(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    ident, _, eng = _serve(
        m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32, kv_dtype="f32"
    )
    assert ident == base
    assert "k_scale" in eng.cache and eng.cache["k"].dtype == jnp.float32


def test_engine_kv_f32_lane_paged_prefix_bit_identical(small_model):
    cfg, m, p = small_model
    sizes = (5, 11, 8)
    base, _, _ = _serve(
        m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32, kv_block_size=8
    )
    ident, _, _ = _serve(
        m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32, kv_block_size=8,
        prefix_cache=True, kv_dtype="f32",
    )
    assert ident == base


def test_engine_rejects_kv_dtype_on_legacy_path(small_model):
    cfg, m, p = small_model
    with pytest.raises(ValueError, match="unified"):
        ServeEngine(m, p, batch_slots=2, max_len=32, unified=False, kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        ServeEngine(m, p, batch_slots=2, max_len=32, kv_dtype="bf16")


# --------------------------------------------- engine: int8 quality gates


def test_engine_int8_greedy_agreement(small_model):
    """The steady greedy scenario, teacher-forced: replay the fp32 engine's
    streams through fp32 and int8 caches and compare every argmax decision.
    The >= 99% acceptance gate applies to decisions whose fp32 top-2 margin
    clears the measured int8 noise floor — on this RANDOM-INIT reduced model
    ~15% of steps are sub-0.03 near-ties that no 8-bit cache (or bf16, or a
    different matmul order) can pin down; a trained model's margins put
    virtually every step above the floor. Overall agreement is bounded too,
    and the logit perturbation itself is pinned."""
    cfg, m, p = small_model
    sizes = (5, 8, 11, 13, 16, 19, 23, 27)
    base, _, eng = _serve(
        m, p, _reqs(cfg, sizes, max_new=12), batch_slots=4, max_len=48,
        kv_dtype="int8",
    )
    assert eng.cache["k"].dtype == jnp.int8
    assert all(len(t) == 12 for t in base.values())
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32) for s in sizes]
    total = agree = decided = decided_agree = 0
    max_err = 0.0
    for i, pr in enumerate(prompts):
        seq = jnp.asarray(np.concatenate([pr, np.asarray(base[i], np.int32)]))
        t = len(seq)
        slot = jnp.zeros((t,), jnp.int32)
        pos = jnp.arange(t, dtype=jnp.int32)
        rows = jnp.arange(len(pr) - 1, t - 1, dtype=jnp.int32)  # decision points
        lf, _ = m.packed_step(p, m.init_cache(1, 64), seq, slot, pos, out_rows=rows)
        lq, _ = m.packed_step(
            p, m.init_cache(1, 64, kv_dtype=jnp.int8), seq, slot, pos, out_rows=rows
        )
        lf, lq = np.asarray(lf), np.asarray(lq)
        max_err = max(max_err, float(np.abs(lf - lq).max()))
        srt = np.sort(lf, axis=-1)
        margin = srt[:, -1] - srt[:, -2]
        same = lf.argmax(-1) == lq.argmax(-1)
        total += len(same)
        agree += int(same.sum())
        clear = margin > 0.03  # ~2x the observed noise floor
        decided += int(clear.sum())
        decided_agree += int(same[clear].sum())
    assert max_err < 0.05, max_err  # int8 KV perturbs logits by ~1e-2 here
    assert decided >= total // 2  # the gate must actually cover the run
    assert decided_agree / decided >= 0.99, (
        f"greedy agreement {decided_agree}/{decided} above the noise floor"
    )
    assert agree / total >= 0.9, f"overall agreement {agree}/{total}"


def test_int8_kv_sampling_tv_under_gate(small_model):
    """Sampling quality gate: 20k draws from the next-token distribution
    computed over an int8 KV cache stay within TV < 0.05 of the fp32
    renormalized-softmax oracle (reusing test_sampling's oracle/draw
    helpers). top-k bounds the support so binomial noise at N_DRAWS is
    ~0.01 — the budget is almost entirely quantization error."""
    cfg, m, p = small_model
    rng = np.random.default_rng(7)
    t = 24
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=t), jnp.int32)
    slot = jnp.zeros((t,), jnp.int32)
    pos = jnp.arange(t, dtype=jnp.int32)
    last = jnp.asarray([t - 1], jnp.int32)

    cache_f = m.init_cache(1, 32)
    logits_f, _ = m.packed_step(p, cache_f, prompt, slot, pos, out_rows=last)
    cache_q = m.init_cache(1, 32, kv_dtype=jnp.int8)
    logits_q, _ = m.packed_step(p, cache_q, prompt, slot, pos, out_rows=last)
    # positive control: the f32 store lane reproduces the plain logits bitwise
    cache_i = m.init_cache(1, 32, kv_dtype=jnp.float32)
    logits_i, _ = m.packed_step(p, cache_i, prompt, slot, pos, out_rows=last)
    assert (np.asarray(logits_i) == np.asarray(logits_f)).all()

    sp = SamplingParams(max_new=1, temperature=0.8, top_k=16, top_p=0.95)
    draws = _draw(np.asarray(logits_q[0]), sp, n=N_DRAWS)
    counts = np.bincount(draws, minlength=cfg.vocab_size)
    probs = oracle_probs(np.asarray(logits_f[0]), sp)
    tv = _tv(counts, probs)
    assert tv < 0.05, f"TV(int8 draws, fp32 oracle) = {tv:.4f}"


# --------------------------------------------------- composition: features


def test_quant_paged_matches_quant_dense(small_model):
    """int8 through the paged pool == int8 through the dense cache: the
    pool's block-shaped scale leaves carry the same values the dense
    [B, S, KV] leaves do."""
    cfg, m, p = small_model
    sizes = (5, 11, 8, 14)
    dense, _, _ = _serve(
        m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32, kv_dtype="int8"
    )
    paged, _, eng = _serve(
        m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32, kv_block_size=8,
        kv_dtype="int8",
    )
    assert paged == dense
    assert eng.cache["k"].dtype == jnp.int8 and "k_scale" in eng.cache


def test_quant_prefix_cow_scales_travel(small_model):
    """Prefix-cache hits on an int8 pool reuse quantized blocks AND their
    scale rows: streams match a no-prefix int8 engine bit-for-bit while the
    radix tree actually serves hits (scales travel with the shared blocks
    through COW re-reference)."""
    cfg, m, p = small_model
    shared = np.arange(1, 17, dtype=np.int32)  # 16-token shared system prefix
    sizes = (5, 7, 9, 6)
    base, _, _ = _serve(
        m, p, _reqs(cfg, sizes, prefix=shared, max_new=6),
        batch_slots=2, max_len=64, kv_block_size=8, kv_dtype="int8",
    )
    got, _, eng = _serve(
        m, p, _reqs(cfg, sizes, prefix=shared, max_new=6),
        batch_slots=2, max_len=64, kv_block_size=8, kv_dtype="int8",
        prefix_cache=True,
    )
    assert got == base
    assert eng.prefix.stats().hit_tokens > 0


def test_quant_speculative_bit_identical(small_model):
    """ngram speculation over an int8 cache commits the same greedy streams
    as int8 without speculation (verify reads the same quantized rows)."""
    cfg, m, p = small_model
    sizes = (6, 10, 8)
    base, _, _ = _serve(
        m, p, _reqs(cfg, sizes, max_new=10), batch_slots=2, max_len=48,
        kv_dtype="int8",
    )
    spec, stats, _ = _serve(
        m, p, _reqs(cfg, sizes, max_new=10), batch_slots=2, max_len=48,
        kv_dtype="int8", speculate="ngram",
    )
    assert spec == base
    assert stats.spec_ticks > 0


def test_quant_cluster_mid_stream_reconfigure(small_model):
    """int8 KV + int8 weights survive a mid-stream SPLIT->MERGE drain/
    re-home/resume with streams bit-identical to an uninterrupted int8
    engine (both fabrics quantize identically, so a re-homed request's
    re-prefill lands in an equivalently-quantized cache)."""
    cfg, m, p = small_model
    sizes = (5, 11, 8, 14, 7)
    ref, _, _ = _serve(
        m, p, _reqs(cfg, sizes, max_new=6), batch_slots=2, max_len=48,
        kv_dtype="int8", weight_dtype="int8",
    )
    cl = ServeCluster(
        m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48,
        kv_dtype="int8", weight_dtype="int8",
    )
    arrivals = [(i * 0.002, r) for i, r in enumerate(_reqs(cfg, sizes, max_new=6))]
    stats = cl.run(arrivals=arrivals, reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1
    assert stats.kv_bytes_resident > 0


# ------------------------------------------------------- byte accounting


def test_paged_bytes_per_block_is_measured(small_model):
    """bytes_per_block comes from the actual pool leaves, never a
    slots*f32 assumption: f32 = L*2*bs*KV*hd*4; int8 adds the f32 scale
    rows but still lands ~3.2x lighter at hd=16."""
    cfg, m, p = small_model
    L, kv, hd, bs = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, 8
    _, _, e32 = _serve(
        m, p, _reqs(cfg, (5,)), batch_slots=2, max_len=32, kv_block_size=bs
    )
    assert e32.pool.bytes_per_block == L * 2 * bs * kv * hd * 4
    _, _, e8 = _serve(
        m, p, _reqs(cfg, (5,)), batch_slots=2, max_len=32, kv_block_size=bs,
        kv_dtype="int8",
    )
    assert e8.pool.bytes_per_block == L * 2 * (bs * kv * hd + bs * kv * 4)
    assert e8.pool.bytes_per_block * 3 < e32.pool.bytes_per_block


def test_kv_bytes_resident_reported(small_model):
    cfg, m, p = small_model
    sizes = (5, 11, 8)
    _, s32, e32 = _serve(m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32)
    _, s8, e8 = _serve(
        m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32, kv_dtype="int8"
    )
    # dense residency is the whole preallocated cache, dtype-aware
    assert s32.kv_bytes_resident == e32.kv_bytes_resident() > 0
    assert s8.kv_bytes_resident == e8.kv_bytes_resident() > 0
    assert s8.kv_bytes_resident * 3 < s32.kv_bytes_resident
    # paged residency peaks with pool occupancy and returns to 0 on drain
    _, sp, ep = _serve(
        m, p, _reqs(cfg, sizes), batch_slots=2, max_len=32, kv_block_size=8,
        kv_dtype="int8",
    )
    assert sp.kv_bytes_resident > 0
    assert sp.kv_bytes_resident % ep.pool.bytes_per_block == 0
    assert ep.pool.used == 0 and ep.kv_bytes_resident() == 0
    assert ep.pool.stats().kv_bytes_resident == 0


# ------------------------------------------------------- weight serving


def test_quantize_params_identity_and_structure(small_model):
    cfg, m, p = small_model
    assert quantize_params(p, None) is p
    assert quantize_params(p, "f32") is p
    qp = quantize_params(p, "int8")
    wq = qp["blocks"]["attn"]["wq"]
    assert is_quantized(wq) and wq["q8"].dtype == jnp.int8
    assert wq["scale"].dtype == jnp.float32
    # non-matmul leaves ride through untouched (same array objects; the
    # containers are rebuilt by the tree walk)
    for sub in ("embed", "final_norm"):
        assert all(
            a is b
            for a, b in zip(jax.tree.leaves(qp[sub]), jax.tree.leaves(p[sub]))
        ), sub
    # qweight read-through: dequant error bounded by half a step,
    # f32 leaves pass through unchanged
    w = np.asarray(p["blocks"]["attn"]["wq"])
    deq = np.asarray(qweight(wq))
    assert (np.abs(deq - w) <= np.asarray(wq["scale"]) / 2 + 1e-6).all()
    assert qweight(p["blocks"]["attn"]["wq"]) is p["blocks"]["attn"]["wq"]


def test_quantize_params_moe_router_stays_dense():
    cfg = get_arch("llama4-scout-17b-a16e").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    qp = quantize_params(p, "int8")
    moe = qp["moe_blocks"]["moe"]
    assert is_quantized(moe["w_in"]) and is_quantized(moe["w_out"])
    assert not is_quantized(moe["router"])  # tiny, accuracy-critical
    assert moe["router"].dtype == jnp.float32


def test_weight_int8_serves_and_shrinks(small_model):
    """int8 weight serving runs the full engine path, the quantized block
    stack is ~4x lighter, and teacher-forced argmax decisions above the
    noise floor agree >= 99% with fp32 weights (same margin-aware gate as
    the KV test — random-init margins are full of near-ties)."""
    from repro.common.utils import pytree_bytes

    cfg, m, p = small_model
    qp = quantize_params(p, "int8")
    assert pytree_bytes(qp["blocks"]) * 3 < pytree_bytes(p["blocks"])
    sizes = (5, 8, 11, 13)
    q8, _, _ = _serve(
        m, p, _reqs(cfg, sizes, max_new=8), batch_slots=2, max_len=32,
        weight_dtype="int8",
    )
    assert all(len(t) == 8 for t in q8.values())
    rng = np.random.default_rng(9)
    seq = jnp.asarray(rng.integers(0, cfg.vocab_size, size=32), jnp.int32)
    slot = jnp.zeros((32,), jnp.int32)
    pos = jnp.arange(32, dtype=jnp.int32)
    lf, _ = m.packed_step(p, m.init_cache(1, 32), seq, slot, pos)
    lq, _ = m.packed_step(qp, m.init_cache(1, 32), seq, slot, pos)
    lf, lq = np.asarray(lf), np.asarray(lq)
    srt = np.sort(lf, axis=-1)
    clear = (srt[:, -1] - srt[:, -2]) > 0.05  # weight quant noise > KV's
    same = lf.argmax(-1) == lq.argmax(-1)
    assert clear.sum() >= 16, int(clear.sum())
    assert same[clear].mean() >= 0.99, (
        f"weight-int8 argmax agreement {int(same[clear].sum())}/{int(clear.sum())}"
    )


# ------------------------------------------------------- fp8 storage lane


def test_fp8_row_roundtrip_tighter_than_int8():
    """float8_e4m3fn rows round-trip through quantize_rows/dequantize_rows
    with bounded relative error; near-zero rows survive (scale floors at
    1/448 like the int8 lane floors at 1/127)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(6, 32)) * 3.0, jnp.float32)
    q, s = quantize_rows(x, jnp.float8_e4m3fn)
    assert q.dtype == jnp.float8_e4m3fn and s.shape == (6,)
    back = dequantize_rows(q, s)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float(jnp.max(jnp.abs(back - x) / amax)) < 0.04  # e4m3: ~2^-3 rel
    z = jnp.zeros((2, 8), jnp.float32)
    qz, sz = quantize_rows(z, jnp.float8_e4m3fn)
    assert float(jnp.abs(dequantize_rows(qz, sz)).max()) == 0.0


def test_engine_kv_fp8_greedy_agreement(small_model):
    """kv_dtype='fp8' (float8_e4m3fn rows behind the same per-row scale
    machinery) serves full streams and its teacher-forced argmax decisions
    agree with fp32 above the same noise-floor gate as int8. The logit
    perturbation bound is LOOSER than int8's: e4m3's ~2^-4 relative step
    on large elements exceeds int8's uniform amax/254 step — fp8's win is
    dynamic range on small elements, not peak accuracy."""
    cfg, m, p = small_model
    sizes = (5, 8, 11, 13, 16, 19)
    base, _, eng = _serve(
        m, p, _reqs(cfg, sizes, max_new=10), batch_slots=4, max_len=48,
        kv_dtype="fp8",
    )
    assert eng.cache["k"].dtype == jnp.float8_e4m3fn
    assert "k_scale" in eng.cache
    assert all(len(t) == 10 for t in base.values())
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32) for s in sizes]
    total = agree = decided = decided_agree = 0
    max_err = 0.0
    for i, pr in enumerate(prompts):
        seq = jnp.asarray(np.concatenate([pr, np.asarray(base[i], np.int32)]))
        t = len(seq)
        slot = jnp.zeros((t,), jnp.int32)
        pos = jnp.arange(t, dtype=jnp.int32)
        rows = jnp.arange(len(pr) - 1, t - 1, dtype=jnp.int32)
        lf, _ = m.packed_step(p, m.init_cache(1, 64), seq, slot, pos, out_rows=rows)
        lq, _ = m.packed_step(
            p, m.init_cache(1, 64, kv_dtype=jnp.float8_e4m3fn), seq, slot,
            pos, out_rows=rows,
        )
        lf, lq = np.asarray(lf), np.asarray(lq)
        max_err = max(max_err, float(np.abs(lf - lq).max()))
        srt = np.sort(lf, axis=-1)
        margin = srt[:, -1] - srt[:, -2]
        same = lf.argmax(-1) == lq.argmax(-1)
        total += len(same)
        agree += int(same.sum())
        clear = margin > 0.03
        decided += int(clear.sum())
        decided_agree += int(same[clear].sum())
    assert max_err < 0.2, max_err  # e4m3 KV perturbs logits ~1e-1 here
    assert decided >= total // 2
    assert decided_agree / decided >= 0.99, (
        f"fp8 greedy agreement {decided_agree}/{decided} above the floor"
    )


def test_fp8_dtype_aliases_and_bytes(small_model):
    """Every fp8 alias normalizes to float8_e4m3fn, and the byte
    accounting sees 1-byte rows + f32 scales (same residency as int8)."""
    cfg, m, p = small_model
    aliases = ("f8", "fp8", "float8", "float8_e4m3", "float8_e4m3fn")
    engines = [
        ServeEngine(m, p, batch_slots=2, max_len=32, kv_dtype=a)
        for a in aliases
    ]
    assert all(e.cache["k"].dtype == jnp.float8_e4m3fn for e in engines)
    e8 = engines[0]
    ei = ServeEngine(m, p, batch_slots=2, max_len=32, kv_dtype="int8")
    ef = ServeEngine(m, p, batch_slots=2, max_len=32, kv_dtype="f32")
    assert e8.kv_bytes_resident() == ei.kv_bytes_resident()
    assert e8.kv_bytes_resident() < ef.kv_bytes_resident()
