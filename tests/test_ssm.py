"""SSM: chunked scans vs naive sequential reference; SSD vs quadratic form."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.ssm import _selective_scan_chunked, _ssd_chunked


def test_selective_scan_matches_sequential(rng):
    b, s, d, n = 2, 24, 6, 4
    x_c = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32) * 0.5
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, s, d)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (d, n)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    h0 = jnp.zeros((b, d, n), jnp.float32)

    y_chunk, h_chunk = _selective_scan_chunked(x_c, dt, A, Bm, C, h0, chunk=8)

    # naive sequential recurrence: h = exp(dt·A)h + dt·B·x
    dA = np.exp(np.asarray(dt)[..., None] * np.asarray(A))
    dBx = (
        np.asarray(dt)[..., None]
        * np.asarray(Bm)[:, :, None, :]
        * np.asarray(x_c)[..., None]
    )
    h = np.zeros((b, d, n), np.float32)
    ys = []
    for t in range(s):
        h = dA[:, t] * h + dBx[:, t]
        ys.append(np.einsum("bdn,bn->bd", h, np.asarray(C[:, t])))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_chunk), h, rtol=1e-4, atol=1e-5)


def test_ssd_chunked_matches_quadratic(rng):
    b, s, h, p, n = 1, 16, 2, 4, 3
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32) * 0.5
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, 1, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, 1, n)), jnp.float32)

    y, state = _ssd_chunked(x, dt, A, B, C, chunk=4)

    # quadratic reference: y[s] = Σ_{t<=s} (C_s·B_t) exp(Σ_{j in (t,s]} dt_j A) dt_t x_t
    l = np.asarray(dt) * np.asarray(A)  # [b,s,h]
    cum = np.cumsum(l, axis=1)
    y_ref = np.zeros((b, s, h, p), np.float32)
    for si in range(s):
        for t in range(si + 1):
            decay = np.exp(cum[:, si] - cum[:, t])  # [b,h]
            cb = np.einsum("bn,bn->b", np.asarray(C[:, si, 0]), np.asarray(B[:, t, 0]))
            w = cb[:, None] * decay * np.asarray(dt[:, t])  # [b,h]
            y_ref[:, si] += w[..., None] * np.asarray(x[:, t])
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", ["falcon-mamba-7b", "zamba2-2.7b"])
def test_chunk_boundary_invariance(name, rng):
    """Different chunk sizes must give identical full-sequence outputs."""
    import dataclasses

    from repro.models import LM

    cfg = get_arch(name).reduced()
    toks = jax.random.randint(jax.random.key(0), (1, 16), 0, cfg.vocab_size)
    outs = []
    for chunk in (4, 8, 16):
        c = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=chunk))
        m = LM(c)
        p = m.init(jax.random.key(1))
        lg, _ = jax.jit(m.forward)(p, {"tokens": toks})
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-4)
