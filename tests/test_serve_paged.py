"""Block-paged KV serving: the paged pool + block-table indirection must be
BIT-IDENTICAL to the dense per-slot cache for greedy streams — under
mid-stream admissions, cancellation, prefix reuse and pool pressure — and
the paged Pallas kernel must match the gather oracle. The dense engine is
the reference everywhere: paged mode is an opt-in memory layout, never a
numerics change."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.modes import Mode
from repro.kernels import ops
from repro.models import LM
from repro.serve import Request, SamplingParams, ServeCluster, ServeEngine

F32 = jnp.float32


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    return cfg, m, p


# ------------------------------------------------------------------ kernel


def _rand(rng, shape, dtype=F32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2)])
def test_paged_kernel_matches_oracle(h, kv):
    """Interpret-mode paged Pallas kernel vs the gather oracle on a mixed
    pack (decode singletons + a prefill chunk) over a fragmented pool."""
    rng = np.random.default_rng(0)
    nb, bs, d, maxb = 7, 8, 16, 3  # rows address up to 24 positions
    pool_k = _rand(rng, (nb, bs, kv, d))
    pool_v = _rand(rng, (nb, bs, kv, d))
    # two requests with deliberately scrambled, partial tables (sentinel nb
    # marks unallocated tail entries)
    btab = jnp.asarray([[4, 1, 6], [0, 5, nb]], jnp.int32)
    tok_seq = jnp.asarray([0, 1, 1, 1, 1], jnp.int32)
    tok_pos = jnp.asarray([20, 9, 10, 11, 12], jnp.int32)
    q = _rand(rng, (5, h, d))
    got = ops.paged_ragged_attention(
        q, pool_k, pool_v, tok_seq, tok_pos, btab, mode="interpret"
    )
    want = ops.paged_ragged_attention(
        q, pool_k, pool_v, tok_seq, tok_pos, btab, mode="ref"
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_paged_oracle_matches_dense_with_identity_tables():
    """An identity block table makes the pool a pure reshape of the dense
    cache: the paged oracle must agree with the dense ragged oracle."""
    rng = np.random.default_rng(1)
    b, s_max, h, kv, d, bs = 2, 24, 4, 2, 16, 8
    k = _rand(rng, (b, s_max, kv, d))
    v = _rand(rng, (b, s_max, kv, d))
    maxb = s_max // bs
    pool_k = k.reshape(b * maxb, bs, kv, d)
    pool_v = v.reshape(b * maxb, bs, kv, d)
    btab = jnp.arange(b * maxb, dtype=jnp.int32).reshape(b, maxb)
    tok_seq = jnp.asarray([0, 1, 1], jnp.int32)
    tok_pos = jnp.asarray([7, 13, 14], jnp.int32)
    q = _rand(rng, (3, h, d))
    got = ops.paged_ragged_attention(
        q, pool_k, pool_v, tok_seq, tok_pos, btab, mode="ref"
    )
    want = ops.ragged_attention(q, k, v, tok_seq, tok_pos, mode="ref")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


# ------------------------------------------------------------------ engine


def _prompts(cfg, sizes, seed=11):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, cfg.vocab_size, size=s).astype(np.int32) for s in sizes
    ]


def _serve(m, p, prompts, *, max_new=6, slots=2, max_len=64,
           prefill_budget=16, **kw):
    eng = ServeEngine(
        m, p, batch_slots=slots, max_len=max_len,
        prefill_budget=prefill_budget, **kw,
    )
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, params=SamplingParams(max_new=max_new)))
    eng.run()
    return eng, {r.rid: r.generated for r in eng.finished}


def test_paged_engine_bit_identical_to_dense(small_model):
    """More requests than slots (mid-stream admissions as slots churn), a
    prompt longer than the prefill budget (chunked feeding): every greedy
    stream must match the dense engine token-for-token."""
    cfg, m, p = small_model
    prompts = _prompts(cfg, (6, 13, 40, 9, 21))
    _, dense = _serve(m, p, prompts)
    eng, paged = _serve(m, p, prompts, kv_block_size=8)
    assert paged == dense
    # every block went back to the free list when its request finished
    assert eng.pool.free == eng.num_blocks


def test_paged_engine_cancellation_bit_identity(small_model):
    """Mid-stream cancellation frees the cancelled request's blocks and
    must not perturb any other stream (ISSUE acceptance: bit-identical
    under mid-stream admissions + cancellation)."""
    cfg, m, p = small_model
    prompts = _prompts(cfg, (6, 9, 13, 7), seed=23)
    _, dense = _serve(m, p, prompts, max_new=8)

    eng = ServeEngine(m, p, batch_slots=2, max_len=64, prefill_budget=16,
                      kv_block_size=8)
    handles = [
        eng.submit(Request(rid=i, prompt=pr, params=SamplingParams(max_new=8)))
        for i, pr in enumerate(prompts)
    ]
    it = iter(handles[0])
    next(it)
    next(it)  # requests 0/1 are mid-stream on the two slots
    handles[1].cancel()
    eng.run()
    got = {r.rid: r.generated for r in eng.finished}
    cancelled = [r for r in eng.finished if r.finish_reason == "cancelled"]
    assert [r.rid for r in cancelled] == [1]
    # the cancelled stream got a PREFIX of its uncancelled tokens; every
    # surviving stream is bit-identical to dense
    assert got[1] == dense[1][: len(got[1])]
    for rid in (0, 2, 3):
        assert got[rid] == dense[rid]
    assert eng.pool.free == eng.num_blocks  # cancel leaked nothing


def test_paged_prefix_reuse_identity_and_hits(small_model):
    """A shared system prompt: the radix tree must skip its full blocks on
    later admissions (hits recorded) while every stream stays identical to
    prefix-off serving."""
    cfg, m, p = small_model
    rng = np.random.default_rng(3)
    sys_p = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    prompts = [
        np.concatenate([sys_p, rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)])
        for _ in range(4)
    ]
    _, off = _serve(m, p, prompts, kv_block_size=8)
    eng, on = _serve(m, p, prompts, kv_block_size=8, prefix_cache=True)
    assert on == off
    st = eng.prefix.stats()
    assert st.hits >= 1 and st.hit_tokens >= 24  # >= one full shared prefix
    # the tree retains its nodes (resident for future admissions), each
    # holding exactly the tree's own reference
    assert eng.pool.used == st.nodes
    assert all(c in (0, 1) for c in eng.pool.refcount.tolist())


def test_paged_cow_boundary_divergence(small_model):
    """Prompts diverging MID-block share only the blocks before the
    divergence (block-aligned COW: no mid-block copy, no cross-talk) and
    still match prefix-off serving bit-for-bit."""
    cfg, m, p = small_model
    rng = np.random.default_rng(5)
    head = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)  # 2.5 blocks
    tails = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32) for _ in range(3)]
    prompts = [np.concatenate([head, t]) for t in tails]
    _, off = _serve(m, p, prompts, kv_block_size=8, slots=1)
    eng, on = _serve(m, p, prompts, kv_block_size=8, slots=1, prefix_cache=True)
    assert on == off
    st = eng.prefix.stats()
    # only the 2 FULL head blocks (16 tokens) are shareable; the half
    # block where streams diverge is recomputed privately per request
    assert st.hits == 2 and st.hit_tokens == 2 * 16


def test_paged_pool_exhaustion_admission_waits(small_model):
    """A pool too small for every request's worst case: admission makes
    the overflow requests WAIT (recorded as alloc pressure), everything
    still finishes, outputs identical, nothing leaks."""
    cfg, m, p = small_model
    prompts = _prompts(cfg, (24, 26, 25, 27), seed=9)
    _, dense = _serve(m, p, prompts, slots=4)
    # each request needs ceil((len+6)/8) = 4 blocks; 9 blocks admit at
    # most two residents despite 4 free slots
    eng, paged = _serve(m, p, prompts, slots=4, kv_block_size=8, num_blocks=9)
    assert paged == dense
    assert eng.pool.alloc_failures >= 1  # pressure was actually exercised
    assert eng.pool.free == eng.num_blocks


def test_paged_submit_infeasible_request_raises(small_model):
    cfg, m, p = small_model
    eng = ServeEngine(m, p, batch_slots=2, max_len=64, kv_block_size=8,
                      num_blocks=2)
    with pytest.raises(ValueError, match="KV blocks"):
        eng.submit(Request(rid=0, prompt=_prompts(cfg, (20,))[0],
                           params=SamplingParams(max_new=30)))


def test_paged_requires_unified_and_divisibility(small_model):
    cfg, m, p = small_model
    with pytest.raises(ValueError, match="multiple"):
        ServeEngine(m, p, max_len=60, kv_block_size=8)
    with pytest.raises(ValueError, match="kv_block_size"):
        ServeEngine(m, p, max_len=64, prefix_cache=True)


def test_paged_prewarm_then_serve(small_model):
    """prewarm() on a paged engine (all-sentinel tables: warmup dispatches
    drop every write) must leave serving bit-identical."""
    cfg, m, p = small_model
    prompts = _prompts(cfg, (6, 9), seed=31)
    _, dense = _serve(m, p, prompts, max_len=32, max_chunk=2)
    eng = ServeEngine(m, p, batch_slots=2, max_len=32, prefill_budget=16,
                      max_chunk=2, kv_block_size=8)
    eng.prewarm()
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, params=SamplingParams(max_new=6)))
    eng.run()
    assert {r.rid: r.generated for r in eng.finished} == dense


def test_paged_reset_roundtrip(small_model):
    """reset() returns a paged engine to full capacity (pool, prefix tree
    and block tables included) and reserving runs reproduce exactly."""
    cfg, m, p = small_model
    prompts = _prompts(cfg, (6, 9, 13), seed=17)
    eng = ServeEngine(m, p, batch_slots=2, max_len=64, prefill_budget=16,
                      kv_block_size=8, prefix_cache=True)
    def run():
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr,
                               params=SamplingParams(max_new=6)))
        eng.run()
        out = {r.rid: r.generated for r in eng.finished}
        return out
    first = run()
    eng.reset()
    assert eng.pool.free == eng.num_blocks
    assert eng.prefix.stats().nodes == 0
    assert run() == first


def test_paged_cluster_mid_stream_reconfigure(small_model):
    """A paged cluster surviving a mid-stream reconfigure: outputs stay
    bit-identical to a dense engine, and every fabric's pool ends
    refcount-consistent (blocks held only by each engine's prefix tree)."""
    cfg, m, p = small_model
    sizes = (5, 23, 11, 8, 17, 7)
    reqs = lambda: [  # noqa: E731 — fresh Request objects per consumer
        Request(rid=i, prompt=pr, params=SamplingParams(max_new=4))
        for i, pr in enumerate(_prompts(cfg, sizes, seed=21))
    ]
    ref_eng = ServeEngine(m, p, batch_slots=2, max_len=48)
    for r in reqs():
        ref_eng.submit(r)
    ref_eng.run()
    ref = {r.rid: r.generated for r in ref_eng.finished}

    cl = ServeCluster(m, p, mode=Mode.SPLIT, batch_slots=2, max_len=48,
                      kv_block_size=8, prefix_cache=True)
    arrivals = [(i * 0.002, r) for i, r in enumerate(reqs())]
    stats = cl.run(arrivals=arrivals,
                   reconfigure_schedule=[(0.005, Mode.MERGE)])
    assert {r.rid: r.generated for r in cl.finished} == ref
    assert len(stats.reconfigures) == 1
    for engines in cl._fabrics.values():
        for e in engines:
            st = e.prefix.stats()
            assert e.pool.used == st.nodes  # only tree refs remain
            assert all(c in (0, 1) for c in e.pool.refcount.tolist())
