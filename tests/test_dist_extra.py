"""`repro.dist.sharding` edge cases beyond the seed suite: batch/data_size
divisibility, optimizer-state specs mirroring their parameter, and the
single-device fallback path used by laptops and the fast CI lane."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    MeshInfo,
    _add_fsdp_dim,
    batch_shardings,
    opt_shardings,
    param_shardings,
    replicated,
    single_device_mesh_info,
    spec_for_batch,
    spec_for_param,
)

M = 16  # model-axis size for pure spec-level checks


def one_dev_info(batch_axes=("data",), **kw) -> MeshInfo:
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    return MeshInfo(mesh, batch_axes=batch_axes, **kw)


# ---------------------------------------------------------------------------
# batch divisibility
# ---------------------------------------------------------------------------


def test_odd_batch_replicates_instead_of_uneven_shards():
    # 7 % 4 != 0: fall back to replication rather than an invalid sharding
    assert spec_for_batch((7, 128), 4, ("data",)) == P()
    assert spec_for_batch((8, 128), 4, ("data",)) == P(("data",), None)
    # batch smaller than the DP degree also replicates
    assert spec_for_batch((2, 128), 4, ("data",)) == P()
    # scalars have no batch dim
    assert spec_for_batch((), 4, ("data",)) == P()
    # MERGE mode: the folded pod axis rides along in the batch axes
    assert spec_for_batch((8, 16), 8, ("pod", "data")) == P(("pod", "data"), None)


def test_batch_shardings_builder_on_live_mesh():
    info = one_dev_info()
    batch = {
        "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
    }
    sh = batch_shardings(batch, info)
    assert set(sh) == {"tokens", "labels"}
    assert all(s.mesh == info.mesh for s in sh.values())
    # a real array lands under it without error
    x = jax.device_put(jnp.zeros((8, 32), jnp.int32), sh["tokens"])
    assert x.shape == (8, 32)


# ---------------------------------------------------------------------------
# optimizer state mirrors parameters
# ---------------------------------------------------------------------------


def test_opt_shardings_mirror_param_specs():
    from repro.train.optimizer import adamw_init

    params = {
        "embed": {"tok": jax.ShapeDtypeStruct((32768, 4096), jnp.bfloat16)},
        "blocks": {
            "mlp": {"w_in": jax.ShapeDtypeStruct((32, 4096, 13440), jnp.bfloat16)}
        },
    }
    info = one_dev_info()
    p_sh = param_shardings(params, info)
    o_sh = opt_shardings(jax.eval_shape(lambda: adamw_init(params)), info)
    # moments carry exactly their parameter's sharding; step replicates
    assert o_sh.mu == p_sh
    assert o_sh.nu == p_sh
    assert o_sh.step == replicated(info)


def test_moe_block_attention_uses_attn_rule_not_expert_rule():
    """Attention params under `moe_blocks` must take the heads/head_dim rule;
    the expert-dim branch is only for the true `['moe']` expert stacks
    (regression: a bare "moe" substring match sharded d_model instead)."""
    spec = spec_for_param("['moe_blocks']['attn']['wq']", 4, (27, 2048, 16, 128), M)
    assert spec == P(None, None, "model", None)
    # GQA fallback still reachable for moe-family archs
    spec = spec_for_param("['moe_blocks']['attn']['wk']", 4, (27, 2048, 8, 128), M)
    assert spec == P(None, None, None, "model")


def test_spec_rules_are_prefix_invariant():
    """The path rules key on substrings, so a param nested under an optimizer
    prefix (keystr adds e.g. ``[1]`` for the NamedTuple slot) resolves to the
    same spec — this is what makes opt_shardings ≡ param_shardings."""
    for path, shape in [
        ("['blocks']['mlp']['w_in']", (32, 4096, 13440)),
        ("['blocks']['attn']['wk']", (88, 12288, 8, 128)),
        ("['moe_blocks']['moe']['w_in']", (27, 64, 2048, 1408)),
        ("['embed']['tok']", (73448, 2560)),
    ]:
        base = spec_for_param(path, len(shape), shape, M)
        nested = spec_for_param(f"[1]{path}", len(shape), shape, M)
        assert base == nested, path


# ---------------------------------------------------------------------------
# single-device fallback
# ---------------------------------------------------------------------------


def test_single_device_mesh_info_fallback():
    info = single_device_mesh_info()
    assert info.n_devices == 1
    assert info.data_size == 1 and info.model_size == 1
    assert info.batch_spec(3) == P(("data",), None, None)
    # every builder degrades to replication and still produces usable shardings
    params = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
    for sh in jax.tree.leaves(param_shardings(params, info)):
        assert sh == replicated(info)
    w = jax.device_put(jnp.ones((64, 64)), replicated(info))
    assert float(w.sum()) == 64 * 64


def test_fsdp_below_threshold_is_identity():
    info = one_dev_info()
    spec = P(None, None, "model")
    assert _add_fsdp_dim(spec, (4, 8, 16), info, 1, threshold=2**24) == spec
