"""Checkpointing: async roundtrip, retention, restore-into-structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import Checkpointer


def _state(key=0):
    k = jax.random.key(key)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros(16)},
        "step": jnp.int32(7),
        "nested": [jnp.ones((3,)), {"x": jnp.arange(5)}],
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = _state()
    ck.save(10, state, blocking=True)
    restored, step = ck.restore(jax.eval_shape(lambda: state))
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    state = _state()
    h = ck.save(1, state)  # non-blocking
    h.wait()
    assert ck.latest_step() == 1


def test_retention_policy(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s), blocking=True)
    assert ck.list_steps() == [3, 4]


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    for s in (5, 6):
        ck.save(s, _state(s), blocking=True)
    _, step = ck.restore(jax.eval_shape(lambda: _state()), step=5)
    assert step == 5


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(jax.eval_shape(lambda: _state()))
