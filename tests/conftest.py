"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the default single
device; multi-device behaviour is exercised via subprocess helpers
(tests/multidev/) so the dry-run's 512-device environment stays isolated."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
