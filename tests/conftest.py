"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the default single
device; multi-device behaviour is exercised via subprocess helpers
(tests/multidev/) so the dry-run's 512-device environment stays isolated."""

import os
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # pinned container lacks hypothesis: use the bundled stub
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
