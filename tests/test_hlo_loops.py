"""Loop-corrected HLO collective parser unit tests (synthetic modules)."""

from repro.roofline.hlo_loops import corrected_collectives
from repro.roofline.analysis import parse_collectives


SYNTH = """
%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(f32[64]{0} %v), to_apply=%add
  ROOT %t = tuple(...)
}

%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %c = s32[] constant(8)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main.1 (x: f32[64]) -> f32[64] {
  %ag = f32[128]{0} all-gather(f32[64]{0} %x), dimensions={0}
  %w = (s32[], f32[64]) while((s32[], f32[64]) %init), condition=%cond.1, body=%body.1
  ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
}
"""


def test_while_body_multiplied_by_trip_count():
    raw = parse_collectives(SYNTH)
    corr = corrected_collectives(SYNTH)
    assert raw["all-reduce"] == 64 * 4
    assert corr["all-reduce"] == 8 * 64 * 4  # ×trip count
    assert corr["all-gather"] == raw["all-gather"]  # entry-level unchanged


NESTED = """
%inner_body.2 (p: s32[]) -> s32[] {
  %ar2 = f32[16]{0} all-reduce(f32[16]{0} %v), to_apply=%add
  ROOT %x = s32[] add(...)
}

%inner_cond.2 (p: s32[]) -> pred[] {
  %c2 = s32[] constant(4)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c2), direction=LT
}

%outer_body.1 (p: s32[]) -> s32[] {
  %w2 = s32[] while(s32[] %q), condition=%inner_cond.2, body=%inner_body.2
  ROOT %y = s32[] add(...)
}

%outer_cond.1 (p: s32[]) -> pred[] {
  %c1 = s32[] constant(3)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c1), direction=LT
}

ENTRY %main.9 (x: f32[8]) -> f32[8] {
  %w1 = s32[] while(s32[] %init), condition=%outer_cond.1, body=%outer_body.1
  ROOT %r = f32[8]{0} copy(%x)
}
"""


def test_nested_while_multiplies():
    corr = corrected_collectives(NESTED)
    assert corr["all-reduce"] == 3 * 4 * 16 * 4  # outer×inner×bytes


def test_no_entry_falls_back_to_raw():
    frag = "%ar = f32[32]{0} all-reduce(f32[32]{0} %v), to_apply=%add"
    assert corrected_collectives(frag) == parse_collectives(frag)
