"""Per-arch smoke tests (deliverable f): every assigned architecture at a
REDUCED same-family config runs one forward + one train step on CPU with
correct shapes and finite outputs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, TrainConfig, get_arch
from repro.models import LM
from repro.train import adamw_init, make_train_step


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    cfg = get_arch(name).reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32

    if cfg.modality == "audio":
        batch = {
            "embeds": jax.random.normal(jax.random.key(1), (B, S, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size),
        }
    else:
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}

    # forward: shape + finiteness
    logits, aux = jax.jit(model.forward)(
        params, {k: v for k, v in batch.items() if k != "labels"}
    )
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), name
    assert bool(jnp.isfinite(aux)), name

    # one train step: loss finite, params updated, still finite
    tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), name
    assert bool(jnp.isfinite(metrics["grad_norm"])), name
    assert float(metrics["grad_norm"]) > 0.0, name
    # at least one leaf changed
    changed = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params),
    )
    assert changed, name
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf).all()), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_step(name):
    cfg = get_arch(name).reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    B = 2
    cache = model.init_cache(B, 16)
    if cfg.modality == "audio":
        batch = {"embeds": jnp.ones((B, 1, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, new_cache = jax.jit(model.decode_step)(params, cache, batch, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), name
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)
