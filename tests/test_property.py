"""Property-based tests (hypothesis) on system invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.coremark import coremark
from repro.dist.compression import dequantize, quantize
from repro.kernels import ref
from repro.models.attention import chunked_attention, dense_attention

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32), min_size=2, max_size=64),
)
def test_quantize_error_bound(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    q, scale = quantize(x)
    err = np.abs(np.asarray(dequantize(q, scale)) - np.asarray(x))
    # symmetric int8: |err| <= scale/2 = amax/254 per element
    amax = float(np.abs(np.asarray(x)).max())
    assert err.max() <= amax / 254.0 + 1e-6


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_sign(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(32).astype(np.float32))
    q, scale = quantize(x)
    d = np.asarray(dequantize(q, scale))
    big = np.abs(np.asarray(x)) > float(scale)  # below 1 LSB sign may vanish
    assert np.all(np.sign(d[big]) == np.sign(np.asarray(x)[big]))


def test_error_feedback_converges_to_uncompressed_mean():
    """EF-compressed running sum approaches the true sum: residual stays
    bounded instead of accumulating (the EF-SGD invariant)."""
    rng = np.random.default_rng(0)
    resid = np.zeros(16, np.float32)
    total_sent = np.zeros(16, np.float64)
    total_true = np.zeros(16, np.float64)
    for _ in range(200):
        g = rng.standard_normal(16).astype(np.float32)
        corrected = g + resid
        q, s = quantize(jnp.asarray(corrected))
        sent = np.asarray(dequantize(q, s))
        resid = corrected - sent
        total_sent += sent
        total_true += g
    # residual bounded by one quantization step of the last tensor
    assert np.abs(total_sent + resid - total_true).max() < 1e-3
    assert np.abs(resid).max() < 0.1


# ---------------------------------------------------------------------------
# FFT / softmax invariants
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64, 256]))
def test_fft_stockham_matches_numpy(seed, n):
    rng = np.random.default_rng(seed)
    re = jnp.asarray(rng.standard_normal((2, n)).astype(np.float32))
    im = jnp.asarray(rng.standard_normal((2, n)).astype(np.float32))
    sr, si = ref.fft_stockham(re, im)
    z = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=-1)
    scale = max(np.abs(z).max(), 1.0)
    assert np.abs(np.asarray(sr) - z.real).max() / scale < 1e-4
    assert np.abs(np.asarray(si) - z.imag).max() / scale < 1e-4


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.floats(-50, 50))
def test_softmax_shift_invariance(seed, shift):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, 16)).astype(np.float32))
    a = np.asarray(ref.softmax(x))
    b = np.asarray(ref.softmax(x + np.float32(shift)))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
def test_chunked_attention_chunk_invariance(seed, chunk):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 16, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 16, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 16, 2, 8)).astype(np.float32))
    d = np.asarray(dense_attention(q, k, v, causal=True))
    c = np.asarray(chunked_attention(q, k, v, causal=True, chunk=chunk))
    np.testing.assert_allclose(c, d, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# scalar workload determinism (scheduler correctness depends on it)
# ---------------------------------------------------------------------------


def test_coremark_deterministic():
    a = coremark(3, seed=42)
    b = coremark(3, seed=42)
    assert a.checksum == b.checksum
    c = coremark(3, seed=43)
    assert c.checksum != a.checksum
