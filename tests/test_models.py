"""Model-level correctness: decode==forward, prefill+decode==forward,
per-family behaviours (MLA absorbed decode, MoE aux, hybrid tying)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import LM

FAMS = [
    "codeqwen1.5-7b",  # MHA
    "qwen3-32b",  # GQA + qk_norm
    "minicpm3-4b",  # MLA (q_lora)
    "deepseek-v2-lite-16b",  # MoE + MLA + first_k_dense
    "falcon-mamba-7b",  # mamba1
    "zamba2-2.7b",  # hybrid mamba2 + shared attn
]


def _toks(cfg, b, s, key=2):
    return jax.random.randint(jax.random.key(key), (b, s), 0, cfg.vocab_size)


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_forward(name):
    cfg = get_arch(name).reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(1))
    B, S = 2, 8
    toks = _toks(cfg, B, S)
    full, _ = jax.jit(m.forward)(p, {"tokens": toks})
    cache = m.init_cache(B, S)
    step = jax.jit(m.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(p, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 2e-2


@pytest.mark.parametrize("name", FAMS)
def test_prefill_then_decode_matches_forward(name):
    cfg = get_arch(name).reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(1))
    B, S, EXTRA = 2, 6, 3
    toks = _toks(cfg, B, S + EXTRA)
    full, _ = jax.jit(m.forward)(p, {"tokens": toks})
    lg, cache = jax.jit(lambda pp, bb: m.prefill(pp, bb, S + EXTRA))(
        p, {"tokens": toks[:, :S]}
    )
    scale = float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(lg - full[:, :S]))) / scale < 2e-2
    step = jax.jit(m.decode_step)
    for t in range(S, S + EXTRA):
        lg1, cache = step(p, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t))
        err = float(jnp.max(jnp.abs(lg1[:, 0] - full[:, t]))) / scale
        assert err < 2e-2, (name, t, err)


def test_moe_aux_loss_positive():
    cfg = get_arch("deepseek-v2-lite-16b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    _, aux = jax.jit(m.forward)(p, {"tokens": _toks(cfg, 2, 16)})
    # Switch-style balance loss is ≥ 1 per layer at perfect balance
    n_moe = cfg.n_layers - cfg.first_k_dense
    assert float(aux) >= 0.9 * n_moe


def test_hybrid_shared_block_is_tied():
    cfg = get_arch("zamba2-2.7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    # exactly ONE attention block's worth of shared params
    assert "shared" in p
    assert p["shared"]["attn"]["wq"].ndim == 3  # not L-stacked


def test_audio_stub_embeds_path():
    cfg = get_arch("musicgen-large").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    x = jnp.ones((2, 12, cfg.d_model), jnp.float32)
    logits, _ = jax.jit(m.forward)(p, {"embeds": x})
    assert logits.shape == (2, 12, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_f8_kv_cache_decode_close():
    """float8 KV storage (serving memory optimization) stays within a few %
    of the bf16-cache logits."""
    import dataclasses

    cfg = dataclasses.replace(
        get_arch("qwen3-32b").reduced(), kv_cache_dtype="float8_e4m3fn"
    )
    m = LM(cfg)
    p = m.init(jax.random.key(1))
    B, S = 2, 8
    toks = _toks(cfg, B, S)
    full, _ = jax.jit(m.forward)(p, {"tokens": toks})
    cache = m.init_cache(B, S)
    assert jax.tree.leaves(cache)[0].dtype == jnp.float8_e4m3fn
    step = jax.jit(m.decode_step)
    errs = []
    for t in range(S):
        lg, cache = step(p, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) / float(jnp.max(jnp.abs(full))) < 8e-2


def test_param_specs_match_init():
    cfg = get_arch("qwen3-32b").reduced()
    m = LM(cfg)
    specs = m.param_specs()
    params = m.init(jax.random.key(0))
    s_flat, s_def = jax.tree_util.tree_flatten(specs)
    p_flat, p_def = jax.tree_util.tree_flatten(params)
    assert s_def == p_def
    for s, pp in zip(s_flat, p_flat):
        assert s.shape == pp.shape and s.dtype == pp.dtype
