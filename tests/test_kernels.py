"""Per-kernel interpret-mode sweeps vs the jnp oracle (deliverable c).

Every Pallas kernel × a grid of shapes × dtypes, executed with
``interpret=True`` (kernel body runs on CPU) and compared against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

F32 = np.float32
BF16 = jnp.bfloat16


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == BF16 else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (64, 96, 32), (100, 70, 130), (128, 128, 128)])
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_matmul(rng, m, k, n, dtype):
    a, b = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    out = ops.matmul(a, b, mode="interpret", block=32)
    expect = ref.matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(out, F32), np.asarray(expect, F32), **_tol(dtype)
    )


@pytest.mark.parametrize("shape", [(3, 500), (1, 64), (8, 1024)])
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_axpy(rng, shape, dtype):
    x, y = _rand(rng, shape, dtype), _rand(rng, shape, dtype)
    out = ops.axpy(2.5, x, y, mode="interpret", block=128)
    np.testing.assert_allclose(
        np.asarray(out, F32), np.asarray(ref.axpy(2.5, x, y), F32), **_tol(dtype)
    )


@pytest.mark.parametrize("n", [64, 1000, 4096])
def test_dotp(rng, n):
    x, y = _rand(rng, (n,), F32), _rand(rng, (n,), F32)
    got = float(ops.dotp(x, y, mode="interpret", block=256))
    expect = float(ref.dotp(x, y))
    assert abs(got - expect) / (abs(expect) + 1e-6) < 1e-4


@pytest.mark.parametrize("r,c", [(16, 64), (37, 128), (128, 512)])
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_softmax(rng, r, c, dtype):
    x = _rand(rng, (r, c), dtype)
    out = ops.softmax(x, mode="interpret", block_rows=16)
    np.testing.assert_allclose(
        np.asarray(out, F32), np.asarray(ref.softmax(x), F32), **_tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(out, F32).sum(-1), 1.0, rtol=2e-2)


@pytest.mark.parametrize("r,c", [(16, 64), (40, 256)])
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_rmsnorm(rng, r, c, dtype):
    x = _rand(rng, (r, c), dtype)
    w = _rand(rng, (c,), dtype)
    out = ops.rmsnorm(x, w, mode="interpret", block_rows=8)
    np.testing.assert_allclose(
        np.asarray(out, F32), np.asarray(ref.rmsnorm(x, w), F32), **_tol(dtype)
    )


@pytest.mark.parametrize("b,n", [(4, 64), (2, 256), (6, 1024)])
def test_fft(rng, b, n):
    re, im = _rand(rng, (b, n), F32), _rand(rng, (b, n), F32)
    kr, ki = ops.fft(re, im, mode="interpret", block_rows=2)
    fr, fi = ref.fft(re, im)
    scale = float(np.abs(np.asarray(fr)).max())
    assert np.abs(np.asarray(kr) - np.asarray(fr)).max() / scale < 1e-5
    assert np.abs(np.asarray(ki) - np.asarray(fi)).max() / scale < 1e-5


def test_fft_stockham_reference_matches_numpy(rng):
    re, im = _rand(rng, (3, 128), F32), _rand(rng, (3, 128), F32)
    sr, si = ref.fft_stockham(re, im)
    z = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=-1)
    np.testing.assert_allclose(np.asarray(sr), z.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(si), z.imag, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("b,h,w,c,o,kh", [(2, 12, 10, 8, 16, 3), (1, 8, 8, 4, 4, 1)])
def test_conv2d(rng, b, h, w, c, o, kh):
    x = _rand(rng, (b, h, w, c), F32)
    wgt = _rand(rng, (kh, kh, c, o), F32)
    out = ops.conv2d(x, wgt, mode="interpret", block_h=2)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.conv2d(x, wgt)), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("s,blk", [(64, 32), (128, 64), (96, 32)])
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_flash_attention(rng, s, blk, dtype):
    b, h, hd = 2, 3, 16
    q = _rand(rng, (b, h, s, hd), dtype)
    k = _rand(rng, (b, h, s, hd), dtype)
    v = _rand(rng, (b, h, s, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, mode="interpret", block=blk)
    expect = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, F32), np.asarray(expect, F32), **_tol(dtype)
    )


def test_flash_attention_noncausal(rng):
    b, h, s, hd = 1, 2, 64, 16
    q = _rand(rng, (b, h, s, hd), F32)
    k = _rand(rng, (b, h, s, hd), F32)
    v = _rand(rng, (b, h, s, hd), F32)
    out = ops.flash_attention(q, k, v, causal=False, mode="interpret", block=32)
    expect = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4)
