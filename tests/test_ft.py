"""Fault tolerance: watchdog classification; elastic restart logic."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft import Watchdog


def test_watchdog_straggler_and_dead():
    events = []
    wd = Watchdog(
        straggler_after=0.15,
        dead_after=0.4,
        on_straggler=lambda n, s: events.append(("straggler", n)),
        on_dead=lambda n, s: events.append(("dead", n)),
        poll=0.02,
    ).start()
    wd.register("fast")
    wd.register("slow")
    t0 = time.monotonic()
    while time.monotonic() - t0 < 0.6:
        wd.beat("fast")
        time.sleep(0.05)
    wd.stop()
    assert wd.status("fast") == "ok"
    assert wd.status("slow") == "dead"
    kinds = [k for k, n in events if n == "slow"]
    assert "straggler" in kinds and "dead" in kinds
    assert not any(n == "fast" for _, n in events)


def test_watchdog_stale_seconds():
    wd = Watchdog(straggler_after=10.0, dead_after=20.0, poll=0.01).start()
    wd.register("lane")
    time.sleep(0.15)
    stale = wd.stale_seconds("lane")
    assert 0.1 <= stale < 5.0
    wd.beat("lane")
    assert wd.stale_seconds("lane") < stale
    wd.stop()


def test_watchdog_injected_thread_stall_escalates():
    """A worker thread that stalls mid-loop walks ok -> straggler -> dead
    while a healthy peer stays ok — the exact supervision contract the
    serving cluster's split-mode replica threads rely on (there the dead
    lane's requests re-home; see tests/test_serve_cluster.py)."""
    import threading

    events = []
    wd = Watchdog(
        straggler_after=0.1,
        dead_after=0.25,
        on_straggler=lambda n, s: events.append(("straggler", n)),
        on_dead=lambda n, s: events.append(("dead", n)),
        poll=0.01,
    ).start()
    stop = threading.Event()

    def worker(lane, stall_at):
        wd.register(lane)
        for tick in range(200):
            if stop.is_set():
                return
            wd.beat(lane, step=tick)
            if tick == stall_at:
                time.sleep(0.5)  # injected stall: no beats while "hung"
            time.sleep(0.005)

    threads = [
        threading.Thread(target=worker, args=("replica0", -1)),
        threading.Thread(target=worker, args=("replica1", 10)),
    ]
    for t in threads:
        t.start()
    t0 = time.monotonic()
    # snapshot().get, not status(): the lanes register inside the worker
    # threads, and on a loaded host this loop can poll before they've run
    while wd.snapshot().get("replica1") != "dead" and time.monotonic() - t0 < 2.0:
        time.sleep(0.01)
    # snapshot BEFORE teardown: once beating stops, healthy lanes go stale too
    final = wd.snapshot()
    seen = list(events)
    stop.set()
    for t in threads:
        t.join()
    wd.stop()
    assert final["replica0"] == "ok"
    assert final["replica1"] == "dead"  # dead lanes need explicit revive
    kinds = [k for k, n in seen if n == "replica1"]
    assert kinds.index("straggler") < kinds.index("dead")
    assert not any(n == "replica0" for _, n in seen)


def test_watchdog_revive():
    wd = Watchdog(straggler_after=0.05, dead_after=0.1, poll=0.01).start()
    wd.register("lane")
    time.sleep(0.25)
    assert wd.status("lane") == "dead"
    wd.revive("lane")
    assert wd.status("lane") == "ok"
    wd.stop()


def test_elastic_single_device_restart(tmp_path):
    """Elastic loop on 1 device: inject a failure, restore from ckpt, finish.

    (The multi-pod shrink path runs in tests/multidev via subprocess.)"""
    from repro.ckpt import Checkpointer
    from repro.core import SpatzformerCluster
    from repro.ft import run_elastic

    cluster = SpatzformerCluster(n_pods=1, pod_shape=(1, 1))
    ck = Checkpointer(str(tmp_path), keep=3)

    def make_state(info):
        return {"w": jnp.zeros((4,)), "n": jnp.int32(0)}

    def step_factory(info):
        @jax.jit
        def step(state, batch, step_idx):
            return {"w": state["w"] + batch["x"], "n": state["n"] + 1}

        return lambda state, batch, i: step(state, batch, i)

    batches = lambda i: {"x": jnp.full((4,), float(i))}

    # pod 0 "fails" at step 7 -> with n_pods=1 there is no survivor; use a
    # 2-pod cluster shape on the same device? Not possible with 1 device, so
    # test the restart/restore path by failing and surviving to pod 0 itself.
    state, report = run_elastic(
        cluster,
        make_state,
        step_factory,
        batches,
        ck,
        total_steps=12,
        ckpt_every=5,
        fail_at={},
    )
    assert report.steps_done == 12
    assert int(state["n"]) == 12
    # expected accumulated value: sum of 0..11
    np.testing.assert_allclose(np.asarray(state["w"]), float(sum(range(12))))
