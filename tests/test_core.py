"""Spatzformer core on a single device: mode bookkeeping, scheduler paths,
perf model claims. (True multi-pod behaviour runs in test_multidev.py.)"""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Mode,
    MixedScheduler,
    ScalarTask,
    SpatzformerCluster,
    VectorTask,
    coremark,
    switch_mode,
)
from repro.core.perfmodel import (
    KernelCost,
    model_mixed_merge,
    model_mixed_split,
    model_staged_merge,
    model_staged_split,
)


def test_cluster_single_pod_views():
    cl = SpatzformerCluster(n_pods=1, pod_shape=(1, 1))
    assert cl.n_devices == 1
    info = cl.pod_info(0)
    assert info.model_size == 1 and info.data_size == 1


def test_scheduler_merge_overlaps_scalar():
    cl = SpatzformerCluster(n_pods=1, pod_shape=(1, 1))
    sched = MixedScheduler(cl)

    def vec(info):
        time.sleep(0.05)
        return 1

    vts = [VectorTask(f"v{i}", vec) for i in range(3)]
    sts = [ScalarTask("cm", lambda: coremark(1).checksum)]
    rep = sched.run(Mode.MERGE, vts, sts)
    kinds = {r.kind for r in rep.records}
    assert kinds == {"vector", "scalar"}
    lanes = {r.lane for r in rep.records}
    assert any("freed" in l for l in lanes)
    # scalar work started before all vector work finished (overlap happened)
    v_end = max(r.end for r in rep.records if r.kind == "vector")
    s_start = min(r.start for r in rep.records if r.kind == "scalar")
    assert s_start < v_end


def test_switch_mode_preserves_values():
    cl = SpatzformerCluster(n_pods=1, pod_shape=(1, 1))
    state = {"w": jnp.arange(12.0).reshape(3, 4)}
    out, rep = switch_mode(cl, Mode.MERGE, state)
    assert cl.mode is Mode.MERGE
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
    assert rep.bytes_moved == 12 * 4
    out2, _ = switch_mode(cl, Mode.SPLIT, out)
    np.testing.assert_array_equal(np.asarray(out2["w"]), np.asarray(state["w"]))


# ---------------------------------------------------------------------------
# perf-model checks of the paper's claims (C1/C2 structure)
# ---------------------------------------------------------------------------


def test_perfmodel_mixed_workload_speedup_matches_paper_band():
    """Vector-dominated mixed workload: MM/SM speedup approaches 2× (paper:
    avg 1.8×, up to ~2×)."""
    kernels = [
        KernelCost("matmul", flops=500e12, hbm_bytes=800e9) for _ in range(8)
    ]
    scalar_s = 0.02  # CoreMark-ish; vector-dominated regime
    sm = model_mixed_split(kernels, scalar_s, chips_per_pod=256)
    mm = model_mixed_merge(kernels, scalar_s, total_chips=512)
    speedup = sm.makespan / mm.makespan
    assert 1.6 <= speedup <= 2.05, speedup


def test_perfmodel_mixed_workload_scalar_dominated_no_gain():
    kernels = [KernelCost("tiny", flops=1e9, hbm_bytes=1e6)]
    sm = model_mixed_split(kernels, 1.0, chips_per_pod=256)
    mm = model_mixed_merge(kernels, 1.0, total_chips=512)
    assert sm.makespan == pytest.approx(1.0, rel=1e-3)
    assert mm.makespan == pytest.approx(1.0, rel=1e-3)


def test_perfmodel_sync_bound_kernel_merge_wins():
    """Fine-grained sync (many rounds): merged single-program execution beats
    split host-synchronized execution — overlap + amortized dispatch (the
    paper's FFT +20% story); the gap grows with sync frequency."""
    phase = KernelCost("fft_phase", flops=0.5e12, hbm_bytes=2e9)
    xbytes = 512e6

    def gap(rounds):
        sm = model_staged_split(phase, rounds, xbytes, chips_per_pod=256)
        mm = model_staged_merge(phase, rounds, xbytes, total_chips=512)
        return sm.makespan / mm.makespan

    assert gap(1) > 1.1
    assert gap(8) > gap(1)
    # single launch in merge mode; 2 phase + 2 exchange launches per pod per
    # round in split mode
    mm = model_staged_merge(phase, 4, xbytes, total_chips=512)
    assert mm.launches == 1
    sm = model_staged_split(phase, 4, xbytes, chips_per_pod=256)
    assert sm.launches == 4 * (2 + 2) * 2
    # PCIe-staged worst case moves bytes through the hosts
    sm_pcie = model_staged_split(
        phase, 4, xbytes, chips_per_pod=256, exchange_over="pcie"
    )
    assert sm_pcie.host_exchange_bytes > 0 and mm.host_exchange_bytes == 0
    assert sm_pcie.makespan > sm.makespan


def test_perfmodel_energy_merge_saves_dispatch():
    phase = KernelCost("k", flops=1e12, hbm_bytes=1e9)
    sm = model_staged_split(phase, 8, 1e6, chips_per_pod=256)
    mm = model_staged_merge(phase, 8, 1e6, total_chips=512)
    assert mm.energy_j < sm.energy_j  # launch/fetch energy amortized (paper §III)
