"""Training integration: convergence, grad-accum equivalence, schedules,
compressed-DP step (1-device mesh exercises the shard_map path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_arch
from repro.data import DataConfig, SyntheticCorpus
from repro.models import LM
from repro.train import (
    adamw_init,
    make_compressed_dp_train_step,
    make_train_step,
    warmup_cosine,
)


def test_loss_decreases_codeqwen_reduced():
    cfg = get_arch("codeqwen1.5-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    tcfg = TrainConfig(lr=3e-3, warmup_steps=3, total_steps=40)
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    corpus = SyntheticCorpus(DataConfig(cfg.vocab_size, 64, 8, seed=1))
    losses = []
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, corpus.batch(i))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_grad_accum_equivalence():
    """grad_accum=2 must match a single full-batch step (same tokens)."""
    cfg = get_arch("codeqwen1.5-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    corpus = SyntheticCorpus(DataConfig(cfg.vocab_size, 32, 8, seed=2))
    batch = jax.tree.map(jnp.asarray, corpus.batch(0))

    outs = []
    for accum in (1, 2):
        tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10, grad_accum=accum)
        step = jax.jit(make_train_step(model, tcfg))
        opt = adamw_init(params)
        new_params, _, m = step(params, opt, batch)
        outs.append((new_params, float(m["loss"])))
    (p1, l1), (p2, l2) = outs
    assert abs(l1 - l2) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-4
        )


def test_warmup_cosine_schedule():
    tcfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    sched = warmup_cosine(tcfg)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.int32(5))) == pytest.approx(5e-4)
    assert float(sched(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-3)
    # monotone decay after warmup
    assert float(sched(jnp.int32(50))) > float(sched(jnp.int32(90)))


def test_compressed_dp_step_runs_and_learns():
    from jax.sharding import Mesh
    import numpy as onp

    cfg = get_arch("codeqwen1.5-7b").reduced()
    model = LM(cfg)
    params = jax.tree.map(
        lambda x: x.astype(jnp.float32), model.init(jax.random.key(0))
    )
    mesh = Mesh(onp.array(jax.devices()[:1]).reshape(1), ("data",))
    tcfg = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    step = make_compressed_dp_train_step(model, tcfg, mesh)
    opt = adamw_init(params)
    ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    corpus = SyntheticCorpus(DataConfig(cfg.vocab_size, 32, 4, seed=3))
    losses = []
    for i in range(15):
        batch = jax.tree.map(jnp.asarray, corpus.batch(i))
        params, opt, ef, m = step(params, opt, ef, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


def test_data_pipeline_determinism_and_restart():
    from repro.data import PrefetchLoader

    corpus = SyntheticCorpus(DataConfig(1000, 16, 4, seed=9))
    b0 = corpus.batch(5)
    b1 = corpus.batch(5)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])

    loader = PrefetchLoader(corpus, start_step=0)
    first = [next(loader)["tokens"] for _ in range(3)]
    loader.close()
    # restart from step 1 reproduces batches 1,2
    loader2 = PrefetchLoader(corpus, start_step=1)
    second = [next(loader2)["tokens"] for _ in range(2)]
    loader2.close()
    np.testing.assert_array_equal(first[1], second[0])
    np.testing.assert_array_equal(first[2], second[1])
