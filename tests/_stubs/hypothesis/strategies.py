"""Strategies for the hypothesis stub: floats, integers, lists, sampled_from.

Each strategy draws from the shared RNG; the first few examples per run are
boundary-biased (min/max/zero) so the cheap-but-important edges always get
exercised even with few examples.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import numpy as np


class SearchStrategy:
    def __init__(
        self,
        draw: Callable[[np.random.Generator], Any],
        corners: Sequence[Any] = (),
    ):
        self._draw = draw
        self._corners = list(corners)

    def example(self, rng: np.random.Generator, index: int = 0) -> Any:
        if index < len(self._corners):
            return self._corners[index]
        return self._draw(rng)


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    *,
    allow_nan: bool = True,
    allow_infinity: bool = True,
    width: int = 64,
) -> SearchStrategy:
    del allow_nan, allow_infinity  # bounded draws are always finite here

    def cast(v: float) -> float:
        return float(np.float32(v)) if width == 32 else float(v)

    corners = [cast(v) for v in (min_value, max_value) if min_value <= v <= max_value]
    if min_value <= 0.0 <= max_value:
        corners.append(0.0)
    return SearchStrategy(
        lambda rng: cast(rng.uniform(min_value, max_value)), corners=corners
    )


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        corners=[min_value, max_value],
    )


def lists(
    elements: SearchStrategy, *, min_size: int = 0, max_size: int = 10
) -> SearchStrategy:
    def draw(rng: np.random.Generator) -> list:
        size = int(rng.integers(min_size, max_size + 1))
        # ~1 in 8 elements comes from the element strategy's corner pool
        return [
            elements.example(rng, index=0 if rng.random() < 0.125 else 1 << 30)
            for _ in range(size)
        ]

    return SearchStrategy(draw)


def sampled_from(options: Sequence[Any]) -> SearchStrategy:
    options = list(options)
    return SearchStrategy(
        lambda rng: options[int(rng.integers(0, len(options)))],
        corners=options[: min(len(options), 2)],
    )
