"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface this
repo uses. Activated by ``tests/conftest.py`` ONLY when the real package is
absent (the pinned container doesn't ship it; CI pip-installs the real one).

Scope: ``@given`` over positional strategies, ``@settings(max_examples=...,
deadline=...)``, and the four strategies in :mod:`.strategies`. Examples are
drawn from a fixed-seed RNG (deterministic, no shrinking) with a sprinkle of
boundary values — a smoke-grade approximation, not a replacement.
"""

from __future__ import annotations

import functools
import inspect

import numpy as np

from . import strategies  # noqa: F401  (re-export: `from hypothesis import strategies`)

__version__ = "0.0-repro-stub"

_SEED = 20260727


def settings(**kwargs):
    """Records max_examples on the (already ``@given``-wrapped) test."""

    def deco(fn):
        fn._stub_settings = dict(kwargs)
        return fn

    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_stub_settings", {})
            n = int(conf.get("max_examples", 20))
            rng = np.random.default_rng(_SEED)
            for i in range(n):
                drawn = [s.example(rng, index=i) for s in strats]
                kw = {k: s.example(rng, index=i) for k, s in kw_strats.items()}
                fn(*args, *drawn, **kwargs, **kw)

        # hide the strategy-filled parameters from pytest's fixture
        # resolution (it inspects __wrapped__ otherwise)
        del wrapper.__wrapped__
        params = list(inspect.signature(fn).parameters.values())
        keep = params[: len(params) - len(strats)]
        keep = [p for p in keep if p.name not in kw_strats]
        wrapper.__signature__ = inspect.Signature(keep)
        return wrapper

    return deco
