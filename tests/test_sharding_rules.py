"""Sharding-rule unit tests, including the L-dim regression that once cost
6×7 GB of involuntary all-gathers (caught in the dry-run artifact)."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import _add_fsdp_dim, spec_for_param
from repro.roofline.analysis import RooflineTerms, parse_collectives

M = 16  # model-axis size


def test_stacked_dense_mlp_never_shards_layer_dim():
    # [L, d, f] — the regression: -3 must NOT hit dim 0
    spec = spec_for_param("['blocks']['mlp']['w_in']", 3, (32, 4096, 13440), M)
    assert spec == P(None, None, "model")
    spec = spec_for_param("['blocks']['mlp']['w_out']", 3, (32, 13440, 4096), M)
    assert spec == P(None, "model", None)


def test_moe_experts_shard_expert_dim():
    spec = spec_for_param("['moe_blocks']['moe']['w_in']", 4, (27, 64, 2048, 1408), M)
    assert spec == P(None, "model", None, None)
    # shared expert inside the moe subtree is a plain MLP
    spec = spec_for_param(
        "['moe_blocks']['moe']['shared']['w_in']", 3, (27, 2048, 2816), M
    )
    assert spec == P(None, None, "model")


def test_gqa_kv_divisibility_fallback():
    # kv=8 < 16 -> falls through to head_dim 128
    spec = spec_for_param("['blocks']['attn']['wk']", 4, (88, 12288, 8, 128), M)
    assert spec == P(None, None, None, "model")
    # kv=32 divisible -> heads dim
    spec = spec_for_param("['blocks']['attn']['wk']", 4, (32, 4096, 32, 128), M)
    assert spec == P(None, None, "model", None)


def test_model_size_one_replicates():
    spec = spec_for_param("['blocks']['mlp']['w_in']", 3, (32, 4096, 13440), 1)
    assert spec == P()


def test_vocab_fallback_to_dmodel():
    # minicpm3: 73448 % 16 != 0 -> shard d_model instead
    spec = spec_for_param("['embed']['tok']", 2, (73448, 2560), M)
    assert spec == P(None, "model")
    spec = spec_for_param("['embed']['tok']", 2, (32768, 4096), M)
    assert spec == P("model", None)


def test_fsdp_adds_data_dim_above_threshold():
    from repro.dist.sharding import MeshInfo
    from jax.sharding import Mesh
    import jax as _jax

    mesh = Mesh(np.array(_jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    info = MeshInfo(mesh)
    # big leaf (pretend data axis of size 1 divides everything): dim 0 (L)
    # must be skipped, another dim picked
    spec = _add_fsdp_dim(P(None, None, "model"), (88, 12288, 28672), info, 1, 2)
    assert spec[0] is None
    assert spec[1] in ("data", ("data",))  # P may normalize 1-tuples


# ---------------------------------------------------------------------------
# roofline unit tests
# ---------------------------------------------------------------------------


def test_parse_collectives_synthetic():
    hlo = """
  %ag = f32[128,256]{1,0} all-gather(f32[8,256]{1,0} %x), dimensions={0}
  %ar.1 = bf16[1024]{0} all-reduce(bf16[1024]{0} %y), to_apply=%add
  %done = f32[4]{0} all-reduce-done(f32[4]{0} %start)
  %unrelated = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"] == 128 * 256 * 4
    assert out["all-reduce"] == 1024 * 2  # the -done half is not re-counted


def test_roofline_terms_math():
    t = RooflineTerms(
        name="x", chips=256, flops=256 * 197e12, hbm_bytes=0.0, coll_bytes=0.0,
        model_flops=128 * 197e12,
    )
    assert t.t_compute == pytest.approx(1.0)
    assert t.bottleneck == "compute"
    assert t.mfu == pytest.approx(0.5)
    assert t.usefulness == pytest.approx(0.5)
    t2 = RooflineTerms(
        name="y", chips=2, flops=0.0, hbm_bytes=2 * 819e9, coll_bytes=2 * 50e9 * 2,
    )
    assert t2.bottleneck == "collective"
    assert t2.step_time == pytest.approx(2.0)


def test_analytic_counts_sane():
    from repro.configs import get_arch, get_shape
    from repro.roofline.flops import count_cell

    cfg = get_arch("mistral-large-123b")
    c = count_cell(cfg, get_shape("train_4k"), dp=16, tp=16)
    # train flops must be 3-5x of 2*N*D (bwd + remat)
    base = 2 * cfg.num_params() * 4096 * 256
    assert 3 * base < c.flops < 5 * base
    assert c.model_flops == pytest.approx(3 * base)
    # decode flops per step ~ 2*N*B
    d = count_cell(cfg, get_shape("decode_32k"), dp=16, tp=16)
    assert d.flops > 2 * cfg.num_params() * 128  # plus attention context
    assert d.flops < 6 * cfg.num_params() * 128


def test_serve_cache_shardings_never_shard_slot_or_seq():
    """Serving cache placement: positional caches (attention K/V, MLA
    latent/rope) shard only PAST the sequence axis — KV heads first,
    head_dim/rank fallback; slot (dim 1) and sequence (dim 2) stay whole
    (the engine scatters rows at arbitrary (slot, pos) every tick).
    Recurrent SSM leaves take their widest trailing dim."""
    import jax

    from repro.dist.sharding import serve_cache_shardings

    class FakeInfo:
        model_size = 2

        def named(self, spec):
            return spec

    f32 = np.float32
    cache = {
        "k": jax.ShapeDtypeStruct((2, 4, 96, 2, 16), f32),  # KV heads divide
        "v": jax.ShapeDtypeStruct((2, 4, 96, 1, 16), f32),  # GQA fallback: hd
        "ckv": jax.ShapeDtypeStruct((2, 4, 96, 32), f32),  # MLA latent: rank
        "krope": jax.ShapeDtypeStruct((2, 4, 96, 8), f32),
        "attn_k": jax.ShapeDtypeStruct((1, 4, 96, 2, 16), f32),  # hybrid pool
        "mamba": {"conv": jax.ShapeDtypeStruct((2, 4, 3, 64), f32)},  # widest
    }
    specs = serve_cache_shardings(cache, FakeInfo())
    assert specs["k"] == P(None, None, None, "model", None)
    assert specs["v"] == P(None, None, None, None, "model")
    # the MLA regression: dim 2 is SEQUENCE — only the rank dim may shard
    assert specs["ckv"] == P(None, None, None, "model")
    assert specs["krope"] == P(None, None, None, "model")
    assert specs["attn_k"] == P(None, None, None, "model", None)
    assert specs["mamba"]["conv"] == P(None, None, None, "model")
