"""Layer-level unit tests: norms, rope, mlp."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    apply_rope,
    mlp_apply,
    mlp_init,
    rms_norm,
)


def test_rms_norm_matches_manual(rng):
    x = jnp.asarray(rng.standard_normal((4, 8, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(32), jnp.float32)
    got = rms_norm(x, w, eps=1e-6)
    expect = x / np.sqrt((np.asarray(x) ** 2).mean(-1, keepdims=True) + 1e-6) * np.asarray(w)
    np.testing.assert_allclose(np.asarray(got), expect, rtol=1e-5, atol=1e-5)


def test_rope_preserves_norm(rng):
    x = jnp.asarray(rng.standard_normal((2, 6, 4, 16)), jnp.float32)
    pos = jnp.arange(6)[None]
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_property(rng):
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    d = 16
    q = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)

    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 10000.0)
        kj = apply_rope(k, jnp.array([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
    assert abs(dot_at(10, 2) - dot_at(18, 10)) < 1e-4


def test_mlp_shapes_and_finite(rng):
    p = mlp_init(jax.random.key(0), 16, 64, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 5, 16)), jnp.float32)
    y = mlp_apply(p, x)
    assert y.shape == (2, 5, 16)
    assert bool(jnp.isfinite(y).all())
