"""MoE routing: EP-shaped path vs dense oracle, capacity accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.moe import _capacity, _moe_shard, moe_init, moe_reference_dense


def _setup(cf=8.0, tokens=64):
    cfg = get_arch("deepseek-v2-lite-16b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))
    params = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (tokens, cfg.d_model), jnp.float32)
    return cfg, params, x


def test_local_path_matches_dense_oracle_high_capacity(rng):
    cfg, params, x = _setup(cf=64.0)  # capacity >= tokens: no drops
    out, aux = _moe_shard(
        x, params["router"], params["w_in"], params["w_gate"], params["w_out"], cfg, None
    )
    ref = moe_reference_dense(params, cfg, x[None])[0]
    if "shared" in params:
        from repro.models.layers import mlp_apply

        ref = ref - mlp_apply(params["shared"], x[None])[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_capacity_drops_bounded():
    cfg, params, x = _setup(cf=0.5, tokens=256)  # forced drops
    out, _ = _moe_shard(
        x, params["router"], params["w_in"], params["w_gate"], params["w_out"], cfg, None
    )
    # dropped tokens produce zero expert output; count rows that are exactly 0
    zero_rows = int(jnp.sum(jnp.all(out == 0.0, axis=-1)))
    c = _capacity(256, cfg)
    assert c < 256 * cfg.moe.top_k / cfg.moe.n_routed * 2
    assert zero_rows < 256  # not everything dropped


def test_decode_small_batch_no_drops():
    cfg, params, _ = _setup(cf=1.0)
    x = jax.random.normal(jax.random.key(2), (8, cfg.d_model), jnp.float32)
    out, _ = _moe_shard(
        x, params["router"], params["w_in"], params["w_gate"], params["w_out"], cfg, None
    )
    ref = moe_reference_dense(params, cfg, x[None])[0]
    if "shared" in params:
        from repro.models.layers import mlp_apply

        ref = ref - mlp_apply(params["shared"], x[None])[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_router_mass_conservation():
    cfg, params, x = _setup(cf=64.0)
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, _ = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(top_p.sum(-1)), 1.0, rtol=1e-5)
