"""Config registry + parameter accounting sanity."""

import pytest

from repro.configs import (
    ARCHS,
    SHAPES,
    all_cells,
    applicable,
    get_arch,
    get_shape,
)

EXPECTED_PARAMS = {  # name -> (label_count, tolerance)
    "mistral-large-123b": (123e9, 0.05),
    "qwen3-32b": (32.8e9, 0.10),
    "codeqwen1.5-7b": (7.25e9, 0.15),
    "minicpm3-4b": (4.0e9, 0.15),
    "musicgen-large": (3.3e9, 0.15),
    "deepseek-v2-lite-16b": (15.7e9, 0.10),
    "llama4-scout-17b-a16e": (109e9, 0.10),
    "zamba2-2.7b": (2.7e9, 0.20),
    "falcon-mamba-7b": (7.3e9, 0.10),
    "chameleon-34b": (34e9, 0.10),
}


def test_registry_complete():
    assert len(ARCHS) == 10
    assert set(EXPECTED_PARAMS) == set(ARCHS)


@pytest.mark.parametrize("name", sorted(EXPECTED_PARAMS))
def test_param_counts(name):
    target, tol = EXPECTED_PARAMS[name]
    got = get_arch(name).num_params()
    assert abs(got - target) / target < tol, (name, got, target)


def test_active_params_moe():
    ds = get_arch("deepseek-v2-lite-16b")
    assert ds.num_active_params() < 0.25 * ds.num_params()
    l4 = get_arch("llama4-scout-17b-a16e")
    assert abs(l4.num_active_params() - 17.2e9) / 17.2e9 < 0.1


def test_cells_and_applicability():
    cells = all_cells()
    # 10 archs × 3 shapes + 2 long_500k (ssm + hybrid)
    assert len(cells) == 32
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"falcon-mamba-7b", "zamba2-2.7b"}
    for a in ARCHS.values():
        assert applicable(a, SHAPES["train_4k"])


def test_reduced_configs_families_preserved():
    for name, cfg in ARCHS.items():
        red = cfg.reduced()
        assert red.family == cfg.family
        assert (red.mla is None) == (cfg.mla is None)
        assert (red.moe is None) == (cfg.moe is None)
        assert (red.ssm is None) == (cfg.ssm is None)
        assert red.num_params() < 10e6, name


def test_shapes():
    assert get_shape("train_4k").tokens_per_step == 4096 * 256
    assert get_shape("decode_32k").tokens_per_step == 128
    assert get_shape("long_500k").seq_len == 524288
