"""Block pool allocator and radix prefix tree: pure-host unit tests (no
model, no device work) for the invariants the paged serving path leans on
— refcounted sharing, exhaustion behavior, LRU leaf eviction, and the
block-aligned match/insert contract."""

import numpy as np
import pytest

from repro.serve import BlockPool, RadixPrefixCache, blocks_for

# ---------------------------------------------------------------- blocks_for


def test_blocks_for_worst_case_rounding():
    # total = min(prompt + max_new, max_len), rounded up to whole blocks
    assert blocks_for(1, 1, 64, 8) == 1
    assert blocks_for(8, 0, 64, 8) == 1
    assert blocks_for(8, 1, 64, 8) == 2
    assert blocks_for(30, 6, 64, 8) == 5  # 36 tokens -> 5 blocks
    assert blocks_for(60, 100, 64, 8) == 8  # capped by max_len


# ---------------------------------------------------------------- BlockPool


def test_pool_alloc_free_roundtrip():
    p = BlockPool(8, 4)
    a = p.alloc(3)
    assert len(a) == 3 and len(set(a)) == 3
    assert p.used == 3 and p.free == 5
    assert all(p.refcount[b] == 1 for b in a)
    p.release_all(a)
    assert p.used == 0 and p.free == 8
    assert all(p.refcount[b] == 0 for b in a)


def test_pool_alloc_exhaustion_raises():
    p = BlockPool(4, 4)
    p.alloc(4)
    assert not p.can_alloc(1)
    with pytest.raises(RuntimeError):
        p.alloc(1)
    # a failed alloc must not leak partial allocations
    assert p.free == 0 and p.used == 4


def test_pool_refcount_sharing():
    p = BlockPool(4, 4)
    (b,) = p.alloc(1)
    p.acquire(b)  # a second holder (e.g. the prefix tree)
    assert p.refcount[b] == 2
    p.release(b)
    assert p.refcount[b] == 1 and p.used == 1  # still held
    p.release(b)
    assert p.refcount[b] == 0 and p.free == 4  # last ref frees
    with pytest.raises(AssertionError):
        p.release(b)  # double-free is a bug, not a no-op


def test_pool_reset_and_stats():
    p = BlockPool(6, 8)
    p.alloc(5)
    p.reset()
    s = p.stats()
    assert s.free_blocks == 6 and s.used_blocks == 0
    assert p.alloc(6)  # full capacity available again


# ---------------------------------------------------------- RadixPrefixCache


def test_radix_match_is_block_aligned_and_acquires():
    pool = BlockPool(16, 4)
    tree = RadixPrefixCache(pool, 4)
    prompt = np.arange(10, dtype=np.int32)  # blocks [0:4], [4:8]; tail 8:10
    table = pool.alloc(3)
    tree.insert(prompt, table)
    # the tree took its own reference on each full-block node
    assert all(pool.refcount[b] == 2 for b in table[:2])
    assert pool.refcount[table[2]] == 1  # tail block: not a tree node

    shared, matched = tree.match(prompt)
    assert matched == 8 and shared == table[:2]
    # match() acquires immediately — an evict between match and admission
    # can never free these
    assert all(pool.refcount[b] == 3 for b in table[:2])


def test_radix_match_caps_below_full_prompt():
    """A prompt consisting ENTIRELY of cached blocks still leaves >= 1
    token unfed (the engine must feed something to sample from)."""
    pool = BlockPool(16, 4)
    tree = RadixPrefixCache(pool, 4)
    prompt = np.arange(8, dtype=np.int32)  # exactly 2 blocks
    table = pool.alloc(2)
    tree.insert(prompt, table)
    shared, matched = tree.match(prompt)
    assert matched == 4 and len(shared) == 1  # capped at (8-1)//4 = 1 block


def test_radix_divergence_matches_common_blocks_only():
    pool = BlockPool(16, 4)
    tree = RadixPrefixCache(pool, 4)
    a = np.concatenate([np.arange(8), [90, 91]]).astype(np.int32)
    ta = pool.alloc(3)
    tree.insert(a, ta)
    # same first block, diverges inside the second
    b = np.concatenate([np.arange(4), [50, 51, 52, 53], [92]]).astype(np.int32)
    shared, matched = tree.match(b)
    assert matched == 4 and shared == ta[:1]
    pool.release_all(shared)


def test_radix_lru_evict_frees_leaves_only():
    pool = BlockPool(4, 4)
    tree = RadixPrefixCache(pool, 4)
    prompt = np.arange(12, dtype=np.int32)
    table = pool.alloc(3)
    tree.insert(prompt, table)
    pool.release_all(table)  # request finished; only the tree holds refs
    assert pool.free == 1  # 3 nodes resident
    # evicting one block must take the LEAF (deepest node), not the root
    assert tree.evict(1) == 1
    assert pool.refcount[table[2]] == 0
    assert pool.refcount[table[0]] == 1
    # eviction repeats as parents become leaves
    assert tree.evict(2) == 2
    assert pool.free == 4 and tree.stats().nodes == 0


def test_radix_evict_skips_in_use_blocks():
    pool = BlockPool(4, 4)
    tree = RadixPrefixCache(pool, 4)
    prompt = np.arange(8, dtype=np.int32)
    table = pool.alloc(2)
    tree.insert(prompt, table)  # refcount 2 on both (slot + tree)
    # a resident request still holds its refs: nothing is evictable
    assert tree.evict(2) == 0
    assert pool.used == 2
    pool.release_all(table)
    assert tree.evict(2) == 2  # now they go


def test_radix_lru_order():
    pool = BlockPool(8, 4)
    tree = RadixPrefixCache(pool, 4)
    # length 5: one full (matchable) block plus the never-matched last token
    a = np.arange(5, dtype=np.int32)
    b = np.arange(50, 55, dtype=np.int32)
    ta, tb = pool.alloc(1), pool.alloc(1)
    tree.insert(a, ta)
    tree.insert(b, tb)
    pool.release_all(ta + tb)
    shared, matched = tree.match(a)  # touch a: b becomes least-recent
    assert matched == 4 and shared == ta
    pool.release_all(shared)  # drop match()'s reference again
    assert tree.evict(1) == 1
    assert pool.refcount[tb[0]] == 0  # b evicted
    assert pool.refcount[ta[0]] == 1  # a survives


def test_radix_insert_is_idempotent_and_keeps_first_blocks():
    """Two requests racing the same cold prefix: the first insert wins,
    the second request's duplicate blocks stay private to it (released
    when it finishes) — the tree never double-acquires."""
    pool = BlockPool(8, 4)
    tree = RadixPrefixCache(pool, 4)
    prompt = np.arange(8, dtype=np.int32)
    t1, t2 = pool.alloc(2), pool.alloc(2)
    tree.insert(prompt, t1)
    tree.insert(prompt, t2)  # same keys: no new nodes, no refs taken
    assert tree.stats().nodes == 2
    assert all(pool.refcount[b] == 2 for b in t1)
    assert all(pool.refcount[b] == 1 for b in t2)
    pool.release_all(t1 + t2)
    assert pool.used == 2  # only the tree's copies remain


def test_radix_clear_releases_everything():
    pool = BlockPool(8, 4)
    tree = RadixPrefixCache(pool, 4)
    prompt = np.arange(12, dtype=np.int32)
    table = pool.alloc(3)
    tree.insert(prompt, table)
    pool.release_all(table)
    tree.clear()
    assert pool.free == 8 and tree.stats().nodes == 0
