"""Decode-attention parity: the batched GQA decode kernel (interpret mode)
and the grouped oracle vs per-slot dense_attention, including per-slot
cur_len, sliding window, and qk-norm through attention_decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.attention import (
    attention_decode,
    attention_init,
    dense_attention,
)
from repro.models.layers import apply_rope, rms_norm


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# Kernel-level parity: ops.decode_attention (interpret) vs dense_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("kv_heads", [2, 6])
def test_decode_kernel_vs_dense_oracle(rng, window, kv_heads):
    b, h, hd, s_max = 3, 6, 16, 40
    cur_len = np.array([0, 7, 33], np.int32)  # per-slot ragged lengths
    q = _rand(rng, (b, h, hd))
    k = _rand(rng, (b, s_max, kv_heads, hd))
    v = _rand(rng, (b, s_max, kv_heads, hd))

    got = ops.decode_attention(
        q, k, v, jnp.asarray(cur_len), window=window, mode="interpret", block_s=16
    )
    got_ref = ops.decode_attention(
        q, k, v, jnp.asarray(cur_len), window=window, mode="ref"
    )

    # oracle: per slot, one query at absolute position cur_len against the
    # first cur_len+1 cache entries (dense_attention is GQA-native)
    for i in range(b):
        cur = int(cur_len[i])
        o = dense_attention(
            q[i][None, None],          # [1, 1, H, hd]
            k[i, : cur + 1][None],     # [1, cur+1, KV, hd]
            v[i, : cur + 1][None],
            causal=True,
            q_offset=cur,
            window=window,
        )[0, 0]
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(o), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(got_ref[i]), np.asarray(o), rtol=2e-4, atol=2e-4
        )


def test_decode_kernel_low_precision_cache(rng):
    """f8/bf16 cache storage: kernel upcasts to the query dtype."""
    b, h, kv, hd, s_max = 2, 4, 2, 16, 32
    cur = jnp.asarray([5, 17], jnp.int32)
    q = _rand(rng, (b, h, hd))
    k = _rand(rng, (b, s_max, kv, hd)).astype(jnp.bfloat16)
    v = _rand(rng, (b, s_max, kv, hd)).astype(jnp.bfloat16)
    got = ops.decode_attention(q, k, v, cur, mode="interpret", block_s=16)
    expect = ops.decode_attention(q, k, v, cur, mode="ref")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(expect, np.float32),
        rtol=2e-2, atol=2e-2,
    )


# ---------------------------------------------------------------------------
# Layer-level parity: attention_decode vs an independently-built oracle,
# with qk-norm and sliding window enabled
# ---------------------------------------------------------------------------


def _tiny_cfg(**kw):
    base = dict(
        name="tiny", family="dense", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
        norm_eps=1e-5, rope_theta=10000.0,
    )
    base.update(kw)
    return ArchConfig(**base)


@pytest.mark.parametrize("qk_norm,window", [(False, 0), (True, 0), (True, 6)])
def test_attention_decode_vs_manual_oracle(rng, qk_norm, window):
    cfg = _tiny_cfg(qk_norm=qk_norm, sliding_window=window)
    params = attention_init(jax.random.key(0), cfg, jnp.float32)
    b, s_max = 2, 24
    cur_len = np.array([4, 15], np.int32)
    # pre-existing cache contents (as if prefilled)
    cache_k = _rand(rng, (b, s_max, cfg.n_kv_heads, cfg.head_dim))
    cache_v = _rand(rng, (b, s_max, cfg.n_kv_heads, cfg.head_dim))
    x = _rand(rng, (b, 1, cfg.d_model))

    out, new_k, new_v = attention_decode(
        params, cfg, x, cache_k, cache_v, jnp.asarray(cur_len)
    )

    # independent oracle: project, qk-norm, rope at the absolute position,
    # then per-slot dense attention over the updated cache prefix
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    if qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k_new = rms_norm(k_new, params["k_norm"], cfg.norm_eps)
    pos = jnp.asarray(cur_len)[:, None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k_new = apply_rope(k_new, pos, cfg.rope_theta)

    for i in range(b):
        cur = int(cur_len[i])
        # the new token's k/v must have landed at index cur
        np.testing.assert_allclose(
            np.asarray(new_k[i, cur]), np.asarray(k_new[i, 0]), rtol=1e-5, atol=1e-6
        )
        ki = np.array(cache_k[i])  # writable copy
        ki[cur] = np.asarray(k_new[i, 0])
        o = dense_attention(
            q[i][None],
            jnp.asarray(ki[: cur + 1])[None],
            new_v[i, : cur + 1][None],
            causal=True,
            q_offset=cur,
            window=window,
        )
        expect = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
        np.testing.assert_allclose(
            np.asarray(out[i]), np.asarray(expect[0]), rtol=2e-4, atol=2e-4
        )


def test_attention_decode_scalar_cur_len_matches_vector(rng):
    cfg = _tiny_cfg()
    params = attention_init(jax.random.key(1), cfg, jnp.float32)
    b, s_max = 2, 16
    cache_k = _rand(rng, (b, s_max, cfg.n_kv_heads, cfg.head_dim))
    cache_v = _rand(rng, (b, s_max, cfg.n_kv_heads, cfg.head_dim))
    x = _rand(rng, (b, 1, cfg.d_model))
    o1, k1, v1 = attention_decode(params, cfg, x, cache_k, cache_v, jnp.int32(5))
    o2, k2, v2 = attention_decode(
        params, cfg, x, cache_k, cache_v, jnp.asarray([5, 5], jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5, atol=1e-6)


def test_scatter_step_writes_single_row(rng):
    from repro.models.attention import _scatter_step

    cache = jnp.zeros((3, 10, 2, 4), jnp.float32)
    new = _rand(rng, (3, 1, 2, 4))
    cur = jnp.asarray([0, 4, 9], jnp.int32)
    out = _scatter_step(cache, new, cur)
    for i, c in enumerate([0, 4, 9]):
        np.testing.assert_allclose(np.asarray(out[i, c]), np.asarray(new[i, 0]))
        rest = np.delete(np.asarray(out[i]), c, axis=0)
        assert np.all(rest == 0)


def test_dataclass_replace_configs_still_frozen():
    cfg = _tiny_cfg()
    cfg2 = dataclasses.replace(cfg, sliding_window=4)
    assert cfg2.sliding_window == 4 and cfg.sliding_window == 0
