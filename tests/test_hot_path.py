"""Hot-path jaxpr inspection: the ops dispatch layer must not materialize
``jnp.pad`` copies (tail handling lives in the kernels), and the GQA
attention paths must not materialize the H//KV-fold K/V expansion."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import attention as attn_mod


def _top_level_primitives(fn, *args):
    """Primitive names of the traced fn's TOP-LEVEL jaxpr equations — the
    dispatch layer itself, not the Pallas kernel bodies."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return [eqn.primitive.name for eqn in jaxpr.jaxpr.eqns]


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (100, 70, 130)])
def test_matmul_dispatch_issues_no_pad(m, k, n):
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    prims = _top_level_primitives(
        lambda x, y: ops.matmul(x, y, mode="interpret", block=32), a, b
    )
    assert "pad" not in prims, prims


@pytest.mark.parametrize("s", [64, 96, 100])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_dispatch_issues_no_pad(s, causal):
    q = jnp.zeros((2, 2, s, 16), jnp.float32)
    prims = _top_level_primitives(
        lambda x: ops.flash_attention(
            x, x, x, causal=causal, mode="interpret", block=32
        ),
        q,
    )
    assert "pad" not in prims, prims


def test_softmax_rmsnorm_axpy_dotp_dispatch_no_pad():
    x = jnp.zeros((37, 130), jnp.float32)  # ragged both dims
    w = jnp.zeros((130,), jnp.float32)
    v = jnp.zeros((5000,), jnp.float32)
    for fn, args in [
        (lambda a: ops.softmax(a, mode="interpret", block_rows=16), (x,)),
        (lambda a, b: ops.rmsnorm(a, b, mode="interpret", block_rows=16), (x, w)),
        (lambda a: ops.axpy(2.0, a, a, mode="interpret", block=256), (x,)),
        (lambda a: ops.dotp(a, a, mode="interpret", block=256), (v,)),
    ]:
        prims = _top_level_primitives(fn, *args)
        assert "pad" not in prims, prims


@pytest.mark.parametrize("h", [10, 13])  # divisible and ragged H_out
def test_conv2d_dispatch_issues_no_pad(h):
    x = jnp.zeros((2, h, 9, 4), jnp.float32)
    w = jnp.zeros((3, 3, 4, 8), jnp.float32)
    prims = _top_level_primitives(
        lambda a, b: ops.conv2d(a, b, mode="interpret", block_h=4), x, w
    )
    assert "pad" not in prims, prims


@pytest.mark.parametrize("h", [10, 13])
def test_conv2d_masked_grid_matches_ref(h):
    """The shifted-tail-tile grid must stay exact on ragged H (the bug the
    old padded wrapper worked around)."""
    import numpy as np

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, h, 9, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 8)), jnp.float32)
    got = ops.conv2d(x, w, mode="interpret", block_h=4)
    import numpy.testing as npt

    npt.assert_allclose(
        np.asarray(got), np.asarray(ops.conv2d(x, w, mode="ref")),
        rtol=2e-4, atol=2e-4,
    )


def test_ragged_attention_dispatch_no_pad():
    q = jnp.zeros((10, 6, 16), jnp.float32)  # ragged T
    k = jnp.zeros((3, 40, 2, 16), jnp.float32)  # ragged S_max
    slots = jnp.zeros((10,), jnp.int32)
    poss = jnp.zeros((10,), jnp.int32)
    prims = _top_level_primitives(
        lambda a, b, c, d: ops.ragged_attention(
            a, b, b, c, d, mode="interpret", block_s=16
        ),
        q, k, slots, poss,
    )
    assert "pad" not in prims, prims


def test_decode_attention_dispatch_no_pad():
    q = jnp.zeros((3, 6, 16), jnp.float32)
    k = jnp.zeros((3, 40, 2, 16), jnp.float32)
    cur = jnp.zeros((3,), jnp.int32)
    prims = _top_level_primitives(
        lambda a, b, c: ops.decode_attention(
            a, b, b, c, mode="interpret", block_s=16
        ),
        q, k, cur,
    )
    assert "pad" not in prims, prims


@pytest.mark.parametrize("n", [2048, 2500, 700])  # whole, ragged, tail-only
def test_compression_quantize_dequantize_no_pad(n):
    """The int8 compressor jits into serving ticks and the compressed-DP
    train step: the body + tail split must never materialize a jnp.pad
    copy of the gradient/cache tensor."""
    from repro.dist import compression as comp

    x = jnp.zeros((n,), jnp.float32)
    prims = _top_level_primitives(lambda a: comp.quantize(a)[0], x)
    assert "pad" not in prims, prims
    q, s = comp.quantize(x)
    prims = _top_level_primitives(lambda a: comp.dequantize(a, s), q)
    assert "pad" not in prims, prims


def test_quantize_rows_no_pad_and_identity_lane():
    """Insert-time KV row quantization: pad-free in both lanes, and the
    f32 store lane is the exact identity (values untouched, ones scales)."""
    from repro.dist import compression as comp

    x = jnp.zeros((7, 3, 16), jnp.float32)
    for dt in (jnp.int8, jnp.float32):
        prims = _top_level_primitives(
            lambda a: comp.quantize_rows(a, dt)[0], x
        )
        assert "pad" not in prims, prims
    v, s = comp.quantize_rows(x, jnp.float32)
    assert v is x and s.shape == (7, 3)


def _gqa_cfg():
    return ArchConfig(
        name="tiny", family="dense", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
    )


def test_attention_apply_never_calls_repeat_kv(monkeypatch):
    cfg = _gqa_cfg()
    params = attn_mod.attention_init(jax.random.key(0), cfg, jnp.float32)

    def boom(x, groups):
        raise AssertionError("_repeat_kv materialized in attention_apply")

    monkeypatch.setattr(attn_mod, "_repeat_kv", boom)
    x = jnp.zeros((1, 8, cfg.d_model), jnp.float32)
    out = attn_mod.attention_apply(params, cfg, x, jnp.arange(8, dtype=jnp.int32))
    assert out.shape == (1, 8, cfg.d_model)


def test_attention_decode_never_calls_repeat_kv(monkeypatch):
    cfg = _gqa_cfg()
    params = attn_mod.attention_init(jax.random.key(0), cfg, jnp.float32)

    def boom(x, groups):
        raise AssertionError("_repeat_kv materialized in attention_decode")

    monkeypatch.setattr(attn_mod, "_repeat_kv", boom)
    x = jnp.zeros((2, 1, cfg.d_model), jnp.float32)
    ck = jnp.zeros((2, 16, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    out, _, _ = attn_mod.attention_decode(
        params, cfg, x, ck, ck, jnp.asarray([3, 5], jnp.int32)
    )
    assert out.shape == (2, 1, cfg.d_model)


def test_gqa_flash_no_head_expansion_in_jaxpr():
    """No top-level intermediate may carry an H-headed K/V: every broadcast
    to [*, H(=4)-headed, S, d] K/V layout would show up as a broadcast eqn
    whose output has 4 on the head axis with S=33 alongside."""
    b, h, kv, s, d = 1, 4, 2, 33, 16
    q = jnp.zeros((b, h, s, d), jnp.float32)
    k = jnp.zeros((b, kv, s, d), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a, c: ops.gqa_flash_attention(a, c, c, mode="interpret", block_q=16, block_k=16)
    )(q, k)
    expanded_kv_shape = (b * h, s, d)  # what a repeat would produce
    for eqn in jaxpr.jaxpr.eqns:
        if eqn.primitive.name in ("broadcast_in_dim", "concatenate"):
            for out in eqn.outvars:
                assert tuple(out.aval.shape) != expanded_kv_shape, eqn


def _all_primitives(jaxpr):
    """Primitive names of EVERY equation, recursing into sub-jaxprs
    (scan/cond/pjit bodies) — unlike _top_level_primitives, which stops at
    the dispatch layer."""
    names = set()
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            names.add(eqn.primitive.name)
        stack.extend(sj for sj in jax.core.subjaxprs(j))
    return names


def test_greedy_spec_verify_program_is_threefry_and_sort_free():
    """The smode-0 (all-greedy) speculative verify program must be argmax
    prefix agreement only: no threefry PRNG, no sort anywhere in the
    traced program — greedy speculation costs exactly the packed model
    step. The sampled variant (smode 1) is the positive control."""
    from functools import partial

    import numpy as np

    from repro.configs import get_arch
    from repro.models import LM
    from repro.serve import ServeEngine

    cfg = get_arch("codeqwen1.5-7b").reduced()
    m = LM(cfg)
    p = m.init(jax.random.key(0))
    eng = ServeEngine(m, p, batch_slots=2, max_len=32, speculate="ngram")
    pack = np.zeros((3, eng.B * 3 + eng.B), np.int32)  # desc cols + meta cols
    pack[2, : eng.B * 3] = eng.max_len
    spf, spi, btok, bval = eng._sp0
    args = (
        eng.params, eng.cache, eng._last_tok, eng._cur_len,
        jnp.asarray(pack), spf, spi, btok, bval,
    )
    def _prng(n):  # typed-key primitives trace as random_*; raw as threefry*
        return "threefry" in n or n.startswith("random_")

    greedy = _all_primitives(
        jax.make_jaxpr(partial(eng._spec_fn, depth_k=2, smode=0))(*args).jaxpr
    )
    assert not any(_prng(n) for n in greedy), sorted(greedy)
    assert "sort" not in greedy, sorted(greedy)
    sampled = _all_primitives(
        jax.make_jaxpr(partial(eng._spec_fn, depth_k=2, smode=1))(*args).jaxpr
    )
    assert any(_prng(n) for n in sampled), sorted(sampled)
