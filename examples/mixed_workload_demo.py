"""THE PAPER'S EXPERIMENT, live: mixed scalar-vector workloads under
split vs merge mode, on however many devices this process sees.

Run with multiple host devices to see both pods exist for real:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/mixed_workload_demo.py

NOTE on numbers: this container has ONE physical core, so wall-clock
split/merge ratios here demonstrate the MECHANISM (real threads, real
dispatch, real barriers), while the v5e performance model in
benchmarks/mixed_workload.py carries the quantitative claim (1.8×).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Mode,
    MixedScheduler,
    ScalarTask,
    SpatzformerCluster,
    VectorTask,
    coremark,
    fft2d_kernel,
    run_merged,
    run_split_staged,
    switch_mode,
)


def make_vector_task(i: int):
    def fn(info):
        sh = info.named(info.batch_spec(2))
        a = jax.device_put(
            np.random.default_rng(i).standard_normal((1024, 512)).astype(np.float32), sh
        )
        f = jax.jit(lambda m: jax.nn.relu(m @ m.T).sum(), in_shardings=sh)
        return float(jax.block_until_ready(f(a)))

    return VectorTask(f"gemm{i}", fn)


def main() -> None:
    n = len(jax.devices())
    pods = 2 if n >= 2 and n % 2 == 0 else 1
    cluster = SpatzformerCluster(n_pods=pods)
    print(cluster)
    sched = MixedScheduler(cluster)

    vts = [make_vector_task(i) for i in range(6)]
    sts = [ScalarTask("coremark", lambda: coremark(4).checksum)]

    rep_split = sched.run(Mode.SPLIT, vts, sts)
    rep_merge = sched.run(Mode.MERGE, vts, sts)
    print("--- SPLIT ---");  print(rep_split.summary())
    print("--- MERGE ---");  print(rep_merge.summary())
    print(f"makespan split/merge = {rep_split.makespan/rep_merge.makespan:.2f}x "
          "(≈1 expected on this 1-core container; see benchmarks for the v5e model)")

    # runtime reconfiguration with live state
    state = {"w": jnp.ones((256, 256))}
    state, swr = switch_mode(cluster, Mode.MERGE, state)
    print(f"mode switch: {swr.from_desc}->{swr.to_desc} in {swr.seconds*1e3:.2f} ms")

    if pods == 2:
        # the sync-bound two-phase kernel, merged vs split-staged
        x = (np.random.randn(256, 256) + 1j * np.random.randn(256, 256)).astype(
            np.complex64
        )
        k = fft2d_kernel(rounds=2)
        y_m, t_m, _ = run_merged(k, x, cluster)
        y_s, t_s = run_split_staged(k, x, cluster)
        same = np.allclose(y_m, y_s, atol=1e-2)
        print(f"staged fft2d: merged {t_m*1e3:.1f}ms vs split {t_s*1e3:.1f}ms "
              f"(results agree: {same})")


if __name__ == "__main__":
    main()
