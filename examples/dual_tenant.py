"""SPLIT-mode two-tenant demo: two different architectures train
concurrently, one per pod — the paper's "work on different tasks in
parallel" use of split mode.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/dual_tenant.py
"""

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_arch
from repro.core import Mode, MixedScheduler, SpatzformerCluster, VectorTask
from repro.data import DataConfig, SyntheticCorpus
from repro.models import LM
from repro.train import adamw_init, make_train_step


def make_tenant(arch: str, steps: int = 5):
    cfg = get_arch(arch).reduced()

    def fn(info):
        model = LM(cfg)
        params = model.init(jax.random.key(0))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(model, TrainConfig(lr=1e-3)))
        corpus = SyntheticCorpus(DataConfig(cfg.vocab_size, 32, 4, seed=1))
        loss = None
        for i in range(steps):
            batch = jax.tree.map(jnp.asarray, corpus.batch(i))
            params, opt, m = step(params, opt, batch)
            loss = float(m["loss"])
        return f"{arch}: final loss {loss:.3f}"

    return VectorTask(f"train:{arch}", fn)


def main() -> None:
    n = len(jax.devices())
    pods = 2 if n >= 2 and n % 2 == 0 else 1
    cluster = SpatzformerCluster(n_pods=pods)
    print(cluster)
    sched = MixedScheduler(cluster)
    tenants = [
        make_tenant("codeqwen1.5-7b"),
        make_tenant("falcon-mamba-7b"),
    ]
    rep = sched.run(Mode.SPLIT, tenants, scalar_tasks=None)
    print(rep.summary())
    for r in rep.records:
        print(" ", r.result)


if __name__ == "__main__":
    main()
