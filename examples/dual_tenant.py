"""SPLIT-mode two-tenant demo: two different architectures train
concurrently, one per pod — the paper's "work on different tasks in
parallel" use of split mode — then the SAME split idea at the serving
layer: two tenants' request streams served by a `ServeCluster` whose
router pins each tenant to its own engine replica.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/dual_tenant.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_arch
from repro.core import Mode, MixedScheduler, SpatzformerCluster, VectorTask
from repro.data import DataConfig, SyntheticCorpus
from repro.models import LM
from repro.serve import Request, ServeCluster
from repro.train import adamw_init, make_train_step


def make_tenant(arch: str, steps: int = 5):
    cfg = get_arch(arch).reduced()

    def fn(info):
        model = LM(cfg)
        params = model.init(jax.random.key(0))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(model, TrainConfig(lr=1e-3)))
        corpus = SyntheticCorpus(DataConfig(cfg.vocab_size, 32, 4, seed=1))
        loss = None
        for i in range(steps):
            batch = jax.tree.map(jnp.asarray, corpus.batch(i))
            params, opt, m = step(params, opt, batch)
            loss = float(m["loss"])
        return f"{arch}: final loss {loss:.3f}"

    return VectorTask(f"train:{arch}", fn)


def serve_two_tenants() -> None:
    """Split-mode serving: one engine replica per device, each tenant's
    requests pinned to its home replica by the router."""
    cfg = get_arch("codeqwen1.5-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    cluster = ServeCluster(model, params, mode=Mode.SPLIT, batch_slots=2, max_len=64)
    print(cluster)
    rng = np.random.default_rng(0)
    for i in range(8):
        cluster.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 12))).astype(np.int32),
                max_new=8,
                tenant="tenantA" if i % 2 == 0 else "tenantB",
            )
        )
    stats = cluster.run()
    homes = cluster.router.tenant_home
    print(
        f"  served {stats.total_requests} reqs ({stats.tokens_per_sec:,.1f} tok/s), "
        f"tenant homes: {dict(sorted(homes.items()))}, "
        f"per-replica requests: {cluster.router.assigned}"
    )


def main() -> None:
    n = len(jax.devices())
    pods = 2 if n >= 2 and n % 2 == 0 else 1
    cluster = SpatzformerCluster(n_pods=pods)
    print(cluster)
    sched = MixedScheduler(cluster)
    tenants = [
        make_tenant("codeqwen1.5-7b"),
        make_tenant("falcon-mamba-7b"),
    ]
    rep = sched.run(Mode.SPLIT, tenants, scalar_tasks=None)
    print(rep.summary())
    for r in rep.records:
        print(" ", r.result)
    serve_two_tenants()


if __name__ == "__main__":
    main()
