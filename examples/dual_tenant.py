"""SPLIT-mode two-tenant demo: two different architectures train
concurrently, one per pod — the paper's "work on different tasks in
parallel" use of split mode — then the SAME split idea at the serving
layer: two tenants' request streams served by a `ServeCluster` whose
router pins each tenant to its own engine replica.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/dual_tenant.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_arch
from repro.core import Mode, MixedScheduler, SpatzformerCluster, VectorTask
from repro.data import DataConfig, SyntheticCorpus
from repro.models import LM
from repro.serve import Request, SamplingParams, ServeCluster
from repro.train import adamw_init, make_train_step


def make_tenant(arch: str, steps: int = 5):
    cfg = get_arch(arch).reduced()

    def fn(info):
        model = LM(cfg)
        params = model.init(jax.random.key(0))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(model, TrainConfig(lr=1e-3)))
        corpus = SyntheticCorpus(DataConfig(cfg.vocab_size, 32, 4, seed=1))
        loss = None
        for i in range(steps):
            batch = jax.tree.map(jnp.asarray, corpus.batch(i))
            params, opt, m = step(params, opt, batch)
            loss = float(m["loss"])
        return f"{arch}: final loss {loss:.3f}"

    return VectorTask(f"train:{arch}", fn)


def serve_two_tenants() -> None:
    """Split-mode serving: one engine replica per device, each tenant's
    requests pinned to its home replica by the router — and each tenant's
    sampling policy configured ONCE as a cluster-level default
    (SamplingParams), not per request: tenantA decodes greedily, tenantB
    samples a seeded nucleus (top-p) stream."""
    cfg = get_arch("codeqwen1.5-7b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    cluster = ServeCluster(
        model, params, mode=Mode.SPLIT, batch_slots=2, max_len=64,
        tenant_defaults={
            "tenantA": SamplingParams(max_new=8),
            "tenantB": SamplingParams(max_new=8, temperature=0.9, top_p=0.9, seed=7),
        },
    )
    print(cluster)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        reqs.append(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 12))).astype(np.int32),
                tenant="tenantA" if i % 2 == 0 else "tenantB",
            )
        )
        cluster.submit(reqs[-1])  # tenant default params attach here
    stats = cluster.run()
    homes = cluster.router.tenant_home
    print(
        f"  served {stats.total_requests} reqs ({stats.tokens_per_sec:,.1f} tok/s), "
        f"tenant homes: {dict(sorted(homes.items()))}, "
        f"per-replica requests: {cluster.router.assigned}"
    )
    print(
        f"  req 0 [{reqs[0].tenant}] params: greedy -> {reqs[0].generated[:5]}\n"
        f"  req 1 [{reqs[1].tenant}] params: top_p={reqs[1].params.top_p} "
        f"seed={reqs[1].params.seed} -> {reqs[1].generated[:5]}"
    )


def main() -> None:
    n = len(jax.devices())
    pods = 2 if n >= 2 and n % 2 == 0 else 1
    cluster = SpatzformerCluster(n_pods=pods)
    print(cluster)
    sched = MixedScheduler(cluster)
    tenants = [
        make_tenant("codeqwen1.5-7b"),
        make_tenant("falcon-mamba-7b"),
    ]
    rep = sched.run(Mode.SPLIT, tenants, scalar_tasks=None)
    print(rep.summary())
    for r in rep.records:
        print(" ", r.result)
    serve_two_tenants()


if __name__ == "__main__":
    main()
