"""Quickstart: build an assigned architecture, run forward / train-step /
decode on CPU with a reduced config, and show the Spatzformer mode API.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_arch
from repro.core import Mode, SpatzformerCluster, coremark, switch_mode
from repro.models import LM
from repro.train import adamw_init, make_train_step


def main() -> None:
    # ---- 1. pick an assigned architecture (full config), shrink for CPU
    cfg = get_arch("qwen3-32b")
    print(f"full config: {cfg.name}: {cfg.num_params():,} params")
    cfg = cfg.reduced()
    print(f"reduced for CPU: {cfg.num_params():,} params")

    # ---- 2. forward + loss + one optimizer step
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 64), 0, cfg.vocab_size)
    logits, _ = jax.jit(model.forward)(params, {"tokens": toks})
    print("forward:", logits.shape, "finite:", bool(jnp.isfinite(logits).all()))

    step = jax.jit(make_train_step(model, TrainConfig(lr=1e-3)))
    opt = adamw_init(params)
    params, opt, metrics = step(params, opt, {"tokens": toks, "labels": toks})
    print(f"train step: loss={float(metrics['loss']):.3f}")

    # ---- 3. prefill + decode three tokens
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, 96))(
        params, {"tokens": toks}
    )
    tok = toks[:, -1:]
    for t in range(64, 67):
        lg, cache = jax.jit(model.decode_step)(
            params, cache, {"tokens": tok}, jnp.int32(t)
        )
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    print("decoded token ids:", tok[:, 0].tolist())

    # ---- 4. the paper's contribution: runtime-reconfigurable fabric
    cluster = SpatzformerCluster(n_pods=1, pod_shape=(1, 1))  # 1 device here
    print(cluster)
    state, report = switch_mode(cluster, Mode.MERGE, {"params": params})
    print(f"switched to {cluster.mode} in {report.seconds*1e3:.1f} ms")
    cm = coremark(5)
    print(f"scalar (CoreMark-analogue) workload: {cm.iters_per_sec:.1f} iter/s "
          f"checksum={cm.checksum:#06x}")


if __name__ == "__main__":
    main()
