"""Batched serving example: continuous-batching engine over a reduced arch.

    PYTHONPATH=src python examples/serve_batch.py --arch minicpm3-4b
"""

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import LM
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, batch_slots=args.slots, max_len=128)
    # production serving compiles once, then serves: every dispatch variant
    # (incl. the temperature samplers half the requests below need) is
    # built before the first request
    engine.prewarm(sampling=True)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 12))).astype(np.int32),
                max_new=args.max_new,
                temperature=0.0 if i % 2 == 0 else 0.7,
            )
        )
    stats = engine.run()
    print(f"arch={cfg.name} slots={args.slots}")
    print(f"served {stats.total_requests} requests, {stats.total_tokens} decode tokens "
          f"in {stats.wall_seconds:.2f}s -> {stats.tokens_per_sec:,.1f} tok/s")
    print(f"TTFT p50={stats.ttft_p50*1e3:.0f}ms p99={stats.ttft_p99*1e3:.0f}ms  "
          f"TPOT p50={stats.tpot_p50*1e3:.1f}ms p99={stats.tpot_p99*1e3:.1f}ms")
    for r in engine.finished[:3]:
        print(f"  req {r.rid}: ttft={1e3*(r.first_token_at - r.submitted_at):.0f}ms "
              f"tokens={r.generated[:8]}...")


if __name__ == "__main__":
    main()
