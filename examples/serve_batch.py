"""Batched serving example: the request-lifecycle API over a reduced arch —
per-request SamplingParams (greedy / temperature / nucleus top-p), an
incrementally streamed response, and a mid-stream cancellation.

    PYTHONPATH=src python examples/serve_batch.py --arch minicpm3-4b
"""

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import LM
from repro.serve import Request, SamplingParams, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, batch_slots=args.slots, max_len=128)
    # production serving compiles once, then serves: every dispatch variant
    # (incl. the fused temperature/top-k/top-p sampler variants the sampled
    # requests below need) is built before the first request
    engine.prewarm(sampling=True)

    # three request classes sharing the same fabric, reconfigured per
    # request by SamplingParams — never by a recompile
    variants = (
        SamplingParams(max_new=args.max_new),  # greedy
        SamplingParams(max_new=args.max_new, temperature=0.7, seed=1),
        SamplingParams(max_new=args.max_new, temperature=0.9, top_p=0.9, seed=2),
    )
    rng = np.random.default_rng(0)
    handles = [
        engine.submit(
            Request(
                rid=i,
                prompt=rng.integers(
                    0, cfg.vocab_size, size=int(rng.integers(4, 12))
                ).astype(np.int32),
                params=variants[i % len(variants)],
            )
        )
        for i in range(args.requests)
    ]

    # stream request 0 token by token (iterating the handle drives the
    # engine; every other request decodes alongside in the same ticks)...
    print("req 0 (greedy) streams: ", end="", flush=True)
    for i, tok in enumerate(handles[0]):
        print(tok, end=" ", flush=True)
        if i == 2 and len(handles) > 1:
            handles[-1].cancel()  # ...and abort the last request mid-stream
    print(f"[{handles[0].finish_reason}]")
    print(f"req {handles[-1].rid} cancelled after "
          f"{len(handles[-1].request.generated)} tokens "
          f"[{handles[-1].finish_reason}]")

    stats = engine.run()  # drain everything still in flight
    streamed = engine.stream_stats  # the handle-driven portion of the work
    done = stats.total_requests + streamed.total_requests
    print(f"arch={cfg.name} slots={args.slots}")
    print(f"served {done} requests ({streamed.cancelled} cancelled), "
          f"{stats.total_tokens + streamed.total_tokens} decode tokens")
    print(f"drain throughput {stats.tokens_per_sec:,.1f} tok/s  "
          f"TPOT p50={stats.tpot_p50*1e3:.1f}ms p99={stats.tpot_p99*1e3:.1f}ms")
    for r in engine.finished[:3]:
        ttft = "-" if r.first_token_at is None else f"{1e3*(r.first_token_at - r.submitted_at):.0f}ms"
        print(f"  req {r.rid}: finish={r.finish_reason} ttft={ttft} "
              f"tokens={r.generated[:8]}...")


if __name__ == "__main__":
    main()
