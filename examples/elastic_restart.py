"""Fault tolerance demo: train, kill a pod mid-run, shrink, restore, finish.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_restart.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.ckpt import Checkpointer
from repro.core import SpatzformerCluster
from repro.ft import run_elastic


def main() -> None:
    n = len(jax.devices())
    pods = 2 if n >= 2 and n % 2 == 0 else 1
    cluster = SpatzformerCluster(n_pods=pods)
    print(f"starting fabric: {cluster}")

    def make_state(info):
        return {"w": jnp.zeros((64,)), "steps": jnp.int32(0)}

    def step_factory(info):
        print(f"  (re)compiling step for {info.n_devices} devices")

        @jax.jit
        def step(state, batch, _):
            return {"w": state["w"] + batch["x"], "steps": state["steps"] + 1}

        return lambda s, b, i: step(s, b, i)

    batches = lambda i: {"x": jnp.full((64,), float(i))}
    with tempfile.TemporaryDirectory() as d:
        ckpt = Checkpointer(d, keep=3)
        fail_at = {12: 1} if pods == 2 else {}
        state, report = run_elastic(
            cluster, make_state, step_factory, batches, ckpt,
            total_steps=25, ckpt_every=5, fail_at=fail_at,
        )
    print(f"finished: steps={report.steps_done} failures={report.failures} "
          f"final_devices={report.final_devices} restarts={report.restarts}")
    print(f"state check: steps counter={int(state['steps'])} "
          f"(restored step replays from last checkpoint)")


if __name__ == "__main__":
    main()
