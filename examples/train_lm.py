"""End-to-end training driver (deliverable b): data pipeline → sharded
train loop → async checkpointing → restart, on a real (small) LM.

Defaults are CPU-sized (~1.3M params, 120 steps, loss visibly drops on the
structured synthetic corpus). ``--preset 100m`` selects a ~100M-param config
(96 steps/ckpt interval etc. unchanged) for real hardware.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt import Checkpointer
from repro.configs import TrainConfig, get_arch
from repro.data import DataConfig, PrefetchLoader, SyntheticCorpus
from repro.models import LM
from repro.train import adamw_init, make_train_step


def build_cfg(preset: str):
    base = get_arch("codeqwen1.5-7b")
    if preset == "tiny":
        return base.reduced()
    # ~100M: 12L × 768, the classic small-LM shape
    return dataclasses.replace(
        base.reduced(),
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_head=64,
        d_ff=3072,
        vocab_size=32768,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    model = LM(cfg)
    print(f"training {cfg.name} ({cfg.num_params():,} params) "
          f"for {args.steps} steps @ batch={args.batch} seq={args.seq}")

    params = model.init(jax.random.key(0))
    opt = adamw_init(params)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 3),
                       total_steps=args.steps)
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        (params, opt), start = ckpt.restore(jax.eval_shape(lambda: (params, opt)))
        print(f"resumed at step {start}")

    corpus = SyntheticCorpus(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))
    loader = PrefetchLoader(corpus, start_step=start)

    t0 = time.time()
    first_loss = None
    for i in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, next(loader))
        params, opt, m = step(params, opt, batch)
        if first_loss is None:
            first_loss = float(m["loss"])
        if (i + 1) % 10 == 0:
            rate = args.batch * args.seq * (i + 1 - start) / (time.time() - t0)
            print(f"step {i+1:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} tok/s={rate:,.0f}", flush=True)
        if (i + 1) % 40 == 0:
            ckpt.save(i + 1, (params, opt))  # async
    ckpt.save(args.steps, (params, opt), blocking=True)
    final = float(m["loss"])
    print(f"loss {first_loss:.3f} -> {final:.3f} "
          f"({'DECREASED' if final < first_loss else 'no progress'}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
