from repro.ft.elastic import ElasticReport, PodFailure, run_elastic
from repro.ft.watchdog import LaneState, Watchdog

__all__ = ["Watchdog", "LaneState", "PodFailure", "run_elastic", "ElasticReport"]
