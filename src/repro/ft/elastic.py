"""Elastic training driver: pod failure → shrink → restore → continue.

Reuses the Spatzformer reconfiguration machinery
(DESIGN.md §"Autotuning as reconfiguration"): a dead pod
turns the MERGE-mode fabric into "SPLIT with one tenant" on the survivors.
The driver loop:

1. run steps in MERGE mode on the full cluster,
2. on a :class:`PodFailure` (watchdog callback or injected by tests),
   rebuild the cluster without the dead pod (`surviving_cluster`),
3. restore the latest checkpoint RESHARDED onto the surviving mesh
   (`Checkpointer.restore(shardings=...)`),
4. resume the data loader from the restored step and continue.

Step functions are re-jitted per fabric (different mesh ⇒ different
executable); params/opt-state shardings are recomputed from the same rules.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

from repro.ckpt.checkpoint import Checkpointer
from repro.core.cluster import SpatzformerCluster
from repro.dist.sharding import MeshInfo, param_shardings


class PodFailure(RuntimeError):
    def __init__(self, pod: int, msg: str = ""):
        super().__init__(msg or f"pod {pod} failed")
        self.pod = pod


@dataclass
class ElasticReport:
    steps_done: int
    failures: int
    final_devices: int
    restarts: list[tuple[int, int]]  # (step, surviving_devices)


def run_elastic(
    cluster: SpatzformerCluster,
    make_state: Callable[[MeshInfo], Any],
    step_fn_factory: Callable[[MeshInfo], Callable[[Any, dict, int], Any]],
    batches: Callable[[int], dict],
    ckpt: Checkpointer,
    total_steps: int,
    ckpt_every: int = 5,
    fail_at: Optional[dict[int, int]] = None,  # step -> pod to kill (tests)
) -> tuple[Any, ElasticReport]:
    """Generic elastic loop. ``step_fn_factory(info)`` returns a jitted
    ``(state, batch, step) -> state``; ``make_state(info)`` builds fresh
    state on the given fabric (used once at the start)."""
    fail_at = fail_at or {}
    info = cluster.merge_info()
    state = make_state(info)
    step_fn = step_fn_factory(info)
    restarts: list[tuple[int, int]] = []
    failures = 0

    step = 0
    while step < total_steps:
        try:
            if step in fail_at:
                pod = fail_at.pop(step)
                raise PodFailure(pod)
            state = step_fn(state, batches(step), step)
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(step, state)
        except PodFailure as e:
            failures += 1
            ckpt.wait()  # make sure the last async save is durable
            cluster = cluster.surviving_cluster(e.pod)
            # survivors form a single-tenant SPLIT fabric (or a smaller merge)
            info = (
                cluster.merge_info() if cluster.n_pods > 1 else cluster.pod_info(0)
            )
            shardings = param_shardings(jax.eval_shape(lambda: state), info)
            last = ckpt.latest_step()
            if last is not None:
                state, step = ckpt.restore(
                    jax.eval_shape(lambda: state), shardings=shardings
                )
            else:  # failed before the first checkpoint: reshard live state
                from repro.core.reconfigure import reshard as _reshard

                state = _reshard(state, info)
                # step unchanged
            step_fn = step_fn_factory(info)
            restarts.append((step, cluster.n_devices))

    ckpt.wait()
    return state, ElasticReport(
        steps_done=step,
        failures=failures,
        final_devices=cluster.n_devices,
        restarts=restarts,
    )
