"""Heartbeat watchdog: straggler and failure detection.

Controllers (training loops, pod tenants, data workers, and the serving
cluster's split-mode replica threads — see ``repro.serve.cluster``, which
beats one lane per replica scheduling iteration and re-homes a dead
replica's live requests onto survivors) register lanes and beat every
step. The watchdog thread classifies lanes:

* ``ok``        — beat within `straggler_after`
* ``straggler`` — stale beyond `straggler_after` (mitigation hook fires:
  e.g. skip the lane's gradient contribution this step / reassign its shard)
* ``dead``      — stale beyond `dead_after` (failure hook fires: elastic
  shrink via repro.ft.elastic)

At real multi-pod scale each host process runs one of these against its
controller threads and a cluster-level sweeper aggregates; here the tests
drive it with injected stalls (``tests/test_ft.py``).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional


@dataclass
class LaneState:
    name: str
    last_beat: float
    step: int = 0
    status: str = "ok"  # ok | straggler | dead


class Watchdog:
    def __init__(
        self,
        straggler_after: float = 1.0,
        dead_after: float = 5.0,
        on_straggler: Optional[Callable[[str, LaneState], None]] = None,
        on_dead: Optional[Callable[[str, LaneState], None]] = None,
        poll: float = 0.05,
    ):
        self.straggler_after = straggler_after
        self.dead_after = dead_after
        self.on_straggler = on_straggler
        self.on_dead = on_dead
        self.poll = poll
        self._lanes: dict[str, LaneState] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ API

    def register(self, lane: str) -> None:
        with self._lock:
            self._lanes[lane] = LaneState(lane, time.monotonic())

    def beat(self, lane: str, step: Optional[int] = None) -> None:
        with self._lock:
            st = self._lanes[lane]
            st.last_beat = time.monotonic()
            if step is not None:
                st.step = step
            if st.status != "dead":  # dead lanes need explicit revive
                st.status = "ok"

    def revive(self, lane: str) -> None:
        with self._lock:
            st = self._lanes[lane]
            st.status = "ok"
            st.last_beat = time.monotonic()

    def status(self, lane: str) -> str:
        with self._lock:
            return self._lanes[lane].status

    def stale_seconds(self, lane: str) -> float:
        """Seconds since the lane's last beat — telemetry for supervisors
        that want the raw staleness, not just the classified status (the
        serving cluster reports it; tests assert against thresholds)."""
        with self._lock:
            return time.monotonic() - self._lanes[lane].last_beat

    def snapshot(self) -> dict[str, str]:
        with self._lock:
            return {k: v.status for k, v in self._lanes.items()}

    # ------------------------------------------------------------- lifecycle

    def start(self) -> "Watchdog":
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    def _loop(self) -> None:
        while not self._stop.is_set():
            now = time.monotonic()
            fire: list[tuple[str, str, LaneState]] = []
            with self._lock:
                for st in self._lanes.values():
                    stale = now - st.last_beat
                    if st.status == "dead":
                        continue
                    if stale > self.dead_after:
                        st.status = "dead"
                        fire.append(("dead", st.name, st))
                    elif stale > self.straggler_after and st.status == "ok":
                        st.status = "straggler"
                        fire.append(("straggler", st.name, st))
            for kind, name, st in fire:
                cb = self.on_dead if kind == "dead" else self.on_straggler
                if cb is not None:
                    cb(name, st)
            time.sleep(self.poll)
