"""Async sharded checkpointing with reshard-on-restore.

Save path: snapshot device arrays to host (cheap, sequential), then write
one ``.npy`` per leaf plus a JSON manifest in a background thread — training
continues while the filesystem churns (the I/O thread is another scalar task
that MERGE mode parks on the freed controller). Writes go to a temp dir
renamed atomically on completion; a ``latest`` symlink and bounded retention
finish the lifecycle.

Restore takes a *target sharding tree*, so a checkpoint written on one mesh
restores onto any other — this is the elastic-restart path (pod failure ⇒
restore onto the surviving sub-mesh; see repro.ft.elastic).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


@dataclass
class SaveHandle:
    step: int
    path: str
    thread: threading.Thread

    def wait(self) -> None:
        self.thread.join()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._last_handle: Optional[SaveHandle] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: Any, *, blocking: bool = False) -> SaveHandle:
        """Snapshot now, write async. ``state`` is any pytree of arrays."""
        if self._last_handle is not None:
            self._last_handle.wait()  # one in-flight save at a time
        host_leaves = [(k, np.asarray(v)) for k, v in _flatten(state)]
        treedef = jax.tree_util.tree_structure(state)
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"

        def writer() -> None:
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": [], "treedef": str(treedef)}
            for i, (key, arr) in enumerate(host_leaves):
                fname = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append(
                    {
                        "key": key,
                        "file": fname,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                    }
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        t = threading.Thread(target=writer, daemon=True)
        t.start()
        handle = SaveHandle(step, final, t)
        self._last_handle = handle
        if blocking:
            handle.wait()
        return handle

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        state_like: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> tuple[Any, int]:
        """Restore into the structure of ``state_like`` (pytree of arrays or
        ShapeDtypeStructs). ``shardings``: optional matching pytree of
        NamedShardings — device placement happens here (reshard-on-restore).
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like, treedef = jax.tree_util.tree_flatten(state_like)
        assert len(flat_like) == len(manifest["leaves"]), (
            len(flat_like),
            len(manifest["leaves"]),
        )
        arrays = []
        for i, (leaf_meta, like) in enumerate(zip(manifest["leaves"], flat_like)):
            arr = np.load(os.path.join(path, leaf_meta["file"]))
            assert tuple(arr.shape) == tuple(like.shape), (
                leaf_meta["key"], arr.shape, like.shape,
            )
            arrays.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, step

    def wait(self) -> None:
        if self._last_handle is not None:
            self._last_handle.wait()
