from repro.ckpt.checkpoint import Checkpointer, SaveHandle

__all__ = ["Checkpointer", "SaveHandle"]
