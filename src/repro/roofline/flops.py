"""Exact analytic FLOP / HBM-byte / collective-byte accounting per cell.

Why analytic: XLA's cost model counts scan bodies once (see analysis.py), so
for scanned-layer models the compiled numbers undercount by ~n_layers. We
control every einsum in the model, so exact counting is feasible and is the
primary roofline source; the compiled artifact numbers are the cross-check.

Conventions:
* FLOPs: matmul [m,k]@[k,n] = 2mkn. Vector ops (rope, norms, gates) are
  counted with small explicit constants — they matter for SSMs.
* Causal attention scores/AV over a full sequence use the exact ½S(S+1)
  average context.
* Train multipliers, applied to block-level (scanned+rematted) content:
  fwd 1× + recompute 1× + bwd 2× = 4×; embedding/head get 3× (not rematted).
* HBM bytes use a documented approximate traffic model (weights ×reads ×DP
  replication; activation boundaries with remat; optimizer f32 moments;
  decode = params + cache sweep). Good to ±30% — enough to rank terms.
* Collective bytes are GLOBAL wire bytes/step: ring all-reduce of payload P
  over an axis of size n costs 2·P·(n-1) summed over the group.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeConfig

ACT_BYTES = 2  # bf16 activations
GRAD_BYTES = 2  # bf16 grads on the wire
OPT_BYTES = 4  # f32 moments


@dataclass
class CellCounts:
    flops: float  # global FLOPs / step
    hbm_bytes: float  # global HBM bytes / step
    coll_bytes: float  # global wire bytes / step
    model_flops: float  # 6·N(_active)·tokens  (training) or 2·N·tokens (inference)


def _ar_bytes(payload: float, axis: int, groups: int = 1) -> float:
    """Global ring all-reduce wire bytes for `groups` groups of size `axis`."""
    if axis <= 1:
        return 0.0
    return 2.0 * payload * (axis - 1) * groups


# ---------------------------------------------------------------------------
# per-token forward FLOPs, split into (block_flops, edge_flops)
# ---------------------------------------------------------------------------


def _attn_proj_flops(cfg: ArchConfig) -> float:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.nope_head_dim + m.rope_head_dim
        q = (
            2 * d * m.q_lora_rank + 2 * m.q_lora_rank * H * qk
            if m.q_lora_rank
            else 2 * d * H * qk
        )
        kv = 2 * d * (m.kv_lora_rank + m.rope_head_dim) + 2 * m.kv_lora_rank * H * (
            m.nope_head_dim + m.v_head_dim
        )
        o = 2 * H * m.v_head_dim * d
        return q + kv + o
    return 2 * d * (H + 2 * KV) * hd + 2 * H * hd * d


def _attn_ctx_flops(cfg: ArchConfig, ctx: float) -> float:
    """scores + AV per query token against `ctx` context tokens."""
    H = cfg.n_heads
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.nope_head_dim + m.rope_head_dim
        return 2 * ctx * H * (qk + m.v_head_dim)
    return 2 * ctx * H * cfg.head_dim * 2


def _mla_absorbed_ctx_flops(cfg: ArchConfig, ctx: float) -> float:
    """Absorbed-form decode: latent-space scores/AV + absorb matmuls."""
    m = cfg.mla
    H = cfg.n_heads
    absorb = 2 * H * m.nope_head_dim * m.kv_lora_rank + 2 * H * m.kv_lora_rank * m.v_head_dim
    scores = 2 * ctx * H * (m.kv_lora_rank + m.rope_head_dim)
    av = 2 * ctx * H * m.kv_lora_rank
    return absorb + scores + av


def _ffn_flops(cfg: ArchConfig) -> float:
    """Per-token FFN flops for the *repeated* (scanned) layer type."""
    d = cfg.d_model
    if cfg.family == "moe":
        m = cfg.moe
        ff = m.expert_ff or cfg.d_ff
        return 2 * d * m.n_routed + m.top_k * 6 * d * ff + 6 * d * (m.n_shared * ff)
    return 6 * d * cfg.d_ff


def _mamba1_flops(cfg: ArchConfig) -> float:
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    dtr = max(d // 16, 1)
    N = s.state
    proj = 2 * d * 2 * di + 2 * s.conv_kernel * di + 2 * di * (dtr + 2 * N) + 2 * dtr * di + 2 * di * d
    # associative scan ≈ 2× sequential work (4 flops/elem state update) + exp
    scan = 2 * (6 * di * N) + 2 * di * N  # update + y=C·h
    gates = 8 * di
    return proj + scan + gates


def _mamba2_flops(cfg: ArchConfig) -> float:
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    nh = di // s.head_dim
    G, N, Q = s.n_groups, s.state, s.chunk
    conv_dim = di + 2 * G * N
    proj = 2 * d * (2 * di + 2 * G * N + nh) + 2 * s.conv_kernel * conv_dim + 2 * di * d
    # SSD per token: CBᵀ (2QN/head), M@X (2Q·hd/head), state upd + inter (4N·hd/head)
    ssd = nh * (2 * Q * N + 2 * Q * s.head_dim + 4 * N * s.head_dim)
    gates = 10 * di
    return proj + ssd + gates


def _block_fwd_flops_per_token(cfg: ArchConfig, ctx: float, decode: bool) -> float:
    """Per-token forward FLOPs of the full scanned stack (all L layers)."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        per = _mamba1_flops(cfg) if cfg.ssm.variant == "mamba1" else _mamba2_flops(cfg)
        return L * per
    if cfg.family == "hybrid":
        per = _mamba2_flops(cfg) * L
        n_inv = L // cfg.shared_attn_every
        attn = _attn_proj_flops(cfg) + (
            _attn_ctx_flops(cfg, ctx)
        ) + 6 * cfg.d_model * cfg.d_ff
        return per + n_inv * attn
    # dense / moe
    if decode and cfg.mla is not None:
        attn = (
            (2 * cfg.d_model * cfg.mla.q_lora_rank
             + 2 * cfg.mla.q_lora_rank * cfg.n_heads * (cfg.mla.nope_head_dim + cfg.mla.rope_head_dim))
            if cfg.mla.q_lora_rank
            else 2 * cfg.d_model * cfg.n_heads * (cfg.mla.nope_head_dim + cfg.mla.rope_head_dim)
        )
        attn += 2 * cfg.d_model * (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim)
        attn += _mla_absorbed_ctx_flops(cfg, ctx)
        attn += 2 * cfg.n_heads * cfg.mla.v_head_dim * cfg.d_model
    else:
        attn = _attn_proj_flops(cfg) + _attn_ctx_flops(cfg, ctx)
    ffn = _ffn_flops(cfg)
    flops = L * (attn + ffn)
    if cfg.family == "moe" and cfg.first_k_dense:
        dff = cfg.dense_ff or cfg.d_ff
        flops += cfg.first_k_dense * ((6 * cfg.d_model * dff) - _ffn_flops(cfg))
    return flops


def _edge_fwd_flops_per_token(cfg: ArchConfig) -> float:
    return 2 * cfg.d_model * cfg.vocab_size  # unembed matmul (embed is a gather)


# ---------------------------------------------------------------------------
# HBM traffic model
# ---------------------------------------------------------------------------


def _param_bytes(cfg: ArchConfig) -> float:
    return cfg.num_params() * ACT_BYTES


def _act_width(cfg: ArchConfig) -> float:
    """Approx per-token activation stream width (elements) per layer."""
    d = cfg.d_model
    if cfg.family == "ssm":
        di = cfg.ssm.expand * d
        return 4 * d + 6 * di
    if cfg.family == "hybrid":
        di = cfg.ssm.expand * d
        return 4 * d + 6 * di + (2 * cfg.d_ff + 2 * cfg.n_heads * cfg.head_dim) / cfg.shared_attn_every
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.family == "moe":
        m = cfg.moe
        ff_eff = (m.top_k + m.n_shared) * (m.expert_ff or cfg.d_ff)
    else:
        ff_eff = cfg.d_ff
    return 4 * d + 2 * ff_eff + 2 * (H + KV) * hd


def _cache_width(cfg: ArchConfig) -> float:
    """Per-token decode-cache width in elements (KV / latent / none)."""
    if cfg.mla is not None:
        return cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
    if cfg.family == "ssm":
        return 0.0  # O(1) state, counted separately
    if cfg.family == "hybrid":
        n_inv = cfg.n_layers // cfg.shared_attn_every
        return n_inv / cfg.n_layers * 2 * cfg.n_kv_heads * cfg.head_dim
    return 2 * cfg.n_kv_heads * cfg.head_dim


def _ssm_state_bytes(cfg: ArchConfig, batch: int) -> float:
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    s = cfg.ssm
    di = s.expand * cfg.d_model
    if s.variant == "mamba1":
        per = di * s.state * 4 + (s.conv_kernel - 1) * di * ACT_BYTES
    else:
        nh = di // s.head_dim
        per = nh * s.head_dim * s.state * 4 + (s.conv_kernel - 1) * (
            di + 2 * s.n_groups * s.state
        ) * ACT_BYTES
    return cfg.n_layers * batch * per


# ---------------------------------------------------------------------------
# main entry
# ---------------------------------------------------------------------------


def count_cell(
    cfg: ArchConfig,
    shape: ShapeConfig,
    *,
    dp: int,
    tp: int,
    zero: str = "none",  # 'none' | 'zero1' | 'zero3'
) -> CellCounts:
    """Global per-step counts for one (arch × shape) on a dp×tp fabric.

    ``zero1``: post-update parameter all-gather (sharded optimizer).
    ``zero3``: additionally 3 passes of per-layer parameter gathers
    (fwd / remat-recompute / bwd) — weights stored fabric-sharded."""
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    d = cfg.d_model
    n_params = cfg.num_params()
    n_active = cfg.num_active_params()

    if shape.kind in ("train", "prefill"):
        tokens = B * S
        ctx = (S + 1) / 2.0  # causal average context
        fwd_block = _block_fwd_flops_per_token(cfg, ctx, decode=False) * tokens
        fwd_edge = _edge_fwd_flops_per_token(cfg) * tokens
        if shape.kind == "train":
            flops = 4.0 * fwd_block + 3.0 * fwd_edge  # fwd+remat+bwd / fwd+bwd
            model_flops = 6.0 * n_active * tokens
        else:
            flops = fwd_block + fwd_edge
            model_flops = 2.0 * n_active * tokens

        # HBM: weights ×reads ×DP-replication + activations + optimizer
        reads = 3 if shape.kind == "train" else 1
        w_traffic = _param_bytes(cfg) * reads * dp
        act = tokens * _act_width(cfg) * L / max(L, 1)  # per layer width
        act_traffic = tokens * _act_width(cfg) * L * (
            1.0 if shape.kind == "prefill" else 2.5  # fwd w / +bwd r + remat rw
        ) * ACT_BYTES / 1.0
        opt_traffic = (
            n_params * (2 * GRAD_BYTES + 6 * OPT_BYTES) if shape.kind == "train" else 0.0
        )
        hbm = w_traffic + act_traffic + opt_traffic

        # collectives: TP ARs per layer + DP grads. Dense blocks: 2 ARs fwd
        # (attn out + mlp out) ×3 passes for train (fwd/bwd/remat-recompute).
        # SSM blocks: ONE AR per block (in_proj column-sharded feeds
        # out_proj row-sharded directly) — the first 6-AR estimate was
        # refuted by the loop-corrected HLO measurement
        # (repro.roofline.hlo_loops, zamba2 cell).
        ar_payload = (B / dp) * S * d * ACT_BYTES
        passes = 3 if shape.kind == "train" else 1
        if cfg.family in ("ssm", "hybrid"):
            n_ar_layer = 1 * passes
        else:
            n_ar_layer = 2 * passes
        coll = _ar_bytes(ar_payload, tp, groups=dp) * n_ar_layer * L / 2.0
        if cfg.family == "hybrid":
            # shared attention+MLP block every k layers: 2 ARs × passes
            coll += _ar_bytes(ar_payload, tp, groups=dp) * (
                2 * passes * (L // cfg.shared_attn_every)
            ) / 2.0
        if cfg.family == "moe":
            # EP psum of bf16 [T,d] per moe layer (fwd+bwd+remat)
            psum_payload = (B / dp) * S * d * ACT_BYTES
            n_moe = L - cfg.first_k_dense
            coll += _ar_bytes(psum_payload, tp, groups=dp) * (
                3 if shape.kind == "train" else 1
            ) * n_moe / 2.0
        if shape.kind == "train":
            coll += _ar_bytes(n_params / tp * GRAD_BYTES, dp, groups=tp)
            if zero in ("zero1", "zero3"):  # AG of the shard-updated params
                coll += n_params * ACT_BYTES * (dp - 1)
            if zero == "zero3":  # fwd + remat + bwd per-layer weight gathers
                coll += 3 * n_params * ACT_BYTES * (dp - 1) / dp * dp
        return CellCounts(flops, hbm, coll, model_flops)

    # ---------------- decode ----------------
    tokens = B  # one token per sequence per step
    ctx = float(S)
    fwd_block = _block_fwd_flops_per_token(cfg, ctx, decode=True) * tokens
    fwd_edge = _edge_fwd_flops_per_token(cfg) * tokens
    flops = fwd_block + fwd_edge
    model_flops = 2.0 * n_active * tokens

    # HBM: full param sweep ×DP + cache read (context) + state rw
    w_traffic = _param_bytes(cfg) * dp
    cache_read = B * ctx * _cache_width(cfg) * L * ACT_BYTES
    state_rw = 2 * _ssm_state_bytes(cfg, B)
    hbm = w_traffic + cache_read + state_rw

    ar_payload = (B / dp) * 1 * d * ACT_BYTES
    coll = _ar_bytes(ar_payload, tp, groups=dp) * 2 * L / 2.0
    if cfg.family == "moe":
        coll += _ar_bytes((B / dp) * d * ACT_BYTES, tp, groups=dp) * (
            L - cfg.first_k_dense
        ) / 2.0
    return CellCounts(flops, hbm, coll, model_flops)
