from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    parse_collectives,
)
from repro.roofline.flops import CellCounts, count_cell

__all__ = [
    "RooflineTerms",
    "parse_collectives",
    "count_cell",
    "CellCounts",
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
]
