"""Roofline terms from the compiled dry-run artifact + analytic counts.

Semantics discovered on this backend (documented because they shape the
method):

* ``compiled.cost_analysis()`` returns **per-device** flops/bytes and counts
  a ``while`` (lax.scan) body **once**, not ×trip-count. Scanned-layer models
  therefore undercount by ~n_layers.
* ``compiled.memory_analysis()`` is accurate (buffers are sized for the
  whole loop) — it is the "fits in HBM" check.
* the partitioned HLO text contains every collective with its per-device
  shapes — reliable for WHICH collectives and their payloads, with the same
  scan-body-once caveat for collectives inside the layer scan.

The roofline table therefore uses EXACT ANALYTIC counts
(:mod:`repro.roofline.flops` — we control every einsum) as the primary
source, and reports the compiled artifact's raw numbers alongside as a
cross-check (raw × n_layers ≈ analytic for scan-dominated programs).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")
_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4, "s16": 2,
    "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8,
    "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _BYTES[dtype]


def parse_collectives(hlo_text: str) -> dict[str, float]:
    """Sum per-device result bytes of every collective op, by kind.

    Works on ``compiled.as_text()`` (partitioned module). Start/done pairs
    (async collectives) are counted once via the ``-start`` op.
    """
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        # skip the 'done' half of start/done pairs
        if re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)-done", line):
            continue
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(1))[0]
        shapes = _SHAPE_RE.findall(lhs)
        if not shapes:
            continue
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[m.group(1)] = out.get(m.group(1), 0.0) + nbytes
    return out


@dataclass
class RooflineTerms:
    """All quantities GLOBAL (whole mesh) per step; terms in seconds."""

    name: str
    chips: int
    flops: float  # global FLOPs/step
    hbm_bytes: float  # global HBM traffic bytes/step
    coll_bytes: float  # global bytes crossing chip links /step
    model_flops: float = 0.0  # 6·N·D (dense) or 6·N_active·D (MoE)
    # raw compiled-artifact numbers (per-device, scan-body-once) for x-check
    hlo_flops_raw: Optional[float] = None
    hlo_bytes_raw: Optional[float] = None
    hlo_coll_raw: Optional[dict] = None
    memory_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time: the max term (perfect overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        if self.model_flops and self.step_time > 0:
            return self.model_flops / (self.chips * PEAK_FLOPS * self.step_time)
        return 0.0

    @property
    def usefulness(self) -> float:
        """MODEL_FLOPS / total FLOPs (catches remat/redundancy waste)."""
        return self.model_flops / self.flops if self.flops else 0.0

    def row(self) -> str:
        return (
            f"{self.name:42s} {self.t_compute*1e3:9.2f} {self.t_memory*1e3:9.2f} "
            f"{self.t_collective*1e3:9.2f}  {self.bottleneck:10s} "
            f"{self.usefulness:6.2f} {self.mfu*100:6.1f}%"
        )

    @staticmethod
    def header() -> str:
        return (
            f"{'cell':42s} {'t_comp(ms)':>9s} {'t_mem(ms)':>9s} {'t_coll(ms)':>9s}"
            f"  {'bound':10s} {'useful':>6s} {'MFU':>7s}"
        )
