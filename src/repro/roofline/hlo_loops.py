"""Loop-corrected collective accounting from compiled HLO.

``parse_collectives`` (analysis.py) sums per-device collective bytes as
written — but XLA emits a ``lax.scan`` as a ``while`` op whose body appears
ONCE in the module, so collectives inside the layer scan are undercounted by
the trip count. This module segments the HLO text into computations, finds
``while`` ops with their condition/body regions, extracts trip counts from
the condition's loop-bound constant, and multiplies each computation's
collective bytes by the product of enclosing trip counts (nested scans
compose: attention KV-chunk scans inside the layer scan, microbatch scans,
…).

The result is the measured-artifact cross-check for the analytic collective
term in the roofline table (``benchmarks/roofline_bench.py``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.roofline.analysis import parse_collectives

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")


@dataclass
class _Comp:
    name: str
    lines: list[str] = field(default_factory=list)
    whiles: list[tuple[str, str]] = field(default_factory=list)  # (cond, body)
    callees: list[str] = field(default_factory=list)  # fusions / calls


def _segment(hlo: str) -> tuple[dict[str, _Comp], str]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry = ""
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is not None:
            cur.lines.append(line)
            c = _COND.search(line)
            b = _BODY.search(line)
            if c and b:
                cur.whiles.append((c.group(1), b.group(1)))
            else:
                for callee in _CALLS.findall(line):
                    cur.callees.append(callee)
    return comps, entry


def _trip_count(cond: _Comp) -> int:
    """Loop bound heuristic: the largest integer constant compared in the
    condition region (scan conditions are `iter < constant(T)`)."""
    consts = [int(x) for line in cond.lines for x in _CONST.findall(line)]
    consts = [c for c in consts if c > 1]
    return max(consts) if consts else 1


def corrected_collectives(hlo: str) -> dict[str, float]:
    """Per-device collective bytes by kind, with while-body multiplication.

    Propagates multipliers through the full call graph (while bodies ×trips,
    fusions/calls ×1). Computations never reached from ENTRY (parse gaps)
    fall back to multiplier 1 so the estimate is always ≥ the raw parse.
    """
    comps, entry = _segment(hlo)
    if not entry:
        return parse_collectives(hlo)

    mult: dict[str, float] = {name: 0.0 for name in comps}

    def visit(name: str, m: float, depth: int = 0) -> None:
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        if mult[name] >= m:  # already visited with an equal/larger multiplier
            return
        mult[name] = m
        for cond_name, body_name in comp.whiles:
            trips = _trip_count(comps.get(cond_name, _Comp(cond_name)))
            visit(cond_name, m, depth + 1)
            visit(body_name, m * trips, depth + 1)
        for callee in comp.callees:
            visit(callee, m, depth + 1)

    visit(entry, 1.0)

    totals: dict[str, float] = {}
    for name, comp in comps.items():
        local = parse_collectives("\n".join(comp.lines))
        if not local:
            continue
        m = mult.get(name) or 1.0  # unreached: count once (raw fallback)
        for k, v in local.items():
            totals[k] = totals.get(k, 0.0) + v * m
    return totals
