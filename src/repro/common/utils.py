"""Small shared utilities: timing, humanized units, pytree accounting."""

from __future__ import annotations

import time
from typing import Any

import jax
import numpy as np


class Timer:
    """Wall-clock timer usable as a context manager.

    >>> with Timer() as t:
    ...     work()
    >>> t.elapsed  # seconds
    """

    def __init__(self) -> None:
        self.elapsed = 0.0
        self._t0 = 0.0

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.elapsed = time.perf_counter() - self._t0


def _humanize(x: float, units: list[str], base: float = 1000.0) -> str:
    for unit in units:
        if abs(x) < base:
            return f"{x:.3g}{unit}"
        x /= base
    return f"{x:.3g}{units[-1]}"


def human_num(x: float) -> str:
    return _humanize(float(x), ["", "K", "M", "B", "T", "P"])


def human_bytes(x: float) -> str:
    return _humanize(float(x), ["B", "KiB", "MiB", "GiB", "TiB", "PiB"], base=1024.0)


def human_flops(x: float) -> str:
    return _humanize(float(x), ["F", "KF", "MF", "GF", "TF", "PF", "EF"])


def pytree_num_params(tree: Any) -> int:
    """Total number of elements across all leaves (works on ShapeDtypeStruct too)."""
    leaves = jax.tree.leaves(tree)
    return sum(int(np.prod(leaf.shape)) for leaf in leaves)


def pytree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (works on ShapeDtypeStruct too)."""
    leaves = jax.tree.leaves(tree)
    return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize for leaf in leaves)


def tree_struct_str(tree: Any, max_leaves: int = 40) -> str:
    """Debug rendering of a pytree's leaf shapes/dtypes."""
    lines = []
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat[:max_leaves]:
        name = jax.tree_util.keystr(path)
        lines.append(f"  {name}: {tuple(leaf.shape)} {leaf.dtype}")
    if len(flat) > max_leaves:
        lines.append(f"  ... ({len(flat) - max_leaves} more leaves)")
    return "\n".join(lines)


def now_ms() -> float:
    return time.perf_counter() * 1e3
