from repro.common.utils import (
    Timer,
    human_bytes,
    human_flops,
    human_num,
    pytree_bytes,
    pytree_num_params,
    tree_struct_str,
)

__all__ = [
    "Timer",
    "human_bytes",
    "human_flops",
    "human_num",
    "pytree_bytes",
    "pytree_num_params",
    "tree_struct_str",
]
