"""Mixed scalar-vector workload scheduler (paper §III, Fig. 2 right).

Maps two task queues onto the fabric according to the current mode:

* **MERGE** — controller-0 thread drives the *vector* queue on the fused
  mesh (full fabric per kernel); the freed controller thread drains the
  *scalar* queue concurrently. Scalar latency hides behind device compute
  (async dispatch releases the GIL while the device works).
* **SPLIT + scalar work present** — the paper's penalty case: one controller
  is consumed by the scalar queue, leaving its vector unit idle; the other
  controller runs every vector task on just its own pod (half fabric).
* **SPLIT, vector-only** — two-tenant mode: vector tasks round-robin across
  pods and run concurrently (this is where SPLIT shines; see
  ``examples/dual_tenant.py``).

Each VectorTask receives the :class:`MeshInfo` of whatever fabric slice the
scheduler assigned, so the same task body runs in every mode.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.cluster import SpatzformerCluster
from repro.core.modes import Mode
from repro.dist.sharding import MeshInfo


@dataclass
class VectorTask:
    name: str
    fn: Callable[[MeshInfo], Any]  # must block until device work completes


@dataclass
class ScalarTask:
    name: str
    fn: Callable[[], Any]


@dataclass
class TaskRecord:
    name: str
    kind: str  # 'vector' | 'scalar'
    lane: str  # which controller ran it
    start: float
    end: float
    result: Any = None

    @property
    def seconds(self) -> float:
        return self.end - self.start


@dataclass
class ScheduleReport:
    mode: Mode
    makespan: float
    records: list[TaskRecord] = field(default_factory=list)

    def lane_time(self, lane: str) -> float:
        recs = [r for r in self.records if r.lane == lane]
        if not recs:
            return 0.0
        return max(r.end for r in recs) - min(r.start for r in recs)

    def kind_time(self, kind: str) -> float:
        return sum(r.seconds for r in self.records if r.kind == kind)

    def summary(self) -> str:
        lines = [f"mode={self.mode} makespan={self.makespan:.4f}s"]
        for r in self.records:
            lines.append(
                f"  [{r.lane}] {r.kind:6s} {r.name:24s} {r.seconds:.4f}s"
            )
        return "\n".join(lines)


class MixedScheduler:
    """Runs mixed scalar/vector workloads under a given mode."""

    def __init__(self, cluster: SpatzformerCluster):
        self.cluster = cluster

    # ------------------------------------------------------------------ run

    def run(
        self,
        mode: Mode,
        vector_tasks: list[VectorTask],
        scalar_tasks: Optional[list[ScalarTask]] = None,
    ) -> ScheduleReport:
        scalar_tasks = scalar_tasks or []
        t0 = time.perf_counter()
        records: list[TaskRecord] = []
        lock = threading.Lock()

        def record(name, kind, lane, start, end, result):
            with lock:
                records.append(TaskRecord(name, kind, lane, start, end, result))

        def drain_vector(queue: list[VectorTask], info: MeshInfo, lane: str):
            for task in queue:
                s = time.perf_counter()
                res = task.fn(info)
                record(task.name, "vector", lane, s, time.perf_counter(), res)

        def drain_scalar(queue: list[ScalarTask], lane: str):
            for task in queue:
                s = time.perf_counter()
                res = task.fn()
                record(task.name, "scalar", lane, s, time.perf_counter(), res)

        if mode is Mode.MERGE:
            info = self.cluster.merge_info()
            threads = [
                threading.Thread(
                    target=drain_vector, args=(vector_tasks, info, "ctl0/merged")
                )
            ]
            # freed controllers take the scalar queue
            if scalar_tasks:
                threads.append(
                    threading.Thread(
                        target=drain_scalar, args=(scalar_tasks, "ctl1/freed")
                    )
                )
        else:  # SPLIT
            infos = self.cluster.split_infos()
            if scalar_tasks:
                # paper's split-mode penalty: controller-1 (and its vector
                # unit) is consumed by the scalar queue; all vector work
                # lands on pod 0.
                threads = [
                    threading.Thread(
                        target=drain_vector, args=(vector_tasks, infos[0], "ctl0/pod0")
                    ),
                    threading.Thread(
                        target=drain_scalar, args=(scalar_tasks, "ctl1/scalar")
                    ),
                ]
            else:
                # two-tenant mode: round-robin vector tasks across pods
                queues: list[list[VectorTask]] = [[] for _ in infos]
                for i, task in enumerate(vector_tasks):
                    queues[i % len(infos)].append(task)
                threads = [
                    threading.Thread(
                        target=drain_vector, args=(q, infos[i], f"ctl{i}/pod{i}")
                    )
                    for i, q in enumerate(queues)
                    if q
                ]

        for t in threads:
            t.start()
        for t in threads:
            t.join()
        makespan = time.perf_counter() - t0
        records.sort(key=lambda r: r.start)
        return ScheduleReport(mode=mode, makespan=makespan, records=records)
