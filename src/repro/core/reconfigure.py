"""Runtime mode switching: remesh + reshard live state (paper's CSR write).

Switching SPLIT↔MERGE re-homes every live array onto the new mesh view via
``jax.device_put`` with the target :class:`NamedSharding`. The measured
latency and bytes moved are the TPU analogue of the paper's reconfiguration
cost (their mode switch is a CSR write + pipeline drain; ours is a resharding
collective). The same machinery implements *elastic scaling*: shrinking onto
the surviving pod after a failure is just a reshard onto
``cluster.surviving_cluster(dead).pod_info(0)``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

from repro.common.utils import pytree_bytes
from repro.core.cluster import SpatzformerCluster
from repro.core.modes import Mode
from repro.dist.sharding import MeshInfo, param_shardings


@dataclass
class SwitchReport:
    from_desc: str
    to_desc: str
    bytes_moved: int
    seconds: float

    @property
    def gbytes_per_sec(self) -> float:
        return self.bytes_moved / 1e9 / max(self.seconds, 1e-12)


def reshard(
    tree: Any,
    target_info: MeshInfo,
    sharding_fn: Callable[[Any, MeshInfo], Any] = param_shardings,
) -> Any:
    """Re-home a live pytree onto a new mesh view."""
    shardings = sharding_fn(jax.eval_shape(lambda: tree), target_info)
    return jax.device_put(tree, shardings)


def switch_mode(
    cluster: SpatzformerCluster,
    new_mode: Mode,
    live_state: Optional[Any] = None,
    *,
    pod: int = 0,
    sharding_fn: Callable[[Any, MeshInfo], Any] = param_shardings,
) -> tuple[Optional[Any], SwitchReport]:
    """Switch the cluster's mode, resharding ``live_state`` if given.

    Returns (resharded_state_or_None, SwitchReport).
    """
    from_desc = f"{cluster.mode}"
    t0 = time.perf_counter()
    target = cluster.merge_info() if new_mode is Mode.MERGE else cluster.pod_info(pod)
    out = None
    moved = 0
    if live_state is not None:
        out = reshard(live_state, target, sharding_fn)
        jax.block_until_ready(out)
        moved = pytree_bytes(jax.eval_shape(lambda: live_state))
    cluster.set_mode(new_mode)
    secs = time.perf_counter() - t0
    return out, SwitchReport(from_desc, str(new_mode), moved, secs)
