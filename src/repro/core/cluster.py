"""SpatzformerCluster: the reconfigurable device fabric.

Owns the full ``(pod, data, model)`` mesh and exposes per-mode views:

* :meth:`merge_info`  — one :class:`MeshInfo` over the fused mesh, with the
  ``pod`` axis folded into the batch axes (``batch_axes=('pod', 'data')``).
  This is the paper's merge mode: one controller, doubled vector length.
* :meth:`split_infos` — one :class:`MeshInfo` per pod, each a standalone
  ``(data, model)`` mesh over that pod's devices. This is split mode: every
  pod is an independent vector unit with its own controller.

The same object also models the *degraded* fabric for fault tolerance: losing
a pod is exactly "SPLIT with one tenant" (``split_infos()[survivor]``), which
is how :mod:`repro.ft.elastic` re-homes a job after a pod failure.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.modes import Mode
from repro.dist.sharding import MeshInfo


def _auto_pod_shape(n: int) -> tuple[int, int]:
    """Factor a pod's device count into (data, model) as square as possible."""
    best = (n, 1)
    for m in range(1, int(n**0.5) + 1):
        if n % m == 0:
            best = (n // m, m)
    return best


@dataclass
class SpatzformerCluster:
    """Reconfigurable multi-pod fabric.

    Args:
        n_pods: number of independent "vector units" (pods).
        pod_shape: per-pod (data, model) mesh shape; inferred if None.
        devices: explicit device list; defaults to ``jax.devices()``.
    """

    n_pods: int = 2
    pod_shape: Optional[tuple[int, int]] = None
    devices: Optional[Sequence] = None

    def __post_init__(self) -> None:
        devs = list(self.devices if self.devices is not None else jax.devices())
        if len(devs) % self.n_pods:
            raise ValueError(f"{len(devs)} devices not divisible into {self.n_pods} pods")
        per_pod = len(devs) // self.n_pods
        if self.pod_shape is None:
            self.pod_shape = _auto_pod_shape(per_pod)
        d, m = self.pod_shape
        if d * m != per_pod:
            raise ValueError(f"pod_shape {self.pod_shape} != {per_pod} devices/pod")
        self._dev_grid = np.array(devs).reshape(self.n_pods, d, m)
        self._merged_mesh = Mesh(self._dev_grid, ("pod", "data", "model"))
        self._pod_meshes = [
            Mesh(self._dev_grid[i], ("data", "model")) for i in range(self.n_pods)
        ]
        self.mode: Mode = Mode.SPLIT

    # ------------------------------------------------------------------ views

    @property
    def n_devices(self) -> int:
        return self._dev_grid.size

    @property
    def merged_mesh(self) -> Mesh:
        return self._merged_mesh

    def merge_info(self) -> MeshInfo:
        return MeshInfo(self._merged_mesh, batch_axes=("pod", "data"))

    def split_infos(self) -> list[MeshInfo]:
        return [MeshInfo(m, batch_axes=("data",)) for m in self._pod_meshes]

    def pod_info(self, pod: int) -> MeshInfo:
        return self.split_infos()[pod]

    def info_for(self, mode: Mode, pod: int = 0) -> MeshInfo:
        return self.merge_info() if mode is Mode.MERGE else self.pod_info(pod)

    # ------------------------------------------------------------------ mode

    def set_mode(self, mode: Mode) -> None:
        self.mode = mode

    def surviving_cluster(self, dead_pod: int) -> "SpatzformerCluster":
        """Elastic shrink: rebuild the fabric without one pod's devices."""
        keep = [i for i in range(self.n_pods) if i != dead_pod]
        devs = self._dev_grid[keep].reshape(-1).tolist()
        return SpatzformerCluster(
            n_pods=len(keep), pod_shape=self.pod_shape, devices=devs
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        d, m = self.pod_shape
        return (
            f"SpatzformerCluster(pods={self.n_pods}, pod=({d}x{m}), "
            f"devices={self.n_devices}, mode={self.mode})"
        )
