"""Fine-grained synchronization harness (paper §III: "MM fft outperforms
SM fft by more than 20% ... by reducing the synchronization overhead of the
multi-core architecture").

A sync-bound kernel is modeled as repeated rounds of

    x -> phase_a (shard-local)  ->  EXCHANGE (crosses shards)  ->  phase_b

— the canonical shape of a distributed FFT (row FFT → corner-turn transpose
→ column FFT) and of tensor-parallel matmul chains.

Two executions of the *same* kernel:

* :func:`run_merged` — ONE jitted program over the fused fabric. The
  exchange lowers to an on-device all-to-all; no host involvement between
  rounds. This is merge mode: a single control stream drives all vector
  units.
* :func:`run_split_staged` — the multi-controller baseline/split mode: each
  pod owns half the rows and runs per-phase programs; every exchange goes
  through the hosts (fetch halves → global permute → scatter back) with a
  barrier per round. The measured gap vs merged is the TPU analogue of the
  paper's inter-core synchronization overhead (their VUs share
  an L1 SPM, so their exchange is cheap barriers; ours pays host round-trips
  — same mechanism, heavier constant).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cluster import SpatzformerCluster


@dataclass
class TwoPhaseKernel:
    """rounds × (phase_a → transpose-exchange → phase_b) over x: [R, C]."""

    name: str
    phase_a: Callable[[jax.Array], jax.Array]  # row-local
    phase_b: Callable[[jax.Array], jax.Array]  # row-local (after transpose)
    rounds: int = 1


# ---------------------------------------------------------------------------
# kernel instances
# ---------------------------------------------------------------------------


def fft2d_kernel(rounds: int = 4) -> TwoPhaseKernel:
    """2-D FFT per round: FFT rows → corner turn → FFT (former) columns."""

    def phase(x):
        return jnp.fft.fft(x, axis=-1)

    return TwoPhaseKernel("fft2d", phase, phase, rounds)


def matmul_chain_kernel(w1: jax.Array, w2: jax.Array, rounds: int = 4) -> TwoPhaseKernel:
    """TP-style chain: (x@W1)ᵀ@W2 per round — one exchange per round."""

    def a(x):
        y = x.astype(jnp.float32) @ w1
        return jax.nn.gelu(y)

    def b(x):
        return x.astype(jnp.float32) @ w2

    return TwoPhaseKernel("matmul_chain", a, b, rounds)


# ---------------------------------------------------------------------------
# merged execution: one program, on-device exchange
# ---------------------------------------------------------------------------


def _merged_mesh_flat(cluster: SpatzformerCluster) -> Mesh:
    devs = np.array(cluster.merged_mesh.devices).reshape(-1)
    return Mesh(devs, ("fab",))


def run_merged(
    kernel: TwoPhaseKernel, x: np.ndarray, cluster: SpatzformerCluster, *, repeats: int = 3
) -> tuple[np.ndarray, float, Callable]:
    """Returns (result, best_seconds, compiled_fn for inspection)."""
    mesh = _merged_mesh_flat(cluster)
    sh = NamedSharding(mesh, P("fab", None))

    def program(xx):
        for _ in range(kernel.rounds):
            xx = kernel.phase_a(xx)
            xx = jax.lax.with_sharding_constraint(xx.T, sh)  # exchange
            xx = kernel.phase_b(xx)
            xx = jax.lax.with_sharding_constraint(xx.T, sh)  # restore layout
        return xx

    fn = jax.jit(program, in_shardings=sh, out_shardings=sh)
    xd = jax.device_put(x, sh)
    y = jax.block_until_ready(fn(xd))  # warmup/compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        y = jax.block_until_ready(fn(xd))
        best = min(best, time.perf_counter() - t0)
    return np.asarray(y), best, fn


# ---------------------------------------------------------------------------
# split execution: per-pod programs, host-mediated exchange + barrier
# ---------------------------------------------------------------------------


def run_split_staged(
    kernel: TwoPhaseKernel, x: np.ndarray, cluster: SpatzformerCluster, *, repeats: int = 3
) -> tuple[np.ndarray, float]:
    infos = cluster.split_infos()
    meshes = []
    for info in infos:
        devs = np.array(info.mesh.devices).reshape(-1)
        meshes.append(Mesh(devs, ("fab",)))
    shs = [NamedSharding(m, P("fab", None)) for m in meshes]
    n_pods = len(meshes)

    fa = [jax.jit(kernel.phase_a, in_shardings=s, out_shardings=s) for s in shs]
    fb = [jax.jit(kernel.phase_b, in_shardings=s, out_shardings=s) for s in shs]

    def one_run() -> np.ndarray:
        rows = x.shape[0]
        halves = np.split(x, n_pods, axis=0)
        parts = [jax.device_put(h, shs[i]) for i, h in enumerate(halves)]
        for _ in range(kernel.rounds):
            parts = [fa[i](p) for i, p in enumerate(parts)]
            for p in parts:  # barrier: controllers wait on their VUs
                jax.block_until_ready(p)
            # host-mediated corner turn across pods
            glob = np.concatenate([np.asarray(p) for p in parts], axis=0).T
            halves = np.split(glob, n_pods, axis=0)
            parts = [jax.device_put(h, shs[i]) for i, h in enumerate(halves)]
            parts = [fb[i](p) for i, p in enumerate(parts)]
            for p in parts:
                jax.block_until_ready(p)
            glob = np.concatenate([np.asarray(p) for p in parts], axis=0).T
            halves = np.split(glob, n_pods, axis=0)
            parts = [jax.device_put(h, shs[i]) for i, h in enumerate(halves)]
        return np.concatenate([np.asarray(p) for p in parts], axis=0)

    y = one_run()  # warmup/compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        y = one_run()
        best = min(best, time.perf_counter() - t0)
    return y, best
