"""The paper's contribution: runtime-reconfigurable split/merge fabric."""

from repro.core.cluster import SpatzformerCluster
from repro.core.coremark import CoreMarkResult, coremark
from repro.core.modes import Mode
from repro.core.reconfigure import SwitchReport, reshard, switch_mode
from repro.core.scheduler import (
    MixedScheduler,
    ScalarTask,
    ScheduleReport,
    VectorTask,
)
from repro.core.sync import (
    TwoPhaseKernel,
    fft2d_kernel,
    matmul_chain_kernel,
    run_merged,
    run_split_staged,
)

__all__ = [
    "SpatzformerCluster",
    "Mode",
    "MixedScheduler",
    "VectorTask",
    "ScalarTask",
    "ScheduleReport",
    "SwitchReport",
    "reshard",
    "switch_mode",
    "coremark",
    "CoreMarkResult",
    "TwoPhaseKernel",
    "fft2d_kernel",
    "matmul_chain_kernel",
    "run_merged",
    "run_split_staged",
]
