"""Discrete-event performance model of the fabric (TPU v5e constants).

Why this exists: the container running this reproduction has ONE physical
CPU core, so wall-clock split-vs-merge comparisons cannot express fabric
scaling (all XLA host devices time-slice the same core — "half the fabric"
still gets the whole core). The paper's performance claims are therefore
validated through a discrete-event model whose every input is either

* a documented hardware constant (v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
  ~50 GB/s/link ICI, measured-order dispatch/barrier/PCIe constants), or
* measured on this host (scalar-task seconds, exchange byte counts, program
  launch counts taken from the real scheduler/sync code paths).

The model executes the SAME schedules the real scheduler produces; only
device-time is virtual. Benchmarks report both the modeled v5e numbers (the
claim check) and the raw measured mechanism overheads.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class HardwareModel:
    """Per-chip and system constants (TPU v5e defaults)."""

    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link (per chip, one direction)
    launch_overhead: float = 30e-6  # host->device program dispatch
    barrier_overhead: float = 100e-6  # host-mediated multi-controller barrier
    pcie_bw: float = 16e9  # B/s host<->device staging (split-mode exchange)
    # energy constants (used for the paper's energy-efficiency analogue)
    pj_per_flop: float = 0.35  # ~0.35 pJ/bf16 FLOP at 12nm-class node
    pj_per_hbm_byte: float = 60.0
    pj_per_ici_byte: float = 30.0
    j_per_launch: float = 5e-3  # host dispatch+fetch energy per program


V5E = HardwareModel()


@dataclass
class KernelCost:
    """Roofline-style cost of one device program (GLOBAL totals)."""

    name: str
    flops: float
    hbm_bytes: float
    coll_bytes: float = 0.0  # bytes crossing chip boundaries on-device

    def device_seconds(self, chips: int, hw: HardwareModel = V5E) -> float:
        t_c = self.flops / (chips * hw.peak_flops)
        t_m = self.hbm_bytes / (chips * hw.hbm_bw)
        t_x = self.coll_bytes / (chips * hw.ici_bw)
        return max(t_c, t_m, t_x)

    def energy_j(self, hw: HardwareModel = V5E) -> float:
        return (
            self.flops * hw.pj_per_flop * 1e-12
            + self.hbm_bytes * hw.pj_per_hbm_byte * 1e-12
            + self.coll_bytes * hw.pj_per_ici_byte * 1e-12
        )


@dataclass
class ModeledRun:
    """Outcome of simulating one schedule."""

    makespan: float
    vector_busy: float
    scalar_busy: float
    launches: int
    host_exchange_bytes: float
    energy_j: float
    detail: list = field(default_factory=list)


# ---------------------------------------------------------------------------
# schedule-level models (mirror core.scheduler / core.sync exactly)
# ---------------------------------------------------------------------------


def model_vector_stream(
    kernels: list[KernelCost], chips: int, hw: HardwareModel = V5E
) -> tuple[float, float]:
    """(seconds, energy) for a controller draining kernels on `chips` chips."""
    t = 0.0
    e = 0.0
    for k in kernels:
        t += hw.launch_overhead + k.device_seconds(chips, hw)
        e += k.energy_j(hw) + hw.j_per_launch
    return t, e


def model_mixed_split(
    kernels: list[KernelCost],
    scalar_seconds: float,
    chips_per_pod: int,
    hw: HardwareModel = V5E,
) -> ModeledRun:
    """Paper's SM penalty case: scalar queue consumes controller-1 (its pod
    idles); ALL vector work runs on pod-0's chips."""
    t_vec, e = model_vector_stream(kernels, chips_per_pod, hw)
    makespan = max(t_vec, scalar_seconds)
    return ModeledRun(
        makespan=makespan,
        vector_busy=t_vec,
        scalar_busy=scalar_seconds,
        launches=len(kernels),
        host_exchange_bytes=0.0,
        energy_j=e,
    )


def model_mixed_merge(
    kernels: list[KernelCost],
    scalar_seconds: float,
    total_chips: int,
    hw: HardwareModel = V5E,
    merge_coll_penalty: float = 0.0,
) -> ModeledRun:
    """MM: vector stream on the fused fabric; scalar work fully overlapped on
    the freed controller. merge_coll_penalty: extra per-kernel collective
    bytes for the pod-spanning axis (cross-pod DP sync), if any."""
    adj = [
        KernelCost(k.name, k.flops, k.hbm_bytes, k.coll_bytes + merge_coll_penalty)
        for k in kernels
    ]
    t_vec, e = model_vector_stream(adj, total_chips, hw)
    makespan = max(t_vec, scalar_seconds)
    return ModeledRun(
        makespan=makespan,
        vector_busy=t_vec,
        scalar_busy=scalar_seconds,
        launches=len(kernels),
        host_exchange_bytes=0.0,
        energy_j=e,
    )


def model_staged_split(
    phase: KernelCost,
    rounds: int,
    exchange_bytes: float,
    chips_per_pod: int,
    n_pods: int = 2,
    hw: HardwareModel = V5E,
    exchange_over: str = "ici",
) -> ModeledRun:
    """Split/baseline execution of a two-phase sync-bound kernel.

    Per round: 2 × (per-pod phase program + barrier) + a host-orchestrated
    corner-turn exchange. The pods ARE physically linked, so by default the
    exchange program still moves bytes over ICI (``exchange_over='ici'``) —
    but it is a SEPARATE launch per pod with barriers, and nothing overlaps
    (phases, exchange, and sync serialize). ``exchange_over='pcie'`` models
    the worst case where data is staged through the hosts (what
    core.sync.run_split_staged literally does on this container).
    """
    total_chips = chips_per_pod * n_pods
    per_phase = KernelCost(
        phase.name, phase.flops / n_pods, phase.hbm_bytes / n_pods, 0.0
    )
    if exchange_over == "ici":
        t_x = exchange_bytes / (total_chips * hw.ici_bw)
        x_host_bytes = 0.0
    else:
        t_x = 2 * exchange_bytes / hw.pcie_bw
        x_host_bytes = 2 * exchange_bytes
    t = 0.0
    e = 0.0
    launches = 0
    for _ in range(rounds):
        for _ in range(2):  # phase_a, phase_b
            t += hw.launch_overhead + per_phase.device_seconds(chips_per_pod, hw)
            t += hw.barrier_overhead
            launches += n_pods
            e += phase.energy_j(hw) + n_pods * hw.j_per_launch
        # two corner-turn exchange programs per round (turn + restore), each
        # its own launch + barrier on both pods
        t += 2 * (t_x + hw.launch_overhead + hw.barrier_overhead)
        launches += 2 * n_pods
        e += 2 * (
            exchange_bytes * hw.pj_per_ici_byte * 1e-12 + n_pods * hw.j_per_launch
        )
    return ModeledRun(
        makespan=t,
        vector_busy=t,
        scalar_busy=0.0,
        launches=launches,
        host_exchange_bytes=2 * x_host_bytes * rounds,
        energy_j=e,
    )


def model_staged_merge(
    phase: KernelCost,
    rounds: int,
    exchange_bytes: float,
    total_chips: int,
    hw: HardwareModel = V5E,
) -> ModeledRun:
    """Merged execution: ONE program for all rounds; exchanges are on-device
    all-to-alls on ICI; a single dispatch; and — the key merge-mode win —
    the scheduler OVERLAPS round r's collective with round r±1's compute
    (async collectives inside one program), so the makespan is
    launch + max(Σcompute, Σcomm) + one un-overlappable pipeline fill."""
    t_phase = 2 * rounds * phase.device_seconds(total_chips, hw)
    t_x_one = 2 * exchange_bytes / (total_chips * hw.ici_bw)
    t_x = rounds * t_x_one
    t = hw.launch_overhead + max(t_phase, t_x) + min(t_phase, t_x_one)
    e = hw.j_per_launch + 2 * rounds * phase.energy_j(hw) + (
        2 * rounds * exchange_bytes * hw.pj_per_ici_byte * 1e-12
    )
    return ModeledRun(
        makespan=t,
        vector_busy=t,
        scalar_busy=0.0,
        launches=1,
        host_exchange_bytes=0.0,
        energy_j=e,
    )


# ---------------------------------------------------------------------------
# serving-mode model (the reconfiguration controller's decision input)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServingMix:
    """A windowed serving workload, summarized for mode prediction.

    The controller (:mod:`repro.serve.controller`) folds its sliding
    window of arrival/queue observations into one of these; the model
    below turns it into predicted split vs merge makespans. Token costs
    (``flops_per_token``, ``hbm_bytes_per_token``) come from the served
    model's parameter count; the scheduling constants mirror the engine's
    (``prefill_budget`` prompt tokens packed per iteration, fused decode
    chunks of ``max_chunk`` steps, ``batch_slots`` concurrent slots).
    """

    n_requests: int
    prompt_tokens: float  # Σ prompt length over the window
    decode_tokens: float  # Σ max_new (or observed generated) over the window
    longest_tokens: float  # max decode length of any single request
    flops_per_token: float  # ~2 × parameter count
    hbm_bytes_per_token: float  # ~parameter bytes (weight stream per step)
    coll_bytes_per_token: float = 1e5  # merge-mode per-row activation exchange
    prefill_budget: int = 64
    max_chunk: int = 8
    batch_slots: int = 4


def model_serving_mode(
    mix: ServingMix, n_devices: int, mode: str, hw: HardwareModel = V5E
) -> float:
    """Predicted seconds to serve ``mix`` in ``mode`` ("split"|"merge").

    Mirrors the engine's scheduling structure rather than a pure roofline:

    * **prefill** is admission-bandwidth-bound — each engine packs at most
      ``prefill_budget`` prompt tokens per scheduling iteration, so split
      mode's n independent pack streams admit n× faster (the paper's
      many-small-tasks story), while each merge iteration pays a barrier;
    * **decode** is a sequence of fused chunk steps — the sequential depth
      is the longest stream (or the queue serialized through the slots),
      each step streams the weights once per engine (batch-amortized), so
      merge mode's n-chip HBM makes memory-bound decode n× faster but
      pays per-row activation collectives and per-chunk barriers.

    Few long requests → merge wins (HBM). Many short ones → split wins
    (admission bandwidth, no barriers). With n_devices == 1 both modes
    degenerate to the same engine and the prediction collapses too.
    """
    assert mode in ("split", "merge"), mode
    n = max(int(n_devices), 1)
    chips = n if mode == "merge" else 1
    replicas = 1 if mode == "merge" else n
    barrier = hw.barrier_overhead if mode == "merge" else 0.0
    # --- prefill: iterations are serialized per engine by the pack budget
    share_p = mix.prompt_tokens / replicas
    iters = -(-share_p // mix.prefill_budget) if share_p > 0 else 0.0
    t_pack = max(
        mix.prefill_budget * mix.flops_per_token / (chips * hw.peak_flops),
        mix.hbm_bytes_per_token / (chips * hw.hbm_bw),
    )
    t_prefill = iters * (hw.launch_overhead + barrier + t_pack)
    # --- decode: sequential chunk steps over the batched slots
    share_d = mix.decode_tokens / replicas
    b = min(mix.batch_slots, max(1, round(mix.n_requests / replicas)))
    steps = max(mix.longest_tokens, share_d / b)
    t_step = max(
        b * mix.flops_per_token / (chips * hw.peak_flops),
        mix.hbm_bytes_per_token / (chips * hw.hbm_bw),
    )
    if mode == "merge":
        t_step += b * mix.coll_bytes_per_token / hw.ici_bw
    dispatches = steps / mix.max_chunk
    t_decode = steps * t_step + dispatches * (hw.launch_overhead + barrier)
    return t_prefill + t_decode


def serving_mode_advice(
    mix: ServingMix, n_devices: int, hw: HardwareModel = V5E
) -> tuple[str, dict[str, float]]:
    """(best_mode, {"split": s, "merge": s}) for a windowed workload."""
    seconds = {
        m: model_serving_mode(mix, n_devices, m, hw) for m in ("split", "merge")
    }
    best = min(seconds, key=lambda m: (seconds[m], m))
    return best, seconds
