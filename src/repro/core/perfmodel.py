"""Discrete-event performance model of the fabric (TPU v5e constants).

Why this exists: the container running this reproduction has ONE physical
CPU core, so wall-clock split-vs-merge comparisons cannot express fabric
scaling (all XLA host devices time-slice the same core — "half the fabric"
still gets the whole core). The paper's performance claims are therefore
validated through a discrete-event model whose every input is either

* a documented hardware constant (v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
  ~50 GB/s/link ICI, measured-order dispatch/barrier/PCIe constants), or
* measured on this host (scalar-task seconds, exchange byte counts, program
  launch counts taken from the real scheduler/sync code paths).

The model executes the SAME schedules the real scheduler produces; only
device-time is virtual. Benchmarks report both the modeled v5e numbers (the
claim check) and the raw measured mechanism overheads.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class HardwareModel:
    """Per-chip and system constants (TPU v5e defaults)."""

    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link (per chip, one direction)
    launch_overhead: float = 30e-6  # host->device program dispatch
    barrier_overhead: float = 100e-6  # host-mediated multi-controller barrier
    pcie_bw: float = 16e9  # B/s host<->device staging (split-mode exchange)
    # energy constants (used for the paper's energy-efficiency analogue)
    pj_per_flop: float = 0.35  # ~0.35 pJ/bf16 FLOP at 12nm-class node
    pj_per_hbm_byte: float = 60.0
    pj_per_ici_byte: float = 30.0
    j_per_launch: float = 5e-3  # host dispatch+fetch energy per program


V5E = HardwareModel()


@dataclass
class KernelCost:
    """Roofline-style cost of one device program (GLOBAL totals)."""

    name: str
    flops: float
    hbm_bytes: float
    coll_bytes: float = 0.0  # bytes crossing chip boundaries on-device

    def device_seconds(self, chips: int, hw: HardwareModel = V5E) -> float:
        t_c = self.flops / (chips * hw.peak_flops)
        t_m = self.hbm_bytes / (chips * hw.hbm_bw)
        t_x = self.coll_bytes / (chips * hw.ici_bw)
        return max(t_c, t_m, t_x)

    def energy_j(self, hw: HardwareModel = V5E) -> float:
        return (
            self.flops * hw.pj_per_flop * 1e-12
            + self.hbm_bytes * hw.pj_per_hbm_byte * 1e-12
            + self.coll_bytes * hw.pj_per_ici_byte * 1e-12
        )


@dataclass
class ModeledRun:
    """Outcome of simulating one schedule."""

    makespan: float
    vector_busy: float
    scalar_busy: float
    launches: int
    host_exchange_bytes: float
    energy_j: float
    detail: list = field(default_factory=list)


# ---------------------------------------------------------------------------
# schedule-level models (mirror core.scheduler / core.sync exactly)
# ---------------------------------------------------------------------------


def model_vector_stream(
    kernels: list[KernelCost], chips: int, hw: HardwareModel = V5E
) -> tuple[float, float]:
    """(seconds, energy) for a controller draining kernels on `chips` chips."""
    t = 0.0
    e = 0.0
    for k in kernels:
        t += hw.launch_overhead + k.device_seconds(chips, hw)
        e += k.energy_j(hw) + hw.j_per_launch
    return t, e


def model_mixed_split(
    kernels: list[KernelCost],
    scalar_seconds: float,
    chips_per_pod: int,
    hw: HardwareModel = V5E,
) -> ModeledRun:
    """Paper's SM penalty case: scalar queue consumes controller-1 (its pod
    idles); ALL vector work runs on pod-0's chips."""
    t_vec, e = model_vector_stream(kernels, chips_per_pod, hw)
    makespan = max(t_vec, scalar_seconds)
    return ModeledRun(
        makespan=makespan,
        vector_busy=t_vec,
        scalar_busy=scalar_seconds,
        launches=len(kernels),
        host_exchange_bytes=0.0,
        energy_j=e,
    )


def model_mixed_merge(
    kernels: list[KernelCost],
    scalar_seconds: float,
    total_chips: int,
    hw: HardwareModel = V5E,
    merge_coll_penalty: float = 0.0,
) -> ModeledRun:
    """MM: vector stream on the fused fabric; scalar work fully overlapped on
    the freed controller. merge_coll_penalty: extra per-kernel collective
    bytes for the pod-spanning axis (cross-pod DP sync), if any."""
    adj = [
        KernelCost(k.name, k.flops, k.hbm_bytes, k.coll_bytes + merge_coll_penalty)
        for k in kernels
    ]
    t_vec, e = model_vector_stream(adj, total_chips, hw)
    makespan = max(t_vec, scalar_seconds)
    return ModeledRun(
        makespan=makespan,
        vector_busy=t_vec,
        scalar_busy=scalar_seconds,
        launches=len(kernels),
        host_exchange_bytes=0.0,
        energy_j=e,
    )


def model_staged_split(
    phase: KernelCost,
    rounds: int,
    exchange_bytes: float,
    chips_per_pod: int,
    n_pods: int = 2,
    hw: HardwareModel = V5E,
    exchange_over: str = "ici",
) -> ModeledRun:
    """Split/baseline execution of a two-phase sync-bound kernel.

    Per round: 2 × (per-pod phase program + barrier) + a host-orchestrated
    corner-turn exchange. The pods ARE physically linked, so by default the
    exchange program still moves bytes over ICI (``exchange_over='ici'``) —
    but it is a SEPARATE launch per pod with barriers, and nothing overlaps
    (phases, exchange, and sync serialize). ``exchange_over='pcie'`` models
    the worst case where data is staged through the hosts (what
    core.sync.run_split_staged literally does on this container).
    """
    total_chips = chips_per_pod * n_pods
    per_phase = KernelCost(
        phase.name, phase.flops / n_pods, phase.hbm_bytes / n_pods, 0.0
    )
    if exchange_over == "ici":
        t_x = exchange_bytes / (total_chips * hw.ici_bw)
        x_host_bytes = 0.0
    else:
        t_x = 2 * exchange_bytes / hw.pcie_bw
        x_host_bytes = 2 * exchange_bytes
    t = 0.0
    e = 0.0
    launches = 0
    for _ in range(rounds):
        for _ in range(2):  # phase_a, phase_b
            t += hw.launch_overhead + per_phase.device_seconds(chips_per_pod, hw)
            t += hw.barrier_overhead
            launches += n_pods
            e += phase.energy_j(hw) + n_pods * hw.j_per_launch
        # two corner-turn exchange programs per round (turn + restore), each
        # its own launch + barrier on both pods
        t += 2 * (t_x + hw.launch_overhead + hw.barrier_overhead)
        launches += 2 * n_pods
        e += 2 * (
            exchange_bytes * hw.pj_per_ici_byte * 1e-12 + n_pods * hw.j_per_launch
        )
    return ModeledRun(
        makespan=t,
        vector_busy=t,
        scalar_busy=0.0,
        launches=launches,
        host_exchange_bytes=2 * x_host_bytes * rounds,
        energy_j=e,
    )


def model_staged_merge(
    phase: KernelCost,
    rounds: int,
    exchange_bytes: float,
    total_chips: int,
    hw: HardwareModel = V5E,
) -> ModeledRun:
    """Merged execution: ONE program for all rounds; exchanges are on-device
    all-to-alls on ICI; a single dispatch; and — the key merge-mode win —
    the scheduler OVERLAPS round r's collective with round r±1's compute
    (async collectives inside one program), so the makespan is
    launch + max(Σcompute, Σcomm) + one un-overlappable pipeline fill."""
    t_phase = 2 * rounds * phase.device_seconds(total_chips, hw)
    t_x_one = 2 * exchange_bytes / (total_chips * hw.ici_bw)
    t_x = rounds * t_x_one
    t = hw.launch_overhead + max(t_phase, t_x) + min(t_phase, t_x_one)
    e = hw.j_per_launch + 2 * rounds * phase.energy_j(hw) + (
        2 * rounds * exchange_bytes * hw.pj_per_ici_byte * 1e-12
    )
    return ModeledRun(
        makespan=t,
        vector_busy=t,
        scalar_busy=0.0,
        launches=1,
        host_exchange_bytes=0.0,
        energy_j=e,
    )
