"""Operational modes of the reconfigurable cluster (paper §II).

* ``SPLIT``  — the fabric is partitioned on the ``pod`` axis into independent
  sub-meshes ("vector units"), each driven by its own controller thread
  ("scalar core"). Two vectorizable workloads proceed concurrently.
* ``MERGE``  — one controller drives the fused fabric (the ``pod`` axis folds
  into the data axes: doubled effective vector length); the freed controller
  threads execute scalar/control tasks that overlap with device compute.

The mode is a runtime property (paper: "the operational mode can also change
at runtime") — see :mod:`repro.core.reconfigure` for the live-state reshard.

The SAME two modes drive the serving cluster (:mod:`repro.serve.cluster`):
SPLIT is one independent engine replica per device behind a
join-shortest-queue router (the router is the scalar control core), MERGE is
one tensor-parallel engine over every device (the fused vector fabric), and
``ServeCluster.reconfigure`` is the runtime switch whose measured cost plays
the paper's CSR-write number.
"""

from __future__ import annotations

import enum


class Mode(str, enum.Enum):
    SPLIT = "split"
    MERGE = "merge"

    @classmethod
    def parse(cls, value: "Mode | str") -> "Mode":
        """Accepts a ``Mode`` or its string value (CLI flags, configs)."""
        if isinstance(value, Mode):
            return value
        return cls(str(value).lower())

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value
