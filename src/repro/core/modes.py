"""Operational modes of the reconfigurable cluster (paper §II).

* ``SPLIT``  — the fabric is partitioned on the ``pod`` axis into independent
  sub-meshes ("vector units"), each driven by its own controller thread
  ("scalar core"). Two vectorizable workloads proceed concurrently.
* ``MERGE``  — one controller drives the fused fabric (the ``pod`` axis folds
  into the data axes: doubled effective vector length); the freed controller
  threads execute scalar/control tasks that overlap with device compute.

The mode is a runtime property (paper: "the operational mode can also change
at runtime") — see :mod:`repro.core.reconfigure` for the live-state reshard.
"""

from __future__ import annotations

import enum


class Mode(str, enum.Enum):
    SPLIT = "split"
    MERGE = "merge"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value
