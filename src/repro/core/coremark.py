"""CoreMark-analogue scalar workload (paper §III "Mixed scalar-vector").

EEMBC CoreMark exercises three pillars of scalar/control performance:
list processing (pointer chasing), matrix manipulation (small integer
matmul), and a state machine with CRC validation. This module reimplements
those pillars in pure Python — deliberately host-bound, branchy, and
GIL-holding between bytecodes — to model the control/sequential tasks a
freed controller runs in merge mode (telemetry digestion, request admission
control, config state machines, manifest checksums).

The returned checksum makes the work non-elidable and lets tests assert
determinism.
"""

from __future__ import annotations

import time
from dataclasses import dataclass


def _crc16(data: bytes, crc: int = 0) -> int:
    for byte in data:
        crc ^= byte
        for _ in range(8):
            crc = (crc >> 1) ^ 0xA001 if crc & 1 else crc >> 1
    return crc & 0xFFFF


def _list_pillar(n: int, seed: int) -> int:
    """Linked-list build / find / reverse / sort (pointer-chasing analogue)."""
    vals = [(seed + i * 2654435761) % 1000 for i in range(n)]
    head: list = []
    for v in vals:
        head.append(v)
    # find middle elements repeatedly (sequential scans)
    acc = 0
    for probe in vals[:: max(n // 17, 1)]:
        try:
            acc += head.index(probe)
        except ValueError:  # pragma: no cover
            pass
    head.reverse()
    head.sort()
    return (acc + head[n // 2]) & 0xFFFF


def _matrix_pillar(dim: int, seed: int) -> int:
    """Small integer matrix multiply + transpose, pure Python."""
    a = [[(seed + i * dim + j) % 7 for j in range(dim)] for i in range(dim)]
    b = [[(seed + j * dim + i) % 5 for j in range(dim)] for i in range(dim)]
    c = [[0] * dim for _ in range(dim)]
    for i in range(dim):
        ai = a[i]
        ci = c[i]
        for k in range(dim):
            aik = ai[k]
            bk = b[k]
            for j in range(dim):
                ci[j] += aik * bk[j]
    return sum(c[i][i] for i in range(dim)) & 0xFFFF


_STATES = ("START", "INT", "FLOAT", "EXP", "SCI", "INVALID")


def _state_pillar(n: int, seed: int) -> int:
    """Numeric-format state machine over a synthetic character stream."""
    stream = []
    x = seed or 1
    for _ in range(n):
        x = (x * 1103515245 + 12345) & 0x7FFFFFFF
        stream.append("0123456789.eE+-,"[x % 16])
    state = "START"
    counts = dict.fromkeys(_STATES, 0)
    for ch in stream:
        if ch == ",":
            counts[state] += 1
            state = "START"
        elif ch.isdigit():
            state = {"START": "INT", "FLOAT": "FLOAT", "EXP": "SCI"}.get(state, state)
        elif ch == ".":
            state = "FLOAT" if state in ("START", "INT") else "INVALID"
        elif ch in "eE":
            state = "EXP" if state in ("INT", "FLOAT") else "INVALID"
        elif ch in "+-":
            state = state if state == "EXP" else "INVALID"
    return sum((i + 1) * v for i, v in enumerate(counts.values())) & 0xFFFF


@dataclass
class CoreMarkResult:
    iterations: int
    seconds: float
    checksum: int

    @property
    def iters_per_sec(self) -> float:
        return self.iterations / max(self.seconds, 1e-12)


def coremark(iterations: int = 10, *, list_n: int = 300, mat_dim: int = 12,
             state_n: int = 600, seed: int = 0x3415) -> CoreMarkResult:
    """Run the scalar workload; one iteration ≈ one CoreMark loop."""
    t0 = time.perf_counter()
    crc = 0
    for it in range(iterations):
        s = seed + it
        crc = _crc16(_list_pillar(list_n, s).to_bytes(2, "little"), crc)
        crc = _crc16(_matrix_pillar(mat_dim, s).to_bytes(2, "little"), crc)
        crc = _crc16(_state_pillar(state_n, s).to_bytes(2, "little"), crc)
    return CoreMarkResult(iterations, time.perf_counter() - t0, crc)
