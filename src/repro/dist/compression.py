"""Int8 gradient compression: symmetric per-chunk quantization + a ring
all-reduce that moves int8 payloads (+ f32 scales) instead of f32 gradients.

Used by the compressed-DP train step (:mod:`repro.train.step`) together with
error feedback: the quantization residual is carried to the next step, so the
running sum of transmitted gradients tracks the true sum (the EF-SGD
invariant, property-tested in ``tests/test_property.py``).

Quantization contract (pinned by the tests):

* ``scale = amax / 127`` per chunk, round-to-nearest → per-element error is
  at most ``scale / 2 = amax / 254``;
* any element with ``|x| > scale`` keeps its sign through the round trip.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# 1 KiB of int8 payload per f32 scale — ~0.4% scale overhead.
CHUNK = 1024

_INT8_MAX = 127.0


def quantize(x: jax.Array, *, chunk: int = CHUNK) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with one f32 scale per ``chunk`` elements.

    Returns ``(q, scales)`` where ``q`` is int8 with x's shape and ``scales``
    is f32 ``[ceil(x.size / chunk)]`` (a scalar when one chunk suffices, so
    ``float(scale)`` works for small tensors). Wire payload: 1 byte/element +
    the scales — ~3.98× smaller than f32.
    """
    x = jnp.asarray(x, jnp.float32)
    flat = x.reshape(-1)
    n = flat.shape[0]
    n_chunks = max(-(-n // chunk), 1)
    padded = jnp.pad(flat, (0, n_chunks * chunk - n)).reshape(n_chunks, chunk)
    amax = jnp.max(jnp.abs(padded), axis=1)
    scale = jnp.where(amax > 0, amax, 1.0) / _INT8_MAX
    q = jnp.clip(jnp.round(padded / scale[:, None]), -_INT8_MAX, _INT8_MAX)
    q = q.astype(jnp.int8).reshape(-1)[:n].reshape(x.shape)
    return q, (scale[0] if n_chunks == 1 else scale)


def dequantize(q: jax.Array, scale: jax.Array, *, chunk: int = CHUNK) -> jax.Array:
    """Inverse of :func:`quantize`; returns f32 with ``q``'s shape."""
    flat = q.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    scale = jnp.atleast_1d(scale)
    n_chunks = scale.shape[0]
    padded = jnp.pad(flat, (0, n_chunks * chunk - n)).reshape(n_chunks, chunk)
    out = padded * scale[:, None]
    return out.reshape(-1)[:n].reshape(q.shape)


def ring_allreduce_q8(x: jax.Array, axis_name: str, *, chunk: int = CHUNK) -> jax.Array:
    """Mean all-reduce over ``axis_name`` with int8-compressed hops.

    Runs inside ``shard_map``: each device quantizes its local tensor once,
    then int8 payloads (+ scales) travel the ring; every device dequantizes
    and accumulates in f32. The local contribution is also routed through the
    quantizer so all ranks see identically-compressed terms.
    """
    p = int(jax.lax.psum(1, axis_name))
    q, scale = quantize(x, chunk=chunk)
    acc = dequantize(q, scale, chunk=chunk)
    if p == 1:
        return acc
    perm = [(j, (j + 1) % p) for j in range(p)]
    buf_q, buf_s = q, scale
    for _ in range(p - 1):
        buf_q = jax.lax.ppermute(buf_q, axis_name, perm)
        buf_s = jax.lax.ppermute(buf_s, axis_name, perm)
        acc = acc + dequantize(buf_q, buf_s, chunk=chunk)
    return acc / p


def allreduce_pytree_q8(tree: Any, axis_name: str, *, chunk: int = CHUNK) -> Any:
    """Leaf-wise :func:`ring_allreduce_q8` over a gradient pytree."""
    return jax.tree.map(
        lambda leaf: ring_allreduce_q8(leaf, axis_name, chunk=chunk), tree
    )
