"""Int8 compression: symmetric per-chunk quantization (training collectives)
plus per-row quantization (the serving KV cache's insert-time path).

Used by the compressed-DP train step (:mod:`repro.train.step`) together with
error feedback: the quantization residual is carried to the next step, so the
running sum of transmitted gradients tracks the true sum (the EF-SGD
invariant, property-tested in ``tests/test_property.py``). The per-row
variants back the quantized serving cache (:mod:`repro.serve.engine`
``kv_dtype=``): one f32 scale per (position, head) row, updated O(written
rows) at insert time.

Quantization contract (pinned by the tests):

* ``scale = amax / 127`` per chunk, round-to-nearest → per-element error is
  at most ``scale / 2 = amax / 254``;
* any element with ``|x| > scale`` keeps its sign through the round trip.

Everything here is pad-free: the tail chunk is reduced and scaled as its own
segment instead of materializing a padded copy — these functions jit into
serving ticks, where the repo's no-``jnp.pad`` jaxpr convention is pinned by
``tests/test_hot_path.py``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# 1 KiB of int8 payload per f32 scale — ~0.4% scale overhead.
CHUNK = 1024

_INT8_MAX = 127.0
_F8_MAX = 448.0  # float8_e4m3fn max finite value


def quantize(x: jax.Array, *, chunk: int = CHUNK) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with one f32 scale per ``chunk`` elements.

    Returns ``(q, scales)`` where ``q`` is int8 with x's shape and ``scales``
    is f32 ``[ceil(x.size / chunk)]`` (a scalar when one chunk suffices, so
    ``float(scale)`` works for small tensors). Wire payload: 1 byte/element +
    the scales — ~3.98× smaller than f32. Pad-free: the full-chunk body and
    the tail overhang are quantized as separate segments (shapes are static
    at trace time, so the split is free) instead of padding to a rectangle.
    """
    x = jnp.asarray(x, jnp.float32)
    flat = x.reshape(-1)
    n = flat.shape[0]
    if n == 0:  # degenerate: one all-zero chunk's scale, empty payload
        return flat.astype(jnp.int8), jnp.float32(1.0 / _INT8_MAX)
    n_full = n // chunk
    rem = n - n_full * chunk
    body = flat[: n_full * chunk].reshape(max(n_full, 1), -1)
    tail = flat[n_full * chunk:]
    amaxes = []
    if n_full:
        amaxes.append(jnp.max(jnp.abs(body), axis=1))
    if rem:
        amaxes.append(jnp.max(jnp.abs(tail), keepdims=True))
    amax = amaxes[0] if len(amaxes) == 1 else jnp.concatenate(amaxes)
    scale = jnp.where(amax > 0, amax, 1.0) / _INT8_MAX
    parts = []
    if n_full:
        qb = jnp.clip(
            jnp.round(body / scale[:n_full, None]), -_INT8_MAX, _INT8_MAX
        )
        parts.append(qb.reshape(-1))
    if rem:
        qt = jnp.clip(jnp.round(tail / scale[-1]), -_INT8_MAX, _INT8_MAX)
        parts.append(qt)
    q = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    q = q.astype(jnp.int8).reshape(x.shape)
    n_chunks = n_full + (1 if rem else 0)
    return q, (scale[0] if n_chunks == 1 else scale)


def dequantize(q: jax.Array, scale: jax.Array, *, chunk: int = CHUNK) -> jax.Array:
    """Inverse of :func:`quantize`; returns f32 with ``q``'s shape."""
    flat = q.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    if n == 0:
        return flat.reshape(q.shape)
    scale = jnp.atleast_1d(scale)
    n_full = n // chunk
    rem = n - n_full * chunk
    parts = []
    if n_full:
        body = flat[: n_full * chunk].reshape(n_full, chunk)
        parts.append((body * scale[:n_full, None]).reshape(-1))
    if rem:
        parts.append(flat[n_full * chunk:] * scale[n_full])
    out = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return out.reshape(q.shape)


# --------------------------------------------------------------- row-wise
# The serving KV cache's quantization granularity: one scale per row over
# the LAST axis (a (position, head) row of head_dim elements). Insert-time
# quantization touches only the written rows' scales — O(rows written), not
# O(cache) — and the scales live in the cache pytree so they travel through
# paged block tables, prefix COW sharing and cluster re-homing with the
# int8 payload they describe.


def quantize_rows(x: jax.Array, store_dtype: Any) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row quantization over the last axis: ``(values,
    scales)`` with one f32 scale per row. ``store_dtype=float32`` is the
    identity lane — values pass through untouched with all-ones scales, so
    the quantized *machinery* at f32 storage is bit-identical to the plain
    path (``x * 1.0 == x`` in IEEE f32), which is what makes the quantized
    code paths testable against the dense engine."""
    dt = jnp.dtype(store_dtype)
    if dt == jnp.float32:
        return x, jnp.ones(x.shape[:-1], jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    if dt == jnp.dtype(jnp.float8_e4m3fn):
        # fp8 store: scale rows to the e4m3 representable range, keep the
        # same per-row f32 scales — the dequant path is dtype-generic
        scale = jnp.where(amax > 0, amax, 1.0) / _F8_MAX
        q = jnp.clip(x / scale[..., None], -_F8_MAX, _F8_MAX)
        return q.astype(dt), scale
    scale = jnp.where(amax > 0, amax, 1.0) / _INT8_MAX
    q = jnp.clip(jnp.round(x / scale[..., None]), -_INT8_MAX, _INT8_MAX)
    return q.astype(jnp.int8), scale


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_rows`; f32 with ``q``'s shape."""
    return q.astype(jnp.float32) * scale[..., None]


def ring_allreduce_q8(x: jax.Array, axis_name: str, *, chunk: int = CHUNK) -> jax.Array:
    """Mean all-reduce over ``axis_name`` with int8-compressed hops.

    Runs inside ``shard_map``: each device quantizes its local tensor once,
    then int8 payloads (+ scales) travel the ring; every device dequantizes
    and accumulates in f32. The local contribution is also routed through the
    quantizer so all ranks see identically-compressed terms.
    """
    p = int(jax.lax.psum(1, axis_name))
    q, scale = quantize(x, chunk=chunk)
    acc = dequantize(q, scale, chunk=chunk)
    if p == 1:
        return acc
    perm = [(j, (j + 1) % p) for j in range(p)]
    buf_q, buf_s = q, scale
    for _ in range(p - 1):
        buf_q = jax.lax.ppermute(buf_q, axis_name, perm)
        buf_s = jax.lax.ppermute(buf_s, axis_name, perm)
        acc = acc + dequantize(buf_q, buf_s, chunk=chunk)
    return acc / p


def allreduce_pytree_q8(tree: Any, axis_name: str, *, chunk: int = CHUNK) -> Any:
    """Leaf-wise :func:`ring_allreduce_q8` over a gradient pytree."""
    return jax.tree.map(
        lambda leaf: ring_allreduce_q8(leaf, axis_name, chunk=chunk), tree
    )
