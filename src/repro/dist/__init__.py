"""Distribution layer: mesh views, rule-based shardings, ring collectives,
and int8 gradient compression.

This is the substrate the Spatzformer SPLIT/MERGE machinery is built on:
:class:`repro.dist.sharding.MeshInfo` is the per-mode view object that
``SpatzformerCluster.merge_info()`` / ``split_infos()`` hand out, and
reshard-on-mode-switch (the paper's CSR-write reconfiguration analogue) is
``jax.device_put`` onto shardings produced by the rules here.
"""

from repro.dist import collectives, compression, sharding
from repro.dist.sharding import (
    MeshInfo,
    batch_shardings,
    opt_shardings,
    param_shardings,
    replicated,
    single_device_mesh_info,
    spec_for_param,
)

__all__ = [
    "MeshInfo",
    "batch_shardings",
    "collectives",
    "compression",
    "opt_shardings",
    "param_shardings",
    "replicated",
    "sharding",
    "single_device_mesh_info",
    "spec_for_param",
]
