"""Rule-based sharding: axis roles over a mesh + path/shape partition rules.

Two layers:

* :class:`MeshInfo` — a mesh plus axis ROLES. The same physical fabric is
  viewed differently per Spatzformer mode: MERGE folds the ``pod`` axis into
  the batch axes (one fused data-parallel fabric), SPLIT hands each pod its
  own standalone ``(data, model)`` view. ``tp_enabled=False`` additionally
  demotes the ``model`` axis to a batch axis (the DP+ZeRO strategies in
  ``launch/dryrun.py``).
* ``spec_for_param`` and friends — pure partition rules keyed on a leaf's
  pytree path and shape, shared by params, optimizer state and batches so a
  reshard between any two :class:`MeshInfo` views is always well-defined.

Hard-won rules pinned by ``tests/test_sharding_rules.py``:

* a stacked-layer leading dim (ndim ≥ 3) is NEVER sharded — the scan over
  layers would otherwise all-gather the full stack every step (the 6×7 GB
  regression caught in the dry-run artifact);
* MoE expert stacks ``[L, E, d, f]`` shard the EXPERT dim (expert
  parallelism feeds the ``shard_map`` in :mod:`repro.models.moe`);
* GQA attention ``[L, d, kv_heads, head_dim]`` prefers the heads dim and
  falls back to head_dim when ``kv_heads`` isn't divisible (kv=8 on TP-16);
* embeddings prefer the vocab dim, falling back to d_model for odd vocabs
  (minicpm3's 73448);
* ``model_size == 1`` replicates everything.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"

# Leaves below this element count replicate (norm gains, biases, scalars):
# sharding them saves nothing and invites involuntary gathers in scanned
# stacks. Kept well under any weight matrix of the assigned archs.
MIN_SHARD_ELEMS = 2**16

# FSDP second-dim sharding kicks in above this leaf element count by default
# (callers tune it down for optimizer state, e.g. dryrun's 2**22).
DEFAULT_FSDP_THRESHOLD = 2**24


# =============================================================================
# MeshInfo: a mesh plus axis roles
# =============================================================================


@dataclass(frozen=True)
class MeshInfo:
    """A device mesh annotated with which axes carry the batch and whether
    tensor parallelism over the ``model`` axis is active.

    ``batch_axes`` may include ``"model"`` (with ``tp_enabled=False``) for the
    DP+ZeRO strategies: the model axis then counts toward ``data_size`` and
    ``model_size`` reports 1.
    """

    mesh: Mesh
    batch_axes: tuple[str, ...] = ("data",)
    tp_enabled: bool = True

    # ------------------------------------------------------------------ sizes

    @property
    def n_devices(self) -> int:
        return int(self.mesh.size)

    @property
    def data_size(self) -> int:
        """Total data-parallel degree: product of the batch axes' sizes."""
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes], dtype=np.int64))

    @property
    def model_axis(self) -> Optional[str]:
        """The tensor-parallel axis name, or None when TP is off."""
        if not self.tp_enabled:
            return None
        if MODEL_AXIS not in self.mesh.axis_names or MODEL_AXIS in self.batch_axes:
            return None
        return MODEL_AXIS

    @property
    def model_size(self) -> int:
        ax = self.model_axis
        return int(self.mesh.shape[ax]) if ax is not None else 1

    # ------------------------------------------------------------------ specs

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constraint(self, x: jax.Array, spec: P) -> jax.Array:
        return jax.lax.with_sharding_constraint(x, self.named(spec))

    def batch_spec(self, ndim: int) -> P:
        """P with the batch axes on dim 0 and the rest replicated."""
        return P(self.batch_axes, *([None] * (ndim - 1)))


def single_device_mesh_info() -> MeshInfo:
    """Degenerate 1-device ``(data, model)`` view — the fallback fabric when
    ``len(jax.devices()) == 1`` (laptops, the fast CI lane)."""
    grid = np.array(jax.devices()[:1]).reshape(1, 1)
    return MeshInfo(Mesh(grid, ("data", MODEL_AXIS)), batch_axes=("data",))


def serving_mesh_info(devices: Optional[Any] = None) -> MeshInfo:
    """Merged serving fabric: ONE ``(data=1, model=N)`` view over the given
    devices — the whole cluster becomes a single tensor-parallel engine
    (the serving analogue of Spatzformer's merge mode: one controller, all
    lanes fused). Degenerates gracefully to the single-device view."""
    devs = list(devices) if devices is not None else list(jax.devices())
    grid = np.array(devs).reshape(1, len(devs))
    return MeshInfo(Mesh(grid, ("data", MODEL_AXIS)), batch_axes=("data",))


# =============================================================================
# partition rules
# =============================================================================


def _divisible(dim: int, by: int) -> bool:
    return dim >= by and dim % by == 0


def spec_for_param(path: str, ndim: int, shape: tuple[int, ...], model_size: int) -> P:
    """Tensor-parallel PartitionSpec for one parameter leaf.

    ``path`` is the ``jax.tree_util.keystr`` rendering of the leaf's pytree
    path (e.g. ``"['blocks']['attn']['wk']"``); rules key on substrings so the
    same rules apply when the tree is nested under optimizer-state prefixes.
    """
    if model_size <= 1 or ndim == 0:
        return P()
    parts: list[Any] = [None] * ndim
    # stacked-layer stacks [L, ...]: dim 0 is scanned over, never sharded
    first = 1 if ndim >= 3 else 0

    # MoE expert stacks [L, E, d, f]: expert parallelism on the expert dim.
    # Matched on the exact `['moe']` segment — attention params under
    # `moe_blocks` must NOT take this branch (their dim 1 is d_model, which
    # always divides TP and would defeat the heads/head_dim rule below).
    # The shared expert nested under the moe subtree is a plain MLP and
    # falls through to the generic rule.
    if "['moe']" in path and "shared" not in path and ndim == 4:
        if _divisible(shape[1], model_size):
            parts[1] = MODEL_AXIS
            return P(*parts)

    # Attention projections [L, d, (kv_)heads, head_dim]: heads first (clean
    # head parallelism), head_dim as the GQA fallback (kv_heads < TP degree).
    if "attn" in path and ndim == 4:
        for dim in (2, 3):
            if _divisible(shape[dim], model_size):
                parts[dim] = MODEL_AXIS
                return P(*parts)

    # Generic rule: the largest shardable dim wins. Vocab→d_model fallback
    # for embeddings falls out of this (prefer the bigger vocab dim when it
    # divides, else d_model).
    for dim in sorted(range(first, ndim), key=lambda d: shape[d], reverse=True):
        if _divisible(shape[dim], model_size):
            parts[dim] = MODEL_AXIS
            return P(*parts)
    return P()


def _add_fsdp_dim(
    spec: P,
    shape: tuple[int, ...],
    info: MeshInfo,
    data_size: int,
    threshold: int = DEFAULT_FSDP_THRESHOLD,
) -> P:
    """ZeRO/FSDP second-dim sharding: put the batch axes on the largest free
    dim of a big leaf. The stacked-layer dim 0 (ndim ≥ 3) is never eligible —
    same regression guard as :func:`spec_for_param`."""
    ndim = len(shape)
    if ndim == 0 or math.prod(shape) < threshold:
        return spec
    parts: list[Any] = list(spec) + [None] * (ndim - len(spec))
    first = 1 if ndim >= 3 else 0
    candidates = [
        d
        for d in range(first, ndim)
        if parts[d] is None and _divisible(shape[d], max(data_size, 1))
    ]
    if not candidates:
        return spec
    best = max(candidates, key=lambda d: shape[d])
    parts[best] = info.batch_axes
    return P(*parts)


def spec_for_batch(shape: tuple[int, ...], data_size: int, batch_axes: tuple[str, ...]) -> P:
    """Batch-leaf spec: shard dim 0 over the batch axes when divisible,
    replicate otherwise (odd global batches, scalars)."""
    if not shape or data_size <= 0 or not _divisible(shape[0], max(data_size, 1)):
        return P()
    return P(batch_axes, *([None] * (len(shape) - 1)))


# =============================================================================
# pytree builders
# =============================================================================


def param_shardings(
    tree: Any,
    info: MeshInfo,
    *,
    fsdp: bool = False,
    fsdp_threshold: int = DEFAULT_FSDP_THRESHOLD,
) -> Any:
    """NamedSharding pytree for params (or anything param-shaped: grads,
    optimizer moments, EF residuals). Pass ``fsdp=True`` to additionally
    shard big leaves over the batch axes (ZeRO-style)."""
    model_size = info.model_size

    def one(path, leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 0 or math.prod(shape) < MIN_SHARD_ELEMS:
            spec = P()
        else:
            spec = spec_for_param(
                jax.tree_util.keystr(path), len(shape), shape, model_size
            )
        if fsdp:
            spec = _add_fsdp_dim(spec, shape, info, info.data_size, fsdp_threshold)
        return info.named(spec)

    return jax.tree_util.tree_map_with_path(one, tree)


def opt_shardings(opt_tree: Any, info: MeshInfo, **kwargs: Any) -> Any:
    """Optimizer-state shardings: moments mirror their parameter's spec (the
    rules key on path substrings, so the param subtree nested inside the
    AdamW state resolves identically); scalar ``step`` replicates."""
    return param_shardings(opt_tree, info, **kwargs)


def batch_shardings(tree: Any, info: MeshInfo) -> Any:
    """NamedSharding pytree for a data batch: leading dim over the batch
    axes, replicated fallback when the batch doesn't divide ``data_size``."""
    data_size = info.data_size
    return jax.tree.map(
        lambda leaf: info.named(
            spec_for_batch(tuple(leaf.shape), data_size, info.batch_axes)
        ),
        tree,
    )


def replicated(info: MeshInfo) -> NamedSharding:
    """Fully-replicated sharding on this view (scalars, metrics)."""
    return info.named(P())


# =============================================================================
# serving shardings (merge-mode tensor-parallel engine)
# =============================================================================


# cache leaves whose dim 2 is the SEQUENCE axis ([L, B, S, ...]): the
# attention K/V pools, the hybrid shared-block pools, the MLA latent/rope
# caches (see LM.init_cache), and the quantized cache's per-row scale
# leaves [L, B, S, KV] (their KV dim shards with the payload's KV heads;
# a replicated fallback still broadcasts cleanly against a head_dim-sharded
# payload). Everything else is recurrent state with no positional axis.
_SEQ_CACHE_KEYS = frozenset(
    {"k", "v", "k_scale", "v_scale", "attn_k", "attn_v", "ckv", "krope"}
)


def serve_cache_shardings(cache_shape: Any, info: MeshInfo) -> Any:
    """KV-cache placement for the SERVING slot pool — ``[L, B_slots, S_max,
    KV, hd]`` / MLA ``[L, B_slots, S_max, rank]`` leaves plus SSM state
    ``[L, B_slots, ...]``.

    Differs from training-time ``LM.cache_shardings`` on purpose: the
    serving engine scatters single rows at arbitrary ``(slot, pos)`` every
    tick, so the slot (B) and sequence (S) dims are NEVER sharded — a
    model-axis split of either would turn every O(1) cache write into a
    cross-shard exchange. Positional caches (leaf names in
    ``_SEQ_CACHE_KEYS``) partition only dims past the sequence axis: KV
    heads first (clean head parallelism, matching ``spec_for_param``'s
    attention rule), head_dim/latent-rank as the fallback. Recurrent SSM
    leaves take their widest trailing dim ≥ dim 2. The layer stack dim 0 is
    never sharded.
    """
    ms = info.model_size

    def leaf_spec(path, leaf):
        parts: list[Any] = [None] * leaf.ndim
        if ms > 1 and leaf.ndim >= 2:
            name = getattr(path[-1], "key", None) if path else None
            if name in _SEQ_CACHE_KEYS:
                # [L, B, S, ...]: only dims PAST the sequence axis are
                # eligible — (kv_)heads first on 5-D, head_dim/rank last
                order = [d for d in (leaf.ndim - 2, leaf.ndim - 1) if d >= 3]
            else:
                # SSM conv/recurrent state [L, B, ...]: widest trailing dim
                order = sorted(
                    range(2, leaf.ndim), key=lambda d: leaf.shape[d], reverse=True
                )
            for d in order:
                if _divisible(leaf.shape[d], ms):
                    parts[d] = MODEL_AXIS
                    break
        return info.named(P(*parts))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def serve_state_shardings(tree: Any, info: MeshInfo) -> Any:
    """Per-slot engine state (last tokens, cur_len, override lanes, PRNG
    key): pure control state, replicated on every shard — the merged
    fabric runs under one controller, so every device sees the identical
    slot bookkeeping."""
    return jax.tree.map(lambda _: replicated(info), tree)
