"""Ring collective matmuls for explicit ``shard_map`` programs.

These are the hand-rolled analogues of the collective-matmul fusions XLA
emits for TP: matmul chunks interleave with ``ppermute`` hops so the wire
time hides behind compute. They run inside ``jax.shard_map`` bodies — each
function sees its LOCAL shard and the mesh axis name to ring over.

Validated against dense oracles in ``tests/test_multidev.py``:

* ``ring_rs_matmul`` — x:[M, K/p] · w:[K/p, N] → y:[M/p, N]; the partial
  products are ring reduce-scattered so every device ends with its own
  fully-summed row block (the "megatron row-parallel" output pattern).
* ``ring_ag_matmul`` — x:[M/p, K] · w:[K, N/p] → y:[M, N/p]; x row blocks
  travel the ring, each hop contributing one output block (all-gather
  overlapped with matmul, "column-parallel" input pattern).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def axis_size(axis_name: str) -> int:
    """Static size of a mesh axis from inside a shard_map body.

    ``psum`` of a Python constant is evaluated at trace time, so this is a
    plain int usable for Python-level ring loops.
    """
    return int(jax.lax.psum(1, axis_name))


def ring_rs_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """Matmul + ring reduce-scatter. Local shapes: x [M, K/p], w [K/p, N];
    returns this device's summed row block [M/p, N]."""
    p = axis_size(axis_name)
    partial = jnp.dot(x, w)  # [M, N], partial sum over the local K shard
    if p == 1:
        return partial
    m = partial.shape[0]
    if m % p:
        raise ValueError(f"rows {m} not divisible by axis '{axis_name}' size {p}")
    chunks = partial.reshape(p, m // p, *partial.shape[1:])
    idx = jax.lax.axis_index(axis_name)
    # device j hands its accumulator to j-1 each hop; after p-1 hops device i
    # holds chunk i with all p contributions.
    perm = [(j, (j - 1) % p) for j in range(p)]
    acc = jax.lax.dynamic_index_in_dim(chunks, (idx + 1) % p, 0, keepdims=False)
    for t in range(1, p):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + jax.lax.dynamic_index_in_dim(
            chunks, (idx + 1 + t) % p, 0, keepdims=False
        )
    return acc


def ring_ag_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """All-gather-overlapped matmul. Local shapes: x [M/p, K], w [K, N/p];
    returns the full-row output [M, N/p] (rows in global order)."""
    p = axis_size(axis_name)
    if p == 1:
        return jnp.dot(x, w)
    m = x.shape[0]
    idx = jax.lax.axis_index(axis_name)
    # device j forwards its x block to j+1, so after t hops the buffer holds
    # device (idx - t)'s rows; each hop contributes that block of the output.
    perm = [(j, (j + 1) % p) for j in range(p)]
    out = jnp.zeros((p * m, w.shape[1]), jnp.result_type(x.dtype, w.dtype))
    buf = x
    for t in range(p):
        src = (idx - t) % p
        out = jax.lax.dynamic_update_slice(out, jnp.dot(buf, w), (src * m, 0))
        if t < p - 1:
            buf = jax.lax.ppermute(buf, axis_name, perm)
    return out
