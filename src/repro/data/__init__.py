from repro.data.pipeline import (
    DataConfig,
    PrefetchLoader,
    SyntheticCorpus,
    loader_for,
)

__all__ = ["DataConfig", "SyntheticCorpus", "PrefetchLoader", "loader_for"]
