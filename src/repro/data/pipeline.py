"""Synthetic sharded token pipeline with host-side prefetch.

Deterministic per (epoch, step, shard): every batch is reproducible for
checkpoint-restart (the loader state is just an integer step). A background
prefetch thread keeps ``prefetch`` batches ready — in MERGE mode this thread
is one of the scalar tasks living on the freed controller (the paper's
mixed-workload story applied to the input pipeline).

The "corpus" is a keyed PRNG stream shaped like a tokenized dataset (zipfian
token marginals so embedding-gather patterns are realistic, plus structured
spans so the loss is learnable: each span repeats a seeded pattern the model
can pick up — used by the convergence test in examples/train_lm.py).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pattern_period: int = 16  # learnable structure period


class SyntheticCorpus:
    """Deterministic batches: batch(step) is a pure function of (cfg, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # zipfian-ish marginal over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._probs = probs / probs.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
        b, s = cfg.global_batch, cfg.seq_len
        # learnable periodic structure: seeded pattern repeated along the row
        pat_len = cfg.pattern_period
        patterns = rng.choice(cfg.vocab_size, size=(b, pat_len), p=self._probs)
        reps = int(np.ceil(s / pat_len))
        tokens = np.tile(patterns, (1, reps))[:, :s]
        # sprinkle noise so it's not trivially memorizable
        noise_mask = rng.random((b, s)) < 0.1
        noise = rng.choice(cfg.vocab_size, size=(b, s), p=self._probs)
        tokens = np.where(noise_mask, noise, tokens).astype(np.int32)
        return {"tokens": tokens, "labels": tokens.copy()}


class PrefetchLoader:
    """Background-thread prefetcher over a SyntheticCorpus.

    Restartable: ``PrefetchLoader(corpus, start_step=k)`` resumes exactly
    where a checkpointed run left off.
    """

    def __init__(
        self,
        corpus: SyntheticCorpus,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self.corpus = corpus
        self.step = start_step
        self.prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self.corpus.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def loader_for(arch: ArchConfig, shape: ShapeConfig, seed: int = 0) -> PrefetchLoader:
    return PrefetchLoader(
        SyntheticCorpus(
            DataConfig(
                vocab_size=arch.vocab_size,
                seq_len=shape.seq_len,
                global_batch=shape.global_batch,
                seed=seed,
            )
        )
    )
