"""Int8 weight serving: one-shot post-load quantization of matmul weights.

:func:`quantize_params` replaces each eligible stacked matmul weight leaf
with a ``{"q8": int8, "scale": f32}`` sub-dict — symmetric per-output-channel
quantization (amax over the weight's reduction axes, keepdims so the scale
broadcasts back without reshapes). Resident param bytes drop ~4x from f32
while everything precision-critical stays exact: MoE routers (they feed an
expert argmax — a half-ulp logit flip reroutes a token to a different
expert), norms, embeddings, and the unembedding head are never touched.

:func:`qweight` is the read-through used at every consuming einsum site:
dense leaves pass through untouched (the fully-unquantized path is
byte-identical to before this module existed), quantized leaves dequantize
at the point of use — inside the scanned layer body, so the transient dense
weight exists for ONE layer at a time while the resident stack stays int8.
On TPU the Pallas ``matmul_q8`` kernel (:mod:`repro.kernels.matmul`) is the
fused analogue: the int8 tile is the only RHS HBM traffic and the
per-output-channel dequant multiply folds into the accumulator flush.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

_INT8_MAX = 127.0

# subtrees whose leaves are stacked [L, ...] matmul weights; everything
# outside (embeddings, final norm, hybrid's weight-tied shared block whose
# leaves drop the L axis and so index differently) stays dense
_STACK_KEYS = ("blocks", "dense_blocks", "moe_blocks")


def _reduction_axes(name: str, ndim: int) -> Optional[tuple[int, ...]]:
    """Stacked-weight reduction (input) axes for an eligible leaf name.

    Axis 0 is always L. ``wq/wk/wv`` [L, d, H, hd] contract d; ``wo``
    [L, H, hd, d] contracts (H, hd); the MLP triple is [L, d, ff] /
    [L, ff, d] at ndim 3 and the stacked MoE experts [L, E, d, ff] /
    [L, E, ff, d] at ndim 4 (per-expert scales fall out of keepdims).
    ``router`` is deliberately absent: quantizing it perturbs top-k expert
    selection, a routing flip — not a rounding error.
    """
    if name in ("wq", "wk", "wv"):
        return (1,)
    if name == "wo":
        return (1, 2)
    if name in ("w_in", "w_gate", "w_out"):
        return (1,) if ndim == 3 else (2,)
    return None


def quantize_leaf(w: jax.Array, axes: tuple[int, ...]) -> dict[str, jax.Array]:
    """Symmetric int8 over ``axes`` (keepdims scales): the same
    ``scale = amax/127`` contract as the KV-cache rows and the gradient
    compressor (``repro.dist.compression``)."""
    wf = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=tuple(axes), keepdims=True)
    scale = jnp.where(amax > 0.0, amax, 1.0) / _INT8_MAX
    q = jnp.clip(jnp.round(wf / scale), -_INT8_MAX, _INT8_MAX).astype(jnp.int8)
    return {"q8": q, "scale": scale}


def is_quantized(leaf: Any) -> bool:
    return isinstance(leaf, dict) and "q8" in leaf


def qweight(w: Any, dtype: Any = None) -> jax.Array:
    """Read-through dequant: dense weights pass through verbatim; a
    ``{"q8", "scale"}`` leaf widens in one fused multiply at the einsum
    site (per-layer transient — the resident copy stays int8)."""
    if is_quantized(w):
        dense = w["q8"].astype(jnp.float32) * w["scale"]
        return dense if dtype is None else dense.astype(dtype)
    return w


def quantize_params(params: Any, weight_dtype: Any = "int8") -> Any:
    """Quantize every eligible stacked matmul weight in a params pytree.

    ``weight_dtype`` of ``None``/``"f32"``/``"float32"`` is the identity
    (the tree is returned untouched — opt-in means the default path never
    changes object identity, let alone bytes); ``"int8"`` rewrites eligible
    leaves to ``{"q8", "scale"}`` sub-dicts. The returned tree is a new
    dict structure; unquantized leaves are shared, not copied.
    """
    if weight_dtype in (None, "f32", "float32") or (
        not isinstance(weight_dtype, str)
        and jnp.dtype(weight_dtype) == jnp.float32
    ):
        return params
    if jnp.dtype("int8" if weight_dtype == "i8" else weight_dtype) != jnp.int8:
        raise ValueError(f"unsupported weight dtype: {weight_dtype!r}")

    def walk(tree: Any, in_stack: bool) -> Any:
        if not isinstance(tree, dict):
            return tree
        out = {}
        for name, leaf in tree.items():
            if isinstance(leaf, dict):
                out[name] = walk(leaf, in_stack or name in _STACK_KEYS)
                continue
            axes = (
                _reduction_axes(name, getattr(leaf, "ndim", 0))
                if in_stack
                else None
            )
            if axes is not None and leaf.ndim >= 3:
                out[name] = quantize_leaf(leaf, axes)
            else:
                out[name] = leaf
        return out

    return walk(params, False)
