"""State-space blocks: Mamba1 (selective scan) and Mamba2 (SSD, chunked).

TPU adaptation notes: the CUDA reference implementations are
fused scan kernels; here the recurrences are restructured for TPU:

* **Mamba1**: chunked selective scan — an outer ``lax.scan`` over sequence
  chunks carries the [B, d_in, N] state in VMEM-sized pieces, and an inner
  ``associative_scan`` parallelizes within the chunk (VPU-friendly, avoids
  the [B, S, d_in, N] full-sequence blowup: peak temp is [B, Q, d_in, N]).
* **Mamba2 (SSD)**: the chunked block-matrix form — intra-chunk attention-like
  matmuls (MXU work) plus an inter-chunk state recurrence, exactly the
  decomposition the SSD paper advocates; chunk length is picked so the
  [B, H, Q, Q] intra-chunk score block is MXU-aligned.

Both provide O(1)-state single-token ``decode`` steps (used by decode_32k /
long_500k cells).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, dense_init, rms_norm


# ---------------------------------------------------------------------------
# shared: causal depthwise conv1d
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [B, S, C]; w: [K, C] depthwise; left-padded causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # unrolled taps (K is 4): avoids conv lowering overhead, stays fused
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv_step(state: jax.Array, xt: jax.Array, w: jax.Array, b: jax.Array):
    """Single-token conv: state [B, K-1, C] holds the last K-1 inputs.

    Returns (y [B, C], new_state)."""
    k = w.shape[0]
    full = jnp.concatenate([state, xt[:, None, :]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32), w.astype(jnp.float32))
    y = (y + b.astype(jnp.float32)).astype(xt.dtype)
    return y, full[:, 1:, :]


def _conv_carried(
    x_pre: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array,
    n_real: jax.Array,
):
    """Causal conv over a chunk with a carried cross-chunk tail.

    x_pre: [B, S, C] this chunk's pre-conv rows — the first ``n_real`` are
    real, the rest bucket padding AFTER every real row; conv_state:
    [B, K-1, C] the slot's last K-1 real pre-conv rows (zeros for a fresh
    sequence, which reproduces the left-zero-padded conv exactly).
    Returns (x_c [B, S, C] conv outputs for the S new rows, new_state
    [B, K-1, C] = the last K-1 REAL pre-conv rows, sliced at the dynamic
    chunk length so padding never enters a future window)."""
    k = w.shape[0]
    x_ext = jnp.concatenate([conv_state.astype(x_pre.dtype), x_pre], axis=1)
    x_c = causal_conv1d(x_ext, w, b)[:, k - 1 :, :]
    new_state = jax.lax.dynamic_slice_in_dim(x_ext, n_real, k - 1, axis=1)
    return x_c, new_state


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def mamba1_init(key, cfg: ArchConfig, dtype) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (s.conv_kernel, d_in), dtype, fan_in=s.conv_kernel),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * s.state), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dtype, fan_in=dt_rank),
        "dt_bias": jnp.log(
            jnp.exp(
                jax.random.uniform(ks[4], (d_in,), jnp.float32, 1e-3, 1e-1)
            )
            - 1.0
        ).astype(jnp.float32),
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, s.state + 1, dtype=jnp.float32), (d_in, s.state))
        ),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, d), dtype, fan_in=d_in),
    }


def _selective_scan_chunked(
    x_c: jax.Array,  # [B, S, d_in]  (post-conv, post-silu input)
    dt: jax.Array,  # [B, S, d_in] f32 (softplus'ed)
    A: jax.Array,  # [d_in, N] f32 (negative)
    Bm: jax.Array,  # [B, S, N]
    C: jax.Array,  # [B, S, N]
    h0: jax.Array,  # [B, d_in, N]
    chunk: int,
) -> tuple[jax.Array, jax.Array]:
    """y[b,s,d] = Σ_n h[b,s,d,n]·C[b,s,n] with h_s = exp(dt_s A)·h_{s-1} + dt_s B_s x_s.

    Outer scan over chunks, inner associative scan. The discretized
    [B, Q, d_in, N] tensors (dA, dBx) are materialized PER CHUNK inside the
    (rematted) scan body — never for the full sequence: peak temp is
    O(B·Q·d_in·N), not O(B·S·d_in·N) (which hit 368 GB/device on
    falcon-mamba/train_4k). Returns (y, h_final).
    """
    b, s, d_in = x_c.shape
    n = A.shape[1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs_c = (to_chunks(x_c), to_chunks(dt), to_chunks(Bm), to_chunks(C))

    @jax.checkpoint
    def chunk_body(h, xs):
        xq, dtq, bq, cq = xs  # [B,Q,d_in], [B,Q,d_in], [B,Q,N], [B,Q,N]
        da = jnp.exp(dtq[..., None] * A)  # [B,Q,d_in,N]
        dbx = dtq[..., None] * bq[:, :, None, :].astype(jnp.float32) * xq[
            ..., None
        ].astype(jnp.float32)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        acum, bacc = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        hs = acum * h[:, None] + bacc  # [B, Q, d, N]
        y = jnp.einsum("bqdn,bqn->bqd", hs, cq.astype(jnp.float32))
        return hs[:, -1], y

    h_final, ys = jax.lax.scan(chunk_body, h0, xs_c)
    y = ys.swapaxes(0, 1).reshape(b, s, d_in)
    return y, h_final


def mamba1_apply(
    params: Params, cfg: ArchConfig, x: jax.Array, *, return_state: bool = False
):
    """Full-sequence Mamba1 block. x: [B, S, d] -> [B, S, d].

    ``return_state`` also yields the decode cache {'h', 'conv'} after the
    last token (prefill-into-cache)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in = s_cfg.expand * d
    dt_rank = max(d // 16, 1)
    N = s_cfg.state

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = causal_conv1d(x_in, params["conv_w"], params["conv_b"])
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bsd,de->bse", x_c, params["x_proj"])
    dt_r, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"]
    )  # [B,S,d_in] f32
    A = -jnp.exp(params["A_log"])  # [d_in, N]

    chunk = min(s_cfg.chunk, s)
    if s % chunk:
        chunk = s  # tiny sequences in tests
    h0 = jnp.zeros((b, d_in, N), jnp.float32)
    y, h_final = _selective_scan_chunked(
        x_c, dt, A, Bmat.astype(jnp.float32), Cmat.astype(jnp.float32), h0, chunk
    )
    y = y + params["D"] * x_c.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out_proj"])
    if return_state:
        k = s_cfg.conv_kernel
        tail = x_in[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
            x_in, ((0, 0), (k - 1 - s, 0), (0, 0))
        )
        return out, {"h": h_final, "conv": tail.astype(x.dtype)}
    return out


def mamba1_init_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, d_in, s.state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_kernel - 1, d_in), dtype),
    }


def mamba1_decode(
    params: Params, cfg: ArchConfig, x: jax.Array, cache: Params
) -> tuple[jax.Array, Params]:
    """One token. x: [B, 1, d]."""
    s_cfg = cfg.ssm
    b, _, d = x.shape
    dt_rank = max(d // 16, 1)
    N = s_cfg.state

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])[:, 0]
    x_in, z = jnp.split(xz, 2, axis=-1)  # [B, d_in]
    x_c, conv_state = conv_step(cache["conv"], x_in, params["conv_w"], params["conv_b"])
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bd,de->be", x_c, params["x_proj"])
    dt_r, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,rd->bd", dt_r, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"]
    )
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A)  # [B,d_in,N]
    dBx = dt[..., None] * Bmat[:, None, :].astype(jnp.float32) * x_c[..., None].astype(
        jnp.float32
    )
    h = dA * cache["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cmat.astype(jnp.float32))
    y = y + params["D"] * x_c.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("be,ed->bd", y.astype(x.dtype), params["out_proj"])
    return out[:, None, :], {"h": h, "conv": conv_state}


def mamba1_packed(
    params: Params, cfg: ArchConfig, x: jax.Array, cache: Params,
    n_real: jax.Array,
) -> tuple[jax.Array, Params]:
    """State-passing packed chunk: ONE slot's contiguous prompt chunk (plus
    bucket padding AFTER the real rows) through the chunked selective scan,
    carrying the decode cache {'h', 'conv'} across chunks — constant-memory
    chunked prefill for the serving engine's packed tier.

    x: [B, S, d] with the first ``n_real`` rows real. Padding rows are
    scan identities (dt forced to 0 → exp(0·A) = 1 and dt·B·x = 0, both
    exact in fp) so the returned state is precisely the state after the
    real rows; padding y rows are garbage the caller never samples."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    dt_rank = max(d // 16, 1)
    N = s_cfg.state

    real = jnp.arange(s) < n_real  # [S]
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = _conv_carried(
        x_in, cache["conv"], params["conv_w"], params["conv_b"], n_real
    )
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bsd,de->bse", x_c, params["x_proj"])
    dt_r, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"]
    )
    dt = jnp.where(real[None, :, None], dt, 0.0)  # pads: state identity
    A = -jnp.exp(params["A_log"])
    chunk = min(s_cfg.chunk, s)
    if s % chunk:
        chunk = s
    y, h_final = _selective_scan_chunked(
        x_c, dt, A, Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
        cache["h"], chunk,
    )
    y = y + params["D"] * x_c.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out_proj"])
    return out, {"h": h_final, "conv": conv_state.astype(cache["conv"].dtype)}


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg: ArchConfig, dtype) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * s.n_groups * s.state + nh), dtype),
        "conv_w": dense_init(ks[1], (s.conv_kernel, conv_dim), dtype, fan_in=s.conv_kernel),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (nh,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.exp(jax.random.uniform(ks[3], (nh,), jnp.float32, 1e-3, 1e-1)) - 1.0
        ),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(
            jax.random.fold_in(key, 7), (d_in, d), dtype, fan_in=d_in
        ),
    }


def _ssd_chunked(
    xh: jax.Array,  # [B, S, H, P] head-split inputs (already dt-scaled NOT)
    dt: jax.Array,  # [B, S, H] f32 (softplus'ed)
    A: jax.Array,  # [H] f32 (negative)
    Bm: jax.Array,  # [B, S, G, N]
    Cm: jax.Array,  # [B, S, G, N]
    chunk: int,
    state0: jax.Array | None = None,  # [B, H, P, N] carried-in state
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD: y[s] = Σ_{t<=s} C_s·B_t · exp(Σ_{j∈(t,s]} dt_j A) · dt_t · x_t.

    Returns (y [B,S,H,P], final_state [B,H,P,N]). G (groups) broadcast to H.
    ``state0`` carries a previous chunk's state in (packed serving feeds a
    long prompt as budget-bounded chunks); None keeps the fresh-sequence
    zeros this function always used.
    """
    b, s, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g

    def to_chunks(t, extra):  # [B,S,...] -> [nc, B, Q, ...]
        return t.reshape(b, nc, chunk, *extra).transpose(1, 0, 2, *range(3, 3 + len(extra)))

    x_c = to_chunks(xh, (h, p))
    dt_c = to_chunks(dt, (h,))
    B_c = to_chunks(Bm, (g, n))
    C_c = to_chunks(Cm, (g, n))

    # remat: the [B,H,Q,Q] decay/score blocks are recomputed in backward
    # instead of being saved per chunk (×nc ×layers blew past 200 GB/device
    # on zamba2/train_4k)
    @jax.checkpoint
    def chunk_body(state, xs):
        xq, dtq, bq, cq = xs  # [B,Q,H,P], [B,Q,H], [B,Q,G,N] x2
        l = dtq * A  # [B,Q,H] log-decay per step (negative)
        cum = jnp.cumsum(l, axis=1)  # inclusive cumsum
        # intra-chunk: M[s,t] = (C_s·B_t) exp(cum_s - cum_t) dt_t, t<=s
        bq_h = jnp.repeat(bq, rep, axis=2)  # [B,Q,H,N]
        cq_h = jnp.repeat(cq, rep, axis=2)
        cb = jnp.einsum("bqhn,bthn->bhqt", cq_h, bq_h)  # [B,H,Q,Q]
        decay = jnp.exp(
            cum.transpose(0, 2, 1)[:, :, :, None] - cum.transpose(0, 2, 1)[:, :, None, :]
        )  # [B,H,Q,Q]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = jnp.where(causal[None, None], cb * decay, 0.0)
        m = m * dtq.transpose(0, 2, 1)[:, :, None, :]  # × dt_t
        y_intra = jnp.einsum("bhqt,bthp->bqhp", m, xq.astype(jnp.float32))
        # inter-chunk: contribution of carried state, decayed from chunk start
        y_inter = jnp.einsum(
            "bqhn,bhpn->bqhp", cq_h * jnp.exp(cum)[..., None], state
        )
        # state update: S' = exp(cum_Q) S + Σ_t exp(cum_Q - cum_t) dt_t B_t x_t^T
        seg = jnp.exp(cum[:, -1:, :] - cum) * dtq  # [B,Q,H]
        state_new = state * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "bqhn,bqhp,bqh->bhpn", bq_h, xq.astype(jnp.float32), seg
        )
        return state_new, (y_intra + y_inter)

    if state0 is None:
        state0 = jnp.zeros((b, h, p, n), jnp.float32)
    state, ys = jax.lax.scan(
        chunk_body, state0.astype(jnp.float32), (x_c, dt_c, B_c, C_c)
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, state


def mamba2_apply(
    params: Params, cfg: ArchConfig, x: jax.Array, *, return_state: bool = False
):
    """Full-sequence Mamba2 block. x: [B,S,d] -> [B,S,d].

    ``return_state`` also yields the decode cache {'h', 'conv'}."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in = s_cfg.expand * d
    nh = d_in // s_cfg.head_dim
    G, N = s_cfg.n_groups, s_cfg.state

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xBC, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    xBC = causal_conv1d(xBC, params["conv_w"], params["conv_b"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)

    xh = xs.reshape(b, s, nh, s_cfg.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    A = -jnp.exp(params["A_log"])  # [H]
    Bm = Bm.reshape(b, s, G, N)
    Cm = Cm.reshape(b, s, G, N)

    chunk = min(s_cfg.chunk, s)
    if s % chunk:
        chunk = s
    y, h_final = _ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y = y + params["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_in)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if return_state:
        k = s_cfg.conv_kernel
        xBC_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)[1]
        tail = xBC_raw[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
            xBC_raw, ((0, 0), (k - 1 - s, 0), (0, 0))
        )
        return out, {"h": h_final, "conv": tail.astype(x.dtype)}
    return out


def mamba2_init_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state
    return {
        "h": jnp.zeros((batch, nh, s.head_dim, s.state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype),
    }


def mamba2_decode(
    params: Params, cfg: ArchConfig, x: jax.Array, cache: Params
) -> tuple[jax.Array, Params]:
    """One token. x: [B,1,d]."""
    s_cfg = cfg.ssm
    b, _, d = x.shape
    d_in = s_cfg.expand * d
    nh = d_in // s_cfg.head_dim
    G, N = s_cfg.n_groups, s_cfg.state

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])[:, 0]
    z, xBC, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    xBC, conv_state = conv_step(cache["conv"], xBC, params["conv_w"], params["conv_b"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)

    xh = xs.reshape(b, nh, s_cfg.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    a = jnp.exp(dt * -jnp.exp(params["A_log"]))  # [B,H]
    Bm = jnp.repeat(Bm.reshape(b, G, N), nh // G, axis=1)  # [B,H,N]
    Cm = jnp.repeat(Cm.reshape(b, G, N), nh // G, axis=1)

    h = cache["h"] * a[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", Bm.astype(jnp.float32), xh.astype(jnp.float32), dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", h, Cm.astype(jnp.float32))
    y = y + params["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm_w"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])
    return out[:, None, :], {"h": h, "conv": conv_state}


def mamba2_packed(
    params: Params, cfg: ArchConfig, x: jax.Array, cache: Params,
    n_real: jax.Array,
) -> tuple[jax.Array, Params]:
    """State-passing packed chunk for Mamba2/SSD (see :func:`mamba1_packed`
    — same contract: one slot's contiguous chunk, first ``n_real`` rows
    real, dt-masked padding rows are exact state identities)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in = s_cfg.expand * d
    nh = d_in // s_cfg.head_dim
    G, N = s_cfg.n_groups, s_cfg.state

    real = jnp.arange(s) < n_real
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xBC_raw, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    xBC, conv_state = _conv_carried(
        xBC_raw, cache["conv"], params["conv_w"], params["conv_b"], n_real
    )
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)

    xh = xs.reshape(b, s, nh, s_cfg.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    dt = jnp.where(real[None, :, None], dt, 0.0)  # pads: state identity
    A = -jnp.exp(params["A_log"])  # [H]
    Bm = Bm.reshape(b, s, G, N)
    Cm = Cm.reshape(b, s, G, N)
    chunk = min(s_cfg.chunk, s)
    if s % chunk:
        chunk = s
    y, h_final = _ssd_chunked(xh, dt, A, Bm, Cm, chunk, state0=cache["h"])
    y = y + params["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, {"h": h_final, "conv": conv_state.astype(cache["conv"].dtype)}
