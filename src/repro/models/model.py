"""Unified LM over all assigned architecture families.

One class, four families:

* ``dense``  — pre-norm transformer (GQA/MHA, optional qk_norm, optional MLA)
* ``moe``    — same attention; FFN replaced by routed experts (+shared) after
               ``first_k_dense`` leading dense layers
* ``ssm``    — Mamba1 stack (attention-free)
* ``hybrid`` — Mamba2 stack with a single weight-tied attention+MLP block
               invoked every ``shared_attn_every`` layers (Zamba2)

Layer parameters are stacked on a leading ``L`` axis and the stack is
traversed with ``jax.lax.scan`` (compile-time/HLO-size control at 512
devices); ``cfg.remat == 'block'`` wraps the scanned body in
``jax.checkpoint``.

The same class serves training (``forward``), prefill (``forward``), and
decoding (``decode_step`` + ``init_cache``). Modality stubs: ``audio``/``vlm``
archs accept precomputed frame/patch embeddings via ``batch['embeds']``
(the encoders themselves are out of scope here).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import MeshInfo
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Params,
    embed_tokens,
    embedding_init,
    mlp_apply,
    mlp_init,
    rms_norm,
    unembed,
)

# =============================================================================
# construction
# =============================================================================


class LM:
    def __init__(self, cfg: ArchConfig, mesh_info: Optional[MeshInfo] = None):
        self.cfg = cfg
        self.mesh_info = mesh_info
        self.dtype = jnp.dtype(cfg.dtype)
        if cfg.family == "hybrid":
            assert cfg.n_layers % cfg.shared_attn_every == 0, (
                cfg.n_layers,
                cfg.shared_attn_every,
            )

    # ------------------------------------------------------------------ init

    def _block_init(self, key) -> Params:
        """One transformer block's params (dense family or moe attention part)."""
        cfg, dt = self.cfg, self.dtype
        k_attn, k_mlp = jax.random.split(key)
        if cfg.mla is not None:
            attn = mla_mod.mla_init(k_attn, cfg, dt)
        else:
            attn = attn_mod.attention_init(k_attn, cfg, dt)
        return {
            "attn": attn,
            "norm1": jnp.ones((cfg.d_model,), dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "mlp": mlp_init(k_mlp, cfg.d_model, cfg.d_ff, dt),
        }

    def _moe_block_init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        k_attn, k_moe = jax.random.split(key)
        if cfg.mla is not None:
            attn = mla_mod.mla_init(k_attn, cfg, dt)
        else:
            attn = attn_mod.attention_init(k_attn, cfg, dt)
        return {
            "attn": attn,
            "norm1": jnp.ones((cfg.d_model,), dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "moe": moe_mod.moe_init(k_moe, cfg, dt),
        }

    def _dense_block_init_ff(self, key, d_ff: int) -> Params:
        """Dense block with an explicit d_ff (MoE stacks' leading dense layers)."""
        cfg, dt = self.cfg, self.dtype
        k_attn, k_mlp = jax.random.split(key)
        if cfg.mla is not None:
            attn = mla_mod.mla_init(k_attn, cfg, dt)
        else:
            attn = attn_mod.attention_init(k_attn, cfg, dt)
        return {
            "attn": attn,
            "norm1": jnp.ones((cfg.d_model,), dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "mlp": mlp_init(k_mlp, cfg.d_model, d_ff, dt),
        }

    def _mamba_init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        init = ssm_mod.mamba1_init if cfg.ssm.variant == "mamba1" else ssm_mod.mamba2_init
        return {"mamba": init(key, cfg, dt), "norm": jnp.ones((cfg.d_model,), dt)}

    def init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        k_emb, k_blocks, k_shared = jax.random.split(key, 3)
        params: Params = {
            "embed": embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dt, cfg.tie_embeddings),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
        L = cfg.n_layers
        if cfg.family == "dense":
            keys = jax.random.split(k_blocks, L)
            params["blocks"] = jax.vmap(self._block_init)(keys)
        elif cfg.family == "moe":
            kd = cfg.first_k_dense
            if kd:
                dkeys = jax.random.split(jax.random.fold_in(k_blocks, 1), kd)
                dff = cfg.dense_ff or cfg.d_ff
                params["dense_blocks"] = jax.vmap(
                    functools.partial(self._dense_block_init_ff, d_ff=dff)
                )(dkeys)
            mkeys = jax.random.split(jax.random.fold_in(k_blocks, 2), L - kd)
            params["moe_blocks"] = jax.vmap(self._moe_block_init)(mkeys)
        elif cfg.family == "ssm":
            keys = jax.random.split(k_blocks, L)
            params["blocks"] = jax.vmap(self._mamba_init)(keys)
        elif cfg.family == "hybrid":
            keys = jax.random.split(k_blocks, L)
            params["blocks"] = jax.vmap(self._mamba_init)(keys)
            params["shared"] = self._block_init(k_shared)  # ONE tied attn+mlp block
        else:
            raise ValueError(cfg.family)
        return params

    def param_specs(self, seed: int = 0) -> Any:
        """ShapeDtypeStruct pytree of the params (no allocation)."""
        return jax.eval_shape(lambda: self.init(jax.random.key(seed)))

    # ------------------------------------------------------------- block fns

    def _attn_apply(self, blk: Params, x: jax.Array, positions: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = rms_norm(x, blk["norm1"], cfg.norm_eps)
        if cfg.mla is not None:
            a = mla_mod.mla_apply(blk["attn"], cfg, h, positions)
        else:
            a = attn_mod.attention_apply(blk["attn"], cfg, h, positions)
        return x + a

    def _dense_block(self, blk: Params, x: jax.Array, positions: jax.Array) -> jax.Array:
        x = self._attn_apply(blk, x, positions)
        h = rms_norm(x, blk["norm2"], self.cfg.norm_eps)
        return x + mlp_apply(blk["mlp"], h)

    def _moe_block(
        self, blk: Params, x: jax.Array, positions: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        x = self._attn_apply(blk, x, positions)
        h = rms_norm(x, blk["norm2"], self.cfg.norm_eps)
        out, aux = moe_mod.moe_apply(blk["moe"], self.cfg, h, mesh_info=self.mesh_info)
        return x + out, aux

    def _mamba_block(self, blk: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = rms_norm(x, blk["norm"], cfg.norm_eps)
        fn = ssm_mod.mamba1_apply if cfg.ssm.variant == "mamba1" else ssm_mod.mamba2_apply
        return x + fn(blk["mamba"], cfg, h)

    def _maybe_remat(self, fn):
        if self.cfg.remat == "block":
            return jax.checkpoint(fn)
        return fn

    # Megatron-style sequence parallelism: between blocks the residual stream
    # is sharded over the MODEL axis on the sequence dim. XLA inserts the
    # all-gather before attention/FFN (which need full sequence / are head-
    # sharded) and the reduce-scatter after — and, critically, the remat
    # checkpoint saved per scanned layer is the SP-sharded tensor: boundary
    # activation memory drops by the TP degree (17 GB -> ~1 GB on
    # codeqwen/train_4k, measured via launch/dryrun.py).
    def _sp(self, x: jax.Array) -> jax.Array:
        mi = self.mesh_info
        if mi is None or mi.model_size <= 1:
            return x
        s = x.shape[1]
        if s < mi.model_size or s % mi.model_size:
            return x
        from jax.sharding import PartitionSpec as P

        return mi.constraint(x, P(mi.batch_axes, "model", None))

    def _logits_constraint(self, logits: jax.Array) -> jax.Array:
        """Keep [B,S,V] logits vocab-sharded: replicated f32 logits at
        vocab 92k-202k are 12-24 GB/device (measured in the dry-run
        artifact)."""
        mi = self.mesh_info
        if mi is None or mi.model_size <= 1:
            return logits
        if logits.shape[-1] % mi.model_size:
            return logits
        from jax.sharding import PartitionSpec as P

        parts = [None] * logits.ndim
        parts[0] = mi.batch_axes
        parts[-1] = "model"
        return mi.constraint(logits, P(*parts))

    # ---------------------------------------------------------------- forward

    def forward(self, params: Params, batch: dict) -> tuple[jax.Array, jax.Array]:
        """Full-sequence forward (train / prefill).

        batch: {'tokens': [B,S] int32} or {'embeds': [B,S,d]} for audio stubs.
        Returns (logits [B,S,V], aux scalar — MoE load-balance loss or 0).
        """
        cfg = self.cfg
        if "embeds" in batch:
            x = batch["embeds"].astype(self.dtype)
        else:
            x = embed_tokens(params["embed"], batch["tokens"])
        b, s = x.shape[:2]
        positions = jnp.arange(s, dtype=jnp.int32)
        if self.mesh_info is not None:
            x = self.mesh_info.constraint(x, self.mesh_info.batch_spec(3))
        aux_total = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense",):
            body = self._maybe_remat(
                lambda xx, blk: (self._sp(self._dense_block(blk, self._sp(xx), positions)), None)
            )
            x, _ = jax.lax.scan(body, x, params["blocks"])
        elif cfg.family == "moe":
            if cfg.first_k_dense:
                body_d = self._maybe_remat(
                    lambda xx, blk: (self._sp(self._dense_block(blk, self._sp(xx), positions)), None)
                )
                x, _ = jax.lax.scan(body_d, x, params["dense_blocks"])

            def _moe_body(xx, blk):
                xx, aux = self._moe_block(blk, self._sp(xx), positions)
                return self._sp(xx), aux

            body_m = self._maybe_remat(_moe_body)
            x, auxs = jax.lax.scan(body_m, x, params["moe_blocks"])
            aux_total = aux_total + auxs.sum()
        elif cfg.family == "ssm":
            body = self._maybe_remat(
                lambda xx, blk: (self._sp(self._mamba_block(blk, self._sp(xx))), None)
            )
            x, _ = jax.lax.scan(body, x, params["blocks"])
        elif cfg.family == "hybrid":
            every = cfg.shared_attn_every
            n_groups = cfg.n_layers // every
            grouped = jax.tree.map(
                lambda p: p.reshape(n_groups, every, *p.shape[1:]), params["blocks"]
            )
            shared = params["shared"]

            def group_body(xx, gblk):
                def inner(xxx, blk):
                    return self._sp(self._mamba_block(blk, self._sp(xxx))), None

                xx, _ = jax.lax.scan(inner, xx, gblk)
                xx = self._dense_block(shared, xx, positions)
                return self._sp(xx), None

            x, _ = jax.lax.scan(self._maybe_remat(group_body), x, grouped)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x)
        logits = self._logits_constraint(logits)
        return logits, aux_total

    # ----------------------------------------------------------------- cache

    @property
    def cache_dtype(self):
        """KV/latent cache storage dtype (f8 option halves decode HBM)."""
        return jnp.dtype(self.cfg.kv_cache_dtype or self.cfg.dtype)

    def init_cache(
        self, batch: int, max_len: int, kv_dtype: Optional[Any] = None
    ) -> Params:
        """Decode cache pytree (zeros). Layout per family documented inline.

        ``kv_dtype`` opts the positional-KV cache into quantized-row
        storage: K/V leaves store that dtype (int8 for quantized serving;
        f32 keeps the scale machinery but stays bit-identical to the plain
        path) and per-(position, head) f32 ``k_scale``/``v_scale`` leaves
        ``[L, B, S, KV]`` live IN the cache pytree — they thread through
        scan/donation/COW exactly like the payloads they describe."""
        cfg, dt = self.cfg, self.cache_dtype
        L = cfg.n_layers
        if kv_dtype is not None and not self.has_positional_kv:
            raise ValueError(
                f"family {self.family_tag!r} has no positional KV to quantize"
            )
        if cfg.family in ("dense", "moe"):
            if cfg.mla is not None:
                m = cfg.mla
                return {
                    "ckv": jnp.zeros((L, batch, max_len, m.kv_lora_rank), dt),
                    "krope": jnp.zeros((L, batch, max_len, m.rope_head_dim), dt),
                }
            kv, hd = cfg.n_kv_heads, cfg.head_dim
            if kv_dtype is not None:
                qdt = jnp.dtype(kv_dtype)
                return {
                    "k": jnp.zeros((L, batch, max_len, kv, hd), qdt),
                    "v": jnp.zeros((L, batch, max_len, kv, hd), qdt),
                    "k_scale": jnp.ones((L, batch, max_len, kv), jnp.float32),
                    "v_scale": jnp.ones((L, batch, max_len, kv), jnp.float32),
                }
            return {
                "k": jnp.zeros((L, batch, max_len, kv, hd), dt),
                "v": jnp.zeros((L, batch, max_len, kv, hd), dt),
            }
        if cfg.family == "ssm":
            mk = (
                ssm_mod.mamba1_init_cache
                if cfg.ssm.variant == "mamba1"
                else ssm_mod.mamba2_init_cache
            )
            one = mk(cfg, batch, self.dtype)  # SSM states stay full precision
            return jax.tree.map(
                lambda leaf: jnp.zeros((L, *leaf.shape), leaf.dtype), one
            )
        if cfg.family == "hybrid":
            mk = ssm_mod.mamba2_init_cache
            one = mk(cfg, batch, self.dtype)
            n_inv = L // cfg.shared_attn_every
            kv, hd = cfg.n_kv_heads, cfg.head_dim
            return {
                "mamba": jax.tree.map(
                    lambda leaf: jnp.zeros((L, *leaf.shape), leaf.dtype), one
                ),
                "attn_k": jnp.zeros((n_inv, batch, max_len, kv, hd), dt),
                "attn_v": jnp.zeros((n_inv, batch, max_len, kv, hd), dt),
            }
        raise ValueError(cfg.family)

    def init_kv_pool(
        self, num_blocks: int, block_size: int, kv_dtype: Optional[Any] = None
    ) -> Params:
        """Block-paged KV pool (zeros): ``[L, num_blocks, block_size, KV,
        hd]`` per leaf — the dense cache's ``[B, S_max]`` plane refactored
        into shared, individually-ownable blocks (paged serving,
        :mod:`repro.serve.kv_pool`). With identity block tables (block i of
        sequence b = b * max_blocks + i) this is a pure reshape of
        ``init_cache(B, max_blocks * block_size)`` — paging adds an
        indirection, not a new layout. Positional-KV families only
        (``has_positional_kv``).

        ``kv_dtype`` adds quantized-row storage exactly as in
        :meth:`init_cache`: scale leaves ``[L, num_blocks, block_size,
        KV]`` f32 are pool-shaped, so block-granular ownership (COW,
        prefix sharing, re-homing) carries the scales with their blocks
        for free."""
        cfg, dt = self.cfg, self.cache_dtype
        if not self.has_positional_kv:
            raise ValueError(
                f"family {self.family_tag!r} has no positional KV to page"
            )
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        if kv_dtype is not None:
            qdt = jnp.dtype(kv_dtype)
            L = cfg.n_layers
            return {
                "k": jnp.zeros((L, num_blocks, block_size, kv, hd), qdt),
                "v": jnp.zeros((L, num_blocks, block_size, kv, hd), qdt),
                "k_scale": jnp.ones((L, num_blocks, block_size, kv), jnp.float32),
                "v_scale": jnp.ones((L, num_blocks, block_size, kv), jnp.float32),
            }
        return {
            "k": jnp.zeros((cfg.n_layers, num_blocks, block_size, kv, hd), dt),
            "v": jnp.zeros((cfg.n_layers, num_blocks, block_size, kv, hd), dt),
        }

    def cache_specs(self, batch: int, max_len: int) -> Any:
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def cache_shardings(self, cache_shape: Any, mesh_info: MeshInfo) -> Any:
        """Cache placement. Axis 0 is L (or n_inv) — never sharded. Axis 1
        (batch) goes on the DP axes when divisible; otherwise (long_500k's
        B=1) the SEQUENCE dim of attention caches is sharded on the DP axes
        instead (sequence parallelism for the KV sweep). The widest remaining
        trailing dim divisible by the model axis is model-sharded (KV
        head_dim / MLA latent / SSM state)."""
        dp = mesh_info.data_size

        def leaf_spec(path, leaf):
            parts: list[Any] = [None] * leaf.ndim
            used = None
            if leaf.shape[1] % dp == 0 and leaf.shape[1] >= dp:
                parts[1] = mesh_info.batch_axes
            elif (
                leaf.ndim >= 4  # attention caches: [L, B, S, ...]
                and leaf.shape[2] % dp == 0
                and leaf.shape[2] >= dp
            ):
                parts[2] = mesh_info.batch_axes
                used = 2
            # model-axis placement preference for attention caches
            # [L, B, S, KV, hd]: KV heads first (clean head parallelism),
            # then the SEQUENCE dim (flash-decoding-style split-K: the
            # scores/AV contractions run shard-local + one psum, and the
            # scatter is a masked local write — no resharding copies),
            # then head_dim as the last resort.
            order = [3, 2, leaf.ndim - 1] if leaf.ndim >= 4 else list(
                range(leaf.ndim - 1, 1, -1)
            )
            for i in order:
                if i == used or i >= leaf.ndim or parts[i] is not None:
                    continue
                if leaf.shape[i] % mesh_info.model_size == 0 and leaf.shape[i] >= mesh_info.model_size:
                    parts[i] = "model"
                    break
            return mesh_info.named(jax.sharding.PartitionSpec(*parts))

        return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)

    # ---------------------------------------------------------------- prefill

    def prefill(
        self, params: Params, batch: dict, max_len: int
    ) -> tuple[jax.Array, Params]:
        """Full-sequence forward that also fills the decode cache.

        Returns (logits [B,S,V], cache padded to ``max_len``). The caller
        continues with ``decode_step(..., cur_len=S)``.
        """
        cfg = self.cfg
        if "embeds" in batch:
            x = batch["embeds"].astype(self.dtype)
        else:
            x = embed_tokens(params["embed"], batch["tokens"])
        b, s = x.shape[:2]
        assert s <= max_len, (s, max_len)
        positions = jnp.arange(s, dtype=jnp.int32)

        def pad_seq(t):  # [B,S,...] -> [B,max_len,...]
            widths = [(0, 0), (0, max_len - s)] + [(0, 0)] * (t.ndim - 2)
            return jnp.pad(t, widths)

        if cfg.family in ("dense", "moe"):

            def body(xx, blk):
                xx, piece = self._block_forward_capture(blk, xx, positions)
                return xx, piece

            if cfg.family == "dense":
                x, pieces = jax.lax.scan(body, x, params["blocks"])
            else:
                pieces_list = []
                if cfg.first_k_dense:
                    x, pd = jax.lax.scan(body, x, params["dense_blocks"])
                    pieces_list.append(pd)
                x, pm = jax.lax.scan(body, x, params["moe_blocks"])
                pieces_list.append(pm)
                pieces = jax.tree.map(
                    lambda *ts: jnp.concatenate(ts, axis=0), *pieces_list
                ) if len(pieces_list) > 1 else pieces_list[0]
            cache = jax.tree.map(lambda t: pad_seq_axis(t, 2, max_len), pieces)
        elif cfg.family == "ssm":

            def body(xx, blk):
                h = rms_norm(xx, blk["norm"], cfg.norm_eps)
                fn = (
                    ssm_mod.mamba1_apply
                    if cfg.ssm.variant == "mamba1"
                    else ssm_mod.mamba2_apply
                )
                out, st = fn(blk["mamba"], cfg, h, return_state=True)
                return xx + out, st

            x, cache = jax.lax.scan(body, x, params["blocks"])
        elif cfg.family == "hybrid":
            every = cfg.shared_attn_every
            n_groups = cfg.n_layers // every
            grouped = jax.tree.map(
                lambda p: p.reshape(n_groups, every, *p.shape[1:]), params["blocks"]
            )
            shared = params["shared"]

            def group_body(xx, gblk):
                def inner(xxx, blk):
                    h = rms_norm(xxx, blk["norm"], cfg.norm_eps)
                    out, st = ssm_mod.mamba2_apply(blk["mamba"], cfg, h, return_state=True)
                    return xxx + out, st

                xx, sts = jax.lax.scan(inner, xx, gblk)
                h = rms_norm(xx, shared["norm1"], cfg.norm_eps)
                a, (kc, vc) = attn_mod.attention_apply(
                    shared["attn"], cfg, h, positions, return_kv=True
                )
                xx = xx + a
                h = rms_norm(xx, shared["norm2"], cfg.norm_eps)
                xx = xx + mlp_apply(shared["mlp"], h)
                return xx, (sts, kc, vc)

            x, (mcache, ks, vs) = jax.lax.scan(group_body, x, grouped)
            cache = {
                "mamba": jax.tree.map(
                    lambda t: t.reshape(cfg.n_layers, *t.shape[2:]), mcache
                ),
                "attn_k": pad_seq_axis(ks, 2, max_len),
                "attn_v": pad_seq_axis(vs, 2, max_len),
            }
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x)
        return logits, cache

    def _block_forward_capture(self, blk, x, positions):
        """Dense/MoE block forward that also emits this layer's cache piece."""
        cfg = self.cfg
        h = rms_norm(x, blk["norm1"], cfg.norm_eps)
        if cfg.mla is not None:
            a, (ckv, krope) = mla_mod.mla_apply(
                blk["attn"], cfg, h, positions, return_kv=True
            )
            piece = {"ckv": ckv, "krope": krope}
        else:
            a, (k, v) = attn_mod.attention_apply(
                blk["attn"], cfg, h, positions, return_kv=True
            )
            piece = {"k": k, "v": v}
        x = x + a
        h = rms_norm(x, blk["norm2"], cfg.norm_eps)
        if "moe" in blk:
            out, _ = moe_mod.moe_apply(blk["moe"], cfg, h, mesh_info=self.mesh_info)
            x = x + out
        else:
            x = x + mlp_apply(blk["mlp"], h)
        return x, piece

    # ------------------------------------------------------------ decode step

    def _block_decode(
        self, blk: Params, x: jax.Array, cache_l: Params, cur_len: jax.Array,
        block_tables: Optional[jax.Array] = None,
    ) -> tuple[jax.Array, Params]:
        """One layer's decode. cache_l leaves have NO leading L axis here."""
        cfg = self.cfg
        h = rms_norm(x, blk["norm1"], cfg.norm_eps)
        if cfg.mla is not None:
            a, ckv, krope = mla_mod.mla_decode(
                blk["attn"], cfg, h, cache_l["ckv"], cache_l["krope"], cur_len
            )
            new_cache = {"ckv": ckv, "krope": krope}
        elif "k_scale" in cache_l:  # quantized-row cache: scales ride along
            a, ck, cv, cks, cvs = attn_mod.attention_decode(
                blk["attn"], cfg, h, cache_l["k"], cache_l["v"], cur_len,
                mesh_info=self.mesh_info, block_tables=block_tables,
                k_scale=cache_l["k_scale"], v_scale=cache_l["v_scale"],
            )
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        else:
            a, ck, cv = attn_mod.attention_decode(
                blk["attn"], cfg, h, cache_l["k"], cache_l["v"], cur_len,
                mesh_info=self.mesh_info, block_tables=block_tables,
            )
            new_cache = {"k": ck, "v": cv}
        x = x + a
        h = rms_norm(x, blk["norm2"], cfg.norm_eps)
        if "moe" in blk:
            out, _ = moe_mod.moe_apply(blk["moe"], cfg, h, mesh_info=self.mesh_info)
            x = x + out
        else:
            x = x + mlp_apply(blk["mlp"], h)
        return x, new_cache

    def _mamba_decode(self, blk, x, cache_l):
        cfg = self.cfg
        h = rms_norm(x, blk["norm"], cfg.norm_eps)
        fn = ssm_mod.mamba1_decode if cfg.ssm.variant == "mamba1" else ssm_mod.mamba2_decode
        out, new_cache = fn(blk["mamba"], cfg, h, cache_l)
        return x + out, new_cache

    def _ssm_packed(
        self, params: Params, cache: Params, x: jax.Array,
        tok_pos: jax.Array, pack_slots: jax.Array, max_len: int,
    ) -> tuple[jax.Array, Params]:
        """Single-slot packed chunk for the recurrent-state family.

        x: [T, d] embedded tokens — ONE contiguous chunk of slot
        ``pack_slots[0]``'s stream (ascending positions, bucket padding
        after the real rows with the ``tok_pos >= max_len`` sentinel).
        Gathers that slot's (h, conv) state, runs the state-passing chunk
        scan per layer, and scatters the updated state back — O(chunk)
        work and O(1) state bytes regardless of how long the stream gets.
        A chunk that starts at position 0 recycles the state slot (zeros
        in, like a fresh sequence) — admission needs no separate cache
        wipe, mirroring how attention slots tolerate stale rows."""
        cfg = self.cfg
        pos = jnp.asarray(tok_pos, jnp.int32)
        slot = jnp.asarray(pack_slots, jnp.int32)[0]
        n_real = jnp.sum(pos < max_len).astype(jnp.int32)
        fresh = pos[0] == 0  # first chunk of a prompt
        fn = (
            ssm_mod.mamba1_packed
            if cfg.ssm.variant == "mamba1"
            else ssm_mod.mamba2_packed
        )

        def body(xx, xs):
            blk, cl = xs  # cl leaves: [B, ...] (no L axis)
            sl = jax.tree.map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=0),
                cl,
            )
            sl = jax.tree.map(
                lambda c: jnp.where(fresh, jnp.zeros_like(c), c), sl
            )
            h = rms_norm(xx, blk["norm"], cfg.norm_eps)
            out, new_sl = fn(blk["mamba"], cfg, h, sl, n_real)
            ncl = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                    c, n.astype(c.dtype), slot, axis=0
                ),
                cl, new_sl,
            )
            return xx + out, ncl

        xb, new_cache = jax.lax.scan(body, x[None], (params["blocks"], cache))
        return xb[0], new_cache

    # ------------------------------------------------------------ packed step

    @property
    def supports_packed(self) -> bool:
        """Whether the unified ragged prefill+decode dispatch applies.

        Dense/MoE attention scatters positional K/V rows, MLA scatters
        compressed latent rows (``mla_packed``), and SSM rides a
        state-passing single-slot chunk (``mamba{1,2}_packed`` — the
        engine packs recurrent-state admissions one slot per pack).
        Hybrid interleaves recurrent state with a shared attention cache
        and keeps the exact-length prefill + per-step decode path."""
        return self.cfg.family in ("dense", "moe", "ssm")

    @property
    def has_positional_kv(self) -> bool:
        """Whether the decode cache stores one K/V row per position — the
        precondition for paging (block pool indirection) and quantized-row
        storage. The MLA latent cache is positional but compressed-latent
        shaped (no per-head K/V rows for the quant/paged plumbing), and
        SSM state is constant-size — neither pages nor quantizes."""
        return self.cfg.family in ("dense", "moe") and self.cfg.mla is None

    @property
    def family_tag(self) -> str:
        """Human-readable family label for error messages ('moe+mla' when
        the attention is latent, else the bare family)."""
        if self.cfg.mla is not None:
            return f"{self.cfg.family}+mla"
        return self.cfg.family

    def _block_packed(
        self, blk: Params, x: jax.Array, cache_l: Params,
        tok_slot: jax.Array, tok_pos: jax.Array, valid: Optional[jax.Array],
        pack_slots: Optional[jax.Array],
        block_tables: Optional[jax.Array] = None,
    ) -> tuple[jax.Array, Params]:
        """One layer over a packed [T] token batch. cache_l has no L axis."""
        cfg = self.cfg
        h = rms_norm(x, blk["norm1"], cfg.norm_eps)
        if cfg.mla is not None:  # latent-space packed step (never paged)
            a, nckv, nkrope = mla_mod.mla_packed(
                blk["attn"], cfg, h, cache_l["ckv"], cache_l["krope"],
                tok_slot, tok_pos, valid, pack_slots,
            )
            new_cache = {"ckv": nckv, "krope": nkrope}
        elif "k_scale" in cache_l:  # quantized-row cache: scales ride along
            a, ck, cv, cks, cvs = attn_mod.attention_packed(
                blk["attn"], cfg, h, cache_l["k"], cache_l["v"],
                tok_slot, tok_pos, valid, pack_slots,
                mesh_info=self.mesh_info, block_tables=block_tables,
                k_scale=cache_l["k_scale"], v_scale=cache_l["v_scale"],
            )
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        else:
            a, ck, cv = attn_mod.attention_packed(
                blk["attn"], cfg, h, cache_l["k"], cache_l["v"],
                tok_slot, tok_pos, valid, pack_slots,
                mesh_info=self.mesh_info, block_tables=block_tables,
            )
            new_cache = {"k": ck, "v": cv}
        x = x + a
        h = rms_norm(x, blk["norm2"], cfg.norm_eps)
        if "moe" in blk:
            out, _ = moe_mod.moe_apply(
                blk["moe"], cfg, h[None], mesh_info=self.mesh_info
            )
            x = x + out[0]
        else:
            x = x + mlp_apply(blk["mlp"], h)
        return x, new_cache

    def packed_step(
        self,
        params: Params,
        cache: Params,
        tokens: jax.Array,
        tok_slot: jax.Array,
        tok_pos: jax.Array,
        out_rows: Optional[jax.Array] = None,
        pack_slots: Optional[jax.Array] = None,
        block_tables: Optional[jax.Array] = None,
        max_len: Optional[int] = None,
    ) -> tuple[jax.Array, Params]:
        """Unified ragged prefill+decode step: one flat [T] token batch where
        each token carries its own (cache slot, absolute position) — decode
        slots contribute one token, admitting prompts a prefill chunk.

        tokens/tok_slot/tok_pos: [T] int32. Requires ``supports_packed``.
        Returns (logits [T, V], new_cache) — or logits [len(out_rows), V]
        when ``out_rows`` selects the packed rows to unembed (the serving
        engine only samples a chunk's final token, so the [T, V] logits for
        every mid-chunk row are dead weight). With ``pack_slots`` ([P]
        int32), ``tok_slot`` holds indices into it and attention reads only
        those P cache rows (see ``attention_packed``). Padding tokens (a
        pack rounded up to its bucket) should use ``tok_pos >= max_len``:
        their cache writes are dropped and their logits rows are garbage to
        ignore.

        With ``block_tables`` ([B, max_blocks] int32), ``cache`` is a
        block-paged pool from :meth:`init_kv_pool` and every (slot, pos)
        resolves to (block, offset) through the slot's table row — the
        SAME step otherwise (same descriptors, same mask, same sampling
        rows), which is what keeps paged and dense serving bit-identical.

        SSM packs carry one slot per pack (``pack_slots[0]``; the engine
        enforces pack width 1 for recurrent families): the whole [T] batch
        is one contiguous chunk of that slot's stream, and ``max_len`` is
        required — tok_pos >= max_len identifies the bucket padding whose
        rows must be state-identities rather than merely masked.
        """
        cfg = self.cfg
        assert self.supports_packed, cfg.family
        x = embed_tokens(params["embed"], tokens)  # [T, d]
        if cfg.family == "ssm":
            assert block_tables is None, "SSM state is never paged"
            assert max_len is not None, "SSM packed step needs max_len"
            assert pack_slots is not None, "SSM packs carry pack_slots"
            x, new_cache = self._ssm_packed(
                params, cache, x, tok_pos, pack_slots, max_len
            )
            if out_rows is not None:
                x = x[out_rows]
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            return unembed(params["embed"], x), new_cache
        # the attention mask depends only on the pack descriptors — compute
        # it once and share it across every layer
        from repro.kernels import ref as _ref

        if block_tables is None:
            # [L, B, S_max, KV, hd] positional rows or [L, B, S_max, r]
            # compressed latents — batch/seq axes sit in the same places
            k_leaf = cache["ckv"] if cfg.mla is not None else cache["k"]
            n_rows = k_leaf.shape[1] if pack_slots is None else len(pack_slots)
            s_max = k_leaf.shape[2]
        else:  # pool leaf [L, NB, bs, KV, hd]: S_max = table width * block
            n_rows = (
                block_tables.shape[0] if pack_slots is None else len(pack_slots)
            )
            s_max = block_tables.shape[1] * cache["k"].shape[2]
        valid = _ref.ragged_valid_mask(
            tok_slot, tok_pos, n_rows, s_max, cfg.sliding_window
        )

        def body(xx, xs):
            blk, cl = xs
            xx, ncl = self._block_packed(
                blk, xx, cl, tok_slot, tok_pos, valid, pack_slots,
                block_tables,
            )
            return xx, ncl

        if cfg.family == "moe" and cfg.first_k_dense:
            kd = cfg.first_k_dense
            dense_cache = jax.tree.map(lambda c: c[:kd], cache)
            moe_cache = jax.tree.map(lambda c: c[kd:], cache)
            x, nd = jax.lax.scan(body, x, (params["dense_blocks"], dense_cache))
            x, nm = jax.lax.scan(body, x, (params["moe_blocks"], moe_cache))
            new_cache = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), nd, nm
            )
        else:
            blocks = params["blocks"] if cfg.family == "dense" else params["moe_blocks"]
            x, new_cache = jax.lax.scan(body, x, (blocks, cache))

        if out_rows is not None:
            x = x[out_rows]
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x)
        return logits, new_cache

    # ------------------------------------------------------------ decode step

    def decode_step(
        self, params: Params, cache: Params, batch: dict, cur_len: jax.Array,
        block_tables: Optional[jax.Array] = None,
    ) -> tuple[jax.Array, Params]:
        """One token for every sequence.

        batch: {'tokens': [B,1]} or {'embeds': [B,1,d]}. cur_len: scalar int32
        (tokens already cached). Returns (logits [B,1,V], new_cache).
        With ``block_tables``, ``cache`` is a paged pool (see
        :meth:`packed_step`) — dense/moe positional-KV families only.
        """
        cfg = self.cfg
        if block_tables is not None and not self.has_positional_kv:
            raise ValueError(f"family {self.family_tag!r} has no paged path")
        if "embeds" in batch:
            x = batch["embeds"].astype(self.dtype)
        else:
            x = embed_tokens(params["embed"], batch["tokens"])

        if cfg.family in ("dense", "moe"):
            if cfg.family == "moe" and cfg.first_k_dense:
                kd = cfg.first_k_dense
                dense_cache = jax.tree.map(lambda c: c[:kd], cache)
                moe_cache = jax.tree.map(lambda c: c[kd:], cache)

                def body_d(xx, xs):
                    blk, cl = xs
                    xx, ncl = self._block_decode(blk, xx, cl, cur_len, block_tables)
                    return xx, ncl

                x, nd = jax.lax.scan(body_d, x, (params["dense_blocks"], dense_cache))
                x, nm = jax.lax.scan(body_d, x, (params["moe_blocks"], moe_cache))
                new_cache = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], axis=0), nd, nm
                )
            else:
                blocks = params["blocks"] if cfg.family == "dense" else params["moe_blocks"]

                def body(xx, xs):
                    blk, cl = xs
                    xx, ncl = self._block_decode(blk, xx, cl, cur_len, block_tables)
                    return xx, ncl

                x, new_cache = jax.lax.scan(body, x, (blocks, cache))
        elif cfg.family == "ssm":

            def body(xx, xs):
                blk, cl = xs
                xx, ncl = self._mamba_decode(blk, xx, cl)
                return xx, ncl

            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        elif cfg.family == "hybrid":
            every = cfg.shared_attn_every
            n_groups = cfg.n_layers // every
            grouped_blocks = jax.tree.map(
                lambda p: p.reshape(n_groups, every, *p.shape[1:]), params["blocks"]
            )
            grouped_mcache = jax.tree.map(
                lambda c: c.reshape(n_groups, every, *c.shape[1:]), cache["mamba"]
            )
            shared = params["shared"]

            def group_body(xx, xs):
                gblk, gmc, ak, av = xs

                def inner(xxx, ys):
                    blk, cl = ys
                    xxx, ncl = self._mamba_decode(blk, xxx, cl)
                    return xxx, ncl

                xx, ngmc = jax.lax.scan(inner, xx, (gblk, gmc))
                h = rms_norm(xx, shared["norm1"], cfg.norm_eps)
                a, nak, nav = attn_mod.attention_decode(
                    shared["attn"], cfg, h, ak, av, cur_len,
                    mesh_info=self.mesh_info,
                )
                xx = xx + a
                h = rms_norm(xx, shared["norm2"], cfg.norm_eps)
                xx = xx + mlp_apply(shared["mlp"], h)
                return xx, (ngmc, nak, nav)

            x, (ngm, nak, nav) = jax.lax.scan(
                group_body,
                x,
                (grouped_blocks, grouped_mcache, cache["attn_k"], cache["attn_v"]),
            )
            new_cache = {
                "mamba": jax.tree.map(
                    lambda c: c.reshape(cfg.n_layers, *c.shape[2:]), ngm
                ),
                "attn_k": nak,
                "attn_v": nav,
            }
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x)
        return logits, new_cache


def pad_seq_axis(t: jax.Array, axis: int, max_len: int) -> jax.Array:
    """Pad axis ``axis`` (the cache sequence axis) up to max_len with zeros."""
    cur = t.shape[axis]
    if cur == max_len:
        return t
    widths = [(0, 0)] * t.ndim
    widths[axis] = (0, max_len - cur)
    return jnp.pad(t, widths)


# =============================================================================
# input specs (dry-run stand-ins; ShapeDtypeStruct only, no allocation)
# =============================================================================


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Model inputs for one (arch × shape) cell as ShapeDtypeStructs.

    * train:   {'tokens': (B,S), 'labels': (B,S)}           (int32)
    * prefill: {'tokens': (B,S)}
    * decode:  {'tokens': (B,1)}  (+ cache/cur_len supplied by the caller)
    Audio archs replace 'tokens' with precomputed frame embeddings
    (B, S, d_model) per the modality-stub rule; labels stay int32 codes.
    """
    B = shape.global_batch
    S = shape.seq_len
    dt_tok = jnp.int32
    dt_emb = jnp.dtype(cfg.dtype)

    def tok_or_embed(s: int) -> dict:
        if cfg.modality == "audio":
            return {"embeds": jax.ShapeDtypeStruct((B, s, cfg.d_model), dt_emb)}
        return {"tokens": jax.ShapeDtypeStruct((B, s), dt_tok)}

    if shape.kind == "train":
        specs = tok_or_embed(S)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), dt_tok)
        return specs
    if shape.kind == "prefill":
        return tok_or_embed(S)
    if shape.kind == "decode":
        return tok_or_embed(1)
    raise ValueError(shape.kind)
