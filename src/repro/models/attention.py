"""Attention: GQA/MHA with dense and memory-efficient (chunked online-softmax)
implementations, qk-norm, RoPE, sliding windows, and a KV-cache decode path.

All three implementations are GQA-native: K/V stay at ``n_kv_heads`` and the
query heads are grouped ``[B, S, KV, G, hd]`` inside the einsums, so the
``H//KV``-fold K/V expansion (`_repeat_kv`) is never materialized. The
chunked implementation is the CPU/XLA analogue of the Pallas flash-attention
kernel (``repro.kernels.ops.gqa_flash_attention``, which takes over on TPU):
it never materializes the full S×S score matrix — it scans KV blocks
carrying the online (max, sum, acc) triple. Decode dispatches through
``repro.kernels.ops.decode_attention`` (grouped oracle on CPU, the batched
Pallas decode kernel on TPU).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.compression import quantize_rows
from repro.kernels import ops
from repro.models.layers import Params, apply_rope, dense_init, rms_norm
from repro.models.quant import qweight

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ArchConfig, dtype) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, (d, H, hd), dtype),
        "wk": dense_init(k2, (d, KV, hd), dtype),
        "wv": dense_init(k3, (d, KV, hd), dtype),
        "wo": dense_init(k4, (H, hd, d), dtype, fan_in=H * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


# ---------------------------------------------------------------------------
# Score-level primitives
# ---------------------------------------------------------------------------


def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    """[B, S, KV, hd] -> [B, S, KV*groups, hd] by head repetition.

    Kept as a reference utility (and for external callers); the attention
    paths below are GQA-native and never call it.
    """
    if groups == 1:
        return x
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, groups, hd))
    return x.reshape(b, s, kv * groups, hd)


def _head_constraint(x: jax.Array, mesh_info, head_axis: int) -> jax.Array:
    """Pin ``x``'s head dim onto the tensor-parallel ``model`` axis.

    The serving cluster's merge mode shards attention head-parallel: q/k/v
    projections and the KV cache split on their (kv_)head dim, with
    head_dim as the GQA fallback when the head count doesn't divide the TP
    degree — the same preference order as ``spec_for_param`` /
    ``serve_cache_shardings``, so constraining here never fights the
    placement the params and cache arrived with. No-op off-mesh.
    """
    if mesh_info is None or mesh_info.model_size <= 1:
        return x
    from jax.sharding import PartitionSpec as P

    ms = mesh_info.model_size
    for ax in (head_axis, x.ndim - 1):
        if x.shape[ax] % ms == 0 and x.shape[ax] >= ms:
            parts: list = [None] * x.ndim
            parts[ax] = "model"
            return mesh_info.constraint(x, P(*parts))
    return x


def _group_q(q: jax.Array, kv_heads: int) -> jax.Array:
    """[B, Sq, H, hd] -> [B, Sq, KV, G, hd] (head-major grouping: query head
    h belongs to KV head h // G)."""
    b, sq, h, hd = q.shape
    assert h % kv_heads == 0, (h, kv_heads)
    return q.reshape(b, sq, kv_heads, h // kv_heads, hd)


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    window: int = 0,
) -> jax.Array:
    """Reference attention materializing the full score matrix.

    q: [B, Sq, H, hd], k/v: [B, Sk, KV, hd] with H % KV == 0 (KV == H is
    plain MHA). The group dim lives inside the einsum — no K/V repetition.
    q_offset: absolute position of q[0] (for causal masking vs a longer k).
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    scale = hd**-0.5
    if kvh == h:  # MHA: flat 4-D einsums (cheaper to compile/lower than 5-D)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    else:
        qg = _group_q(q, kvh)  # [B,Sq,KV,G,hd]
        scores = (
            jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
        )  # [B,KV,G,Sq,Sk]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    mask = mask[None, None] if kvh == h else mask[None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if kvh == h:
        return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    chunk: int = 512,
    q_offset: int | jax.Array = 0,
    window: int = 0,
) -> jax.Array:
    """Flash-style online-softmax attention scanning KV chunks.

    q: [B, Sq, H, hd], k/v: [B, Sk, KV, hd] with H % KV == 0. Never
    materializes [Sq, Sk]; per-step footprint is [B, KV, G, Sq, chunk].
    Matches :func:`dense_attention` to fp tolerance.
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    grouped = kvh != h  # GQA: group dim inside the einsums, no K/V repeat
    if sk % chunk:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pad_mask = True
    else:
        pad_mask = False
    skp = k.shape[1]
    n_chunks = skp // chunk
    scale = hd**-0.5

    kc = k.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)

    qpos = jnp.arange(sq) + q_offset  # [Sq]
    if grouped:
        qf = _group_q(q, kvh).astype(jnp.float32)  # [B,Sq,KV,G,hd]
        qk, pv = "bqkgd,bckd->bkgqc", "bkgqc,bckd->bkgqd"
        head_shape = (b, kvh, g)
    else:  # MHA: flat 4-D einsums (cheaper to compile/lower than 5-D)
        qf = q.astype(jnp.float32)
        qk, pv = "bqhd,bchd->bhqc", "bhqc,bchd->bhqd"
        head_shape = (b, h)
    n_mask_dims = len(head_shape)  # leading broadcast dims for the [Sq,c] mask

    @jax.checkpoint
    def body(carry, xs):
        # rematted: the [B,heads...,Sq,chunk] probability block is recomputed
        # in backward rather than saved per KV chunk
        m, l, acc = carry  # [*head,Sq], [*head,Sq], [*head,Sq,hd]
        kci, vci, ci = xs  # [B,chunk,KV,hd] x2, scalar chunk index
        scores = jnp.einsum(qk, qf, kci.astype(jnp.float32)) * scale
        kpos = ci * chunk + jnp.arange(chunk)  # [chunk]
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        if pad_mask:
            mask &= (kpos < sk)[None, :]
        scores = jnp.where(
            mask.reshape((1,) * (n_mask_dims - 1) + (1, sq, chunk)), scores, NEG_INF
        )
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            pv, p, vci.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((*head_shape, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((*head_shape, sq), jnp.float32)
    acc0 = jnp.zeros((*head_shape, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # [*head,Sq,hd]
    if grouped:
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    else:
        out = out.transpose(0, 2, 1, 3)
    return out.astype(q.dtype)  # [B,Sq,H,hd]


# ---------------------------------------------------------------------------
# Full block-level forward (projections + rope + attention)
# ---------------------------------------------------------------------------


def attention_apply(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    impl: Optional[str] = None,
    return_kv: bool = False,
):
    """Self-attention over a full sequence (train / prefill).

    x: [B, S, d]; positions: [S] or [B, S]. With ``return_kv`` also returns
    the post-rope (k, v) [B,S,KV,hd] — exactly the decode cache layout,
    enabling prefill-into-cache. On TPU the full-attention window-free case
    routes to the GQA-native Pallas flash kernel.
    """
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    impl = impl or cfg.attn_impl

    q = jnp.einsum("bsd,dhk->bshk", x, qweight(params["wq"], x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, qweight(params["wk"], x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, qweight(params["wv"], x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if positions.ndim == 1:
        positions = positions[None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kv_cache = (k, v) if return_kv else None

    # Pallas GQA flash kernel on TPU, but only on the prefill path
    # (return_kv=True): pallas_call has no VJP, so the training forward
    # (which jax.grad traverses) must stay on the XLA implementations.
    if (
        return_kv
        and jax.default_backend() == "tpu"
        and not cfg.sliding_window
    ):
        # [B,S,H,hd] -> [B,H,S,d] / [B,KV,S,d] for the Pallas GQA kernel
        o = ops.gqa_flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True,
        ).transpose(0, 2, 1, 3)
    elif impl == "dense":
        o = dense_attention(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        o = chunked_attention(
            q, k, v, causal=True, chunk=cfg.attn_chunk, window=cfg.sliding_window
        )
    out = jnp.einsum("bshk,hkd->bsd", o, qweight(params["wo"], o.dtype))
    if return_kv:
        return out, kv_cache
    return out


# ---------------------------------------------------------------------------
# Decode path with KV cache
# ---------------------------------------------------------------------------


def attention_decode(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cur_len: jax.Array,
    mesh_info=None,
    block_tables: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
):
    """One decode step.

    x: [B, 1, d]; cache_k/v: [B, S_max, KV, hd]; cur_len: [] or [B] tokens
    already in the cache. Returns (out [B,1,d], new_k, new_v). With
    ``mesh_info`` the step runs head-sharded over the ``model`` axis
    (merge-mode serving): q and the KV cache split on their head dims, the
    per-shard partial outputs of the ``wo`` contraction all-reduce.

    With ``block_tables`` ([B, max_blocks] int32) the cache arguments are
    instead a block-paged pool ``[num_blocks, block_size, KV, hd]``
    (:mod:`repro.serve.kv_pool`): the new K/V scatter lands at the
    sequence's ``(block, offset)`` for position ``cur_len`` (an
    unallocated-sentinel table entry drops the write — inert slots never
    touch another request's blocks), and attention dispatches through
    ``ops.paged_decode_attention``, whose CPU path is bit-identical to the
    dense gather.

    With ``k_scale``/``v_scale`` ([B, S_max, KV] — or [num_blocks,
    block_size, KV] paged — f32) the cache stores quantized rows: the new
    token's K/V quantize per-(position, head) row at insert (O(written
    rows), never a cache-sized requant), the scales scatter alongside the
    payloads, and attention dequantizes inside the kernel. Returns a
    5-tuple (out, new_k, new_v, new_k_scale, new_v_scale) in that case.
    When the cache dtype is f32 the rows store verbatim with scale 1.0 —
    bit-identical outputs to the unscaled path.
    """
    b, _, d = x.shape
    quant = k_scale is not None

    q = jnp.einsum("bsd,dhk->bshk", x, qweight(params["wq"], x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, qweight(params["wk"], x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, qweight(params["wv"], x.dtype))
    q = _head_constraint(q, mesh_info, 2)
    k = _head_constraint(k, mesh_info, 2)
    v = _head_constraint(v, mesh_info, 2)
    cache_k = _head_constraint(cache_k, mesh_info, 2)
    cache_v = _head_constraint(cache_v, mesh_info, 2)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    pos = jnp.broadcast_to(jnp.asarray(cur_len), (b,))[:, None]  # [B,1]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    if quant:
        # per-(position, head)-row quantization of the freshly projected
        # K/V — identity (payload, ones) when the cache stores f32
        k, ks = quantize_rows(k, cache_k.dtype)
        v, vs = quantize_rows(v, cache_v.dtype)

    if block_tables is None:
        # scatter the new k/v at cur_len
        cache_k = _scatter_step(cache_k, k, cur_len)
        cache_v = _scatter_step(cache_v, v, cur_len)
        if quant:
            k_scale = _scatter_step(k_scale, ks, cur_len)
            v_scale = _scatter_step(v_scale, vs, cur_len)

        # grouped decode attention: never expands the cache to H heads
        # (materializing [B,S,H,hd] per layer is a groups× transient blowup
        # at 32k context); cache may be int8/f8 storage — the kernel widens
        # per-tile in-register, so no dequantized cache copy exists
        o = ops.decode_attention(
            q[:, 0], cache_k, cache_v, cur_len, window=cfg.sliding_window,
            k_scale=k_scale, v_scale=v_scale,
        )[:, None]  # [B,1,H,hd]
    else:
        # paged: (slot, cur_len) -> (block, offset) through the sequence's
        # table row; an unallocated sentinel entry is out of pool range and
        # the write drops (inert/finished slots never corrupt a block that
        # was reassigned to another request)
        bs = cache_k.shape[1]
        p = pos[:, 0]
        blk = block_tables[
            jnp.arange(b), jnp.minimum(p // bs, block_tables.shape[1] - 1)
        ]
        cache_k = cache_k.at[blk, p % bs].set(
            k[:, 0].astype(cache_k.dtype), mode="drop"
        )
        cache_v = cache_v.at[blk, p % bs].set(
            v[:, 0].astype(cache_v.dtype), mode="drop"
        )
        if quant:
            k_scale = k_scale.at[blk, p % bs].set(ks[:, 0], mode="drop")
            v_scale = v_scale.at[blk, p % bs].set(vs[:, 0], mode="drop")
        o = ops.paged_decode_attention(
            q[:, 0], cache_k, cache_v, cur_len, block_tables,
            window=cfg.sliding_window, k_scale=k_scale, v_scale=v_scale,
        )[:, None]
    out = jnp.einsum("bshk,hkd->bsd", o, qweight(params["wo"], o.dtype))
    if quant:
        return out, cache_k, cache_v, k_scale, v_scale
    return out, cache_k, cache_v


def attention_packed(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    tok_slot: jax.Array,
    tok_pos: jax.Array,
    valid: Optional[jax.Array] = None,
    pack_slots: Optional[jax.Array] = None,
    mesh_info=None,
    block_tables: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
):
    """Packed variable-length step: any mix of decode singletons and prefill
    chunks as ONE flat token batch (the unified serving dispatch).

    x: [T, d] packed hidden states; cache_k/v: [B, S_max, KV, hd];
    tok_slot/tok_pos: [T] int32 — token t belongs to cache slot
    ``tok_slot[t]`` at absolute position ``tok_pos[t]``; ``valid``
    optionally passes the precomputed per-pack attention mask (shared by
    every layer). The new K/V are scattered at (slot, pos) in one fused
    scatter (out-of-bounds positions — the pack's bucket padding — are
    dropped), then every token attends with its own causal bound
    ``p <= tok_pos[t]``: a prefill chunk is causally exact against both the
    already-cached prefix and its own earlier tokens written by the same
    scatter. Returns (out [T, d], new_k, new_v).

    With ``pack_slots`` ([P] int32, P ≪ B), ``tok_slot`` holds indices INTO
    ``pack_slots`` and attention runs against only those P gathered cache
    rows — the oracle's masked full-cross score plane then scales with the
    slots actually packed (a handful of admitting sequences), not the whole
    slot pool. Scatters still land in the full cache.

    With ``block_tables`` ([B, max_blocks] int32) the cache arguments are
    a block-paged pool ``[num_blocks, block_size, KV, hd]`` and the
    ``(slot, pos)`` indirection generalizes to ``(block, offset)``: the
    fused scatter routes through the token's table row (bucket-padding
    positions ≥ max_blocks*block_size map to the out-of-range sentinel
    and drop, exactly like the dense out-of-bounds drop), and attention
    dispatches through ``ops.paged_ragged_attention`` against the pack's
    table rows. Prefix-shared blocks are never written here — the engine
    only feeds tokens past the matched prefix, so every scattered
    position lands in a private block (block-aligned copy-on-write).

    With ``k_scale``/``v_scale`` the cache stores quantized rows (see
    :func:`attention_decode`): the pack's T fresh K/V rows quantize at
    insert and their scales scatter through the same (slot, pos) /
    (block, offset) routing as the payloads — O(T) scale rows written per
    step. Returns (out, new_k, new_v, new_k_scale, new_v_scale).
    """
    quant = k_scale is not None
    q = jnp.einsum("td,dhk->thk", x, qweight(params["wq"], x.dtype))
    k = jnp.einsum("td,dhk->thk", x, qweight(params["wk"], x.dtype))
    v = jnp.einsum("td,dhk->thk", x, qweight(params["wv"], x.dtype))
    q = _head_constraint(q, mesh_info, 1)
    k = _head_constraint(k, mesh_info, 1)
    v = _head_constraint(v, mesh_info, 1)
    cache_k = _head_constraint(cache_k, mesh_info, 2)
    cache_v = _head_constraint(cache_v, mesh_info, 2)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    pos = jnp.asarray(tok_pos, jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    glob_slot = tok_slot if pack_slots is None else pack_slots[tok_slot]
    if quant:
        k, ks = quantize_rows(k, cache_k.dtype)  # [T,KV,hd] -> scale [T,KV]
        v, vs = quantize_rows(v, cache_v.dtype)
    if block_tables is None:
        # one fused scatter for the whole pack replaces the per-admission
        # full-cache insert: O(T) rows written, never a cache-sized copy
        cache_k = cache_k.at[glob_slot, pos].set(k.astype(cache_k.dtype), mode="drop")
        cache_v = cache_v.at[glob_slot, pos].set(v.astype(cache_v.dtype), mode="drop")
        if quant:
            k_scale = k_scale.at[glob_slot, pos].set(ks, mode="drop")
            v_scale = v_scale.at[glob_slot, pos].set(vs, mode="drop")

        if pack_slots is None:
            att_k, att_v = cache_k, cache_v
            att_ks, att_vs = k_scale, v_scale
        else:  # P-row sub-cache view: attention work scales with the pack
            att_k, att_v = cache_k[pack_slots], cache_v[pack_slots]
            att_ks = None if k_scale is None else k_scale[pack_slots]
            att_vs = None if v_scale is None else v_scale[pack_slots]
        o = ops.ragged_attention(
            q, att_k, att_v, tok_slot, pos,
            window=cfg.sliding_window, valid=valid,
            k_scale=att_ks, v_scale=att_vs,
        )  # [T, H, hd]
    else:
        # paged pool: same fused scatter through the (block, offset)
        # indirection. Positions past the table (bucket padding) pick the
        # out-of-range sentinel explicitly — clamping the table index and
        # letting a real block id through would corrupt offset 0 of a live
        # block; mode="drop" needs the OOB id to survive to the scatter
        bs = cache_k.shape[1]
        maxb = block_tables.shape[1]
        nb = cache_k.shape[0]
        bidx = jnp.minimum(pos // bs, maxb - 1)
        blk = jnp.where(pos < maxb * bs, block_tables[glob_slot, bidx], nb)
        cache_k = cache_k.at[blk, pos % bs].set(k.astype(cache_k.dtype), mode="drop")
        cache_v = cache_v.at[blk, pos % bs].set(v.astype(cache_v.dtype), mode="drop")
        if quant:
            k_scale = k_scale.at[blk, pos % bs].set(ks, mode="drop")
            v_scale = v_scale.at[blk, pos % bs].set(vs, mode="drop")

        att_btab = (
            block_tables if pack_slots is None else block_tables[pack_slots]
        )
        o = ops.paged_ragged_attention(
            q, cache_k, cache_v, tok_slot, pos, att_btab,
            window=cfg.sliding_window, valid=valid,
            k_scale=k_scale, v_scale=v_scale,
        )  # [T, H, hd]
    out = jnp.einsum("thk,hkd->td", o, qweight(params["wo"], o.dtype))
    if quant:
        return out, cache_k, cache_v, k_scale, v_scale
    return out, cache_k, cache_v


def _scatter_step(cache: jax.Array, new: jax.Array, cur_len: jax.Array) -> jax.Array:
    """Write new [B,1,...] into cache [B,S,...] at position cur_len (per-batch).

    Scalar ``cur_len`` (all sequences aligned — the dry-run decode cells)
    uses one dynamic_update_slice; per-batch lengths use a vmapped
    dynamic_update_slice — an O(1)-per-row write instead of the old O(S)
    one-hot blend that read+wrote the entire cache every decode step.
    """
    cur_len = jnp.asarray(cur_len)
    if cur_len.ndim == 0:
        start = (0, cur_len) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype), start)
    b = cache.shape[0]
    pos = jnp.broadcast_to(cur_len, (b,))

    def write_row(c, n, p):  # c: [S,...], n: [1,...], p: []
        return jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (p,) + (0,) * (c.ndim - 1)
        )

    return jax.vmap(write_row)(cache, new, pos)
