"""Attention: GQA/MHA with dense and memory-efficient (chunked online-softmax)
implementations, qk-norm, RoPE, sliding windows, and a KV-cache decode path.

The chunked implementation is the CPU/XLA analogue of the Pallas
flash-attention kernel (``repro.kernels.flash_attention``): it never
materializes the full S×S score matrix — it scans KV blocks carrying the
online (max, sum, acc) triple. On TPU the Pallas kernel takes over via
``repro.kernels.ops.flash_attention``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, apply_rope, dense_init, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ArchConfig, dtype) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, (d, H, hd), dtype),
        "wk": dense_init(k2, (d, KV, hd), dtype),
        "wv": dense_init(k3, (d, KV, hd), dtype),
        "wo": dense_init(k4, (H, hd, d), dtype, fan_in=H * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


# ---------------------------------------------------------------------------
# Score-level primitives
# ---------------------------------------------------------------------------


def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    """[B, S, KV, hd] -> [B, S, KV*groups, hd] by head repetition."""
    if groups == 1:
        return x
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, groups, hd))
    return x.reshape(b, s, kv * groups, hd)


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    window: int = 0,
) -> jax.Array:
    """Reference attention materializing the full score matrix.

    q: [B, Sq, H, hd], k/v: [B, Sk, H, hd] (already GQA-expanded).
    q_offset: absolute position of q[0] (for causal masking vs a longer k).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd**-0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    chunk: int = 512,
    q_offset: int | jax.Array = 0,
    window: int = 0,
) -> jax.Array:
    """Flash-style online-softmax attention scanning KV chunks.

    Never materializes [Sq, Sk]; per-step footprint is [B, H, Sq, chunk].
    Matches :func:`dense_attention` to fp tolerance.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    if sk % chunk:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pad_mask = jnp.arange(sk + pad) < sk  # [Skp]
    else:
        pad = 0
        pad_mask = None
    skp = k.shape[1]
    n_chunks = skp // chunk
    scale = hd**-0.5

    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    qpos = jnp.arange(sq) + q_offset  # [Sq]
    qf = q.astype(jnp.float32)

    @jax.checkpoint
    def body(carry, xs):
        # rematted: the [B,H,Sq,chunk] probability block is recomputed in
        # backward rather than saved per KV chunk
        m, l, acc = carry  # [B,H,Sq], [B,H,Sq], [B,H,Sq,hd]
        kci, vci, ci = xs  # [B,chunk,H,hd] x2, scalar chunk index
        scores = (
            jnp.einsum("bqhd,bkhd->bhqk", qf, kci.astype(jnp.float32)) * scale
        )  # [B,H,Sq,chunk]
        kpos = ci * chunk + jnp.arange(chunk)  # [chunk]
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        if pad_mask is not None:
            mask &= (kpos < sk)[None, :]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vci.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B,Sq,H,hd]


# ---------------------------------------------------------------------------
# Full block-level forward (projections + rope + attention)
# ---------------------------------------------------------------------------


def attention_apply(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    impl: Optional[str] = None,
    return_kv: bool = False,
):
    """Self-attention over a full sequence (train / prefill).

    x: [B, S, d]; positions: [S] or [B, S]. With ``return_kv`` also returns
    the post-rope, pre-GQA-expansion (k, v) [B,S,KV,hd] — exactly the decode
    cache layout, enabling prefill-into-cache.
    """
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    impl = impl or cfg.attn_impl

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if positions.ndim == 1:
        positions = positions[None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kv_cache = (k, v) if return_kv else None
    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)

    if impl == "dense":
        o = dense_attention(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        o = chunked_attention(
            q, k, v, causal=True, chunk=cfg.attn_chunk, window=cfg.sliding_window
        )
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    if return_kv:
        return out, kv_cache
    return out


# ---------------------------------------------------------------------------
# Decode path with KV cache
# ---------------------------------------------------------------------------


def attention_decode(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cur_len: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step.

    x: [B, 1, d]; cache_k/v: [B, S_max, KV, hd]; cur_len: [] or [B] tokens
    already in the cache. Returns (out [B,1,d], new_k, new_v).
    """
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, _, d = x.shape
    s_max = cache_k.shape[1]

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    pos = jnp.broadcast_to(jnp.asarray(cur_len), (b,))[:, None]  # [B,1]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    # scatter the new k/v at cur_len
    cache_k = _scatter_step(cache_k, k, cur_len)
    cache_v = _scatter_step(cache_v, v, cur_len)

    # grouped-GQA scores: never expand the cache to H heads (materializing
    # [B,S,H,hd] per layer is a groups× transient blowup at 32k context)
    g = H // KV
    qg = q.reshape(b, 1, KV, g, hd)
    scale = hd**-0.5
    scores = (
        jnp.einsum("bqkgd,btkd->bkgqt", qg, cache_k.astype(q.dtype)).astype(
            jnp.float32
        )
        * scale
    )  # [B,KV,G,1,S]  (cache may be f8 storage; compute in model dtype)
    kpos = jnp.arange(s_max)[None, :]  # [1, S]
    valid = kpos <= jnp.broadcast_to(jnp.asarray(cur_len), (b,))[:, None]
    if cfg.sliding_window:
        valid &= kpos > (
            jnp.broadcast_to(jnp.asarray(cur_len), (b,))[:, None] - cfg.sliding_window
        )
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqt,btkd->bqkgd", probs, cache_v.astype(q.dtype))
    o = o.reshape(b, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return out, cache_k, cache_v


def _scatter_step(cache: jax.Array, new: jax.Array, cur_len: jax.Array) -> jax.Array:
    """Write new [B,1,...] into cache [B,S,...] at position cur_len (per-batch).

    Scalar ``cur_len`` (all sequences aligned — the dry-run decode cells) uses
    a cheap dynamic_update_slice; per-batch lengths use a one-hot blend.
    """
    cur_len = jnp.asarray(cur_len)
    if cur_len.ndim == 0:
        start = (0, cur_len) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype), start)
    b, s = cache.shape[:2]
    pos = jnp.broadcast_to(cur_len, (b,))
    onehot = (jnp.arange(s)[None, :] == pos[:, None]).astype(cache.dtype)
    onehot = onehot.reshape(b, s, *((1,) * (cache.ndim - 2)))
    return cache * (1 - onehot) + onehot * new.astype(cache.dtype)
