"""Mixture-of-Experts block with expert parallelism.

Two execution paths sharing one per-shard implementation:

* **reference** (no mesh): every expert lives on the one shard; used by smoke
  tests and as the property-test oracle.
* **EP** (``shard_map`` over the model axis): routed experts are sharded on
  the ``model`` mesh axis; activations arrive replicated over ``model`` (they
  are sharded over the batch axes), each shard computes *its* experts for all
  local tokens via a capacity-bounded sort-free dispatch (one-hot cumsum slot
  assignment, gather → expert GEMM → scatter-add), and a single ``psum`` over
  ``model`` combines partial outputs. This is the "masked local experts +
  reduce" EP style: it trades the all-to-all of token-routed EP for zero
  resharding of activations, which is the right trade on a 1-hop ICI axis
  where the model dimension is already being all-reduced by TP anyway.

Shared experts are mathematically folded into one wider SwiGLU MLP (the sum
of gated MLPs equals a single MLP over the concatenated hidden dim) and run
as a normal TP MLP outside the shard_map region.

Auxiliary load-balance loss (Switch-style): ``E * Σ_e f_e · P_e``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, dense_init


def moe_init(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ff = m.expert_ff or cfg.d_ff
    k_router, k_in, k_gate, k_out, k_shared = jax.random.split(key, 5)
    E = m.n_routed
    p: Params = {
        "router": dense_init(k_router, (d, E), jnp.float32),
        "w_in": dense_init(k_in, (E, d, ff), dtype),
        "w_gate": dense_init(k_gate, (E, d, ff), dtype),
        "w_out": dense_init(k_out, (E, ff, d), dtype, fan_in=ff),
    }
    if m.n_shared:
        from repro.models.layers import mlp_init

        p["shared"] = mlp_init(k_shared, d, m.n_shared * ff, dtype)
    return p


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k * m.capacity_factor / m.n_routed) + 1
    # tiny token counts (decode steps): give full capacity — a dropped token
    # at decode corrupts its sequence, and the slot table is tiny anyway.
    c = max(c, min(tokens, 16))
    return max(min(c, tokens), 1)


def _moe_shard(
    x_flat: jax.Array,  # [T, d] local tokens
    router: jax.Array,  # [d, E] (replicated)
    w_in: jax.Array,  # [E_loc, d, f]
    w_gate: jax.Array,
    w_out: jax.Array,  # [E_loc, f, d]
    cfg: ArchConfig,
    model_axis: Optional[str],
) -> tuple[jax.Array, jax.Array]:
    """Per-shard MoE: compute local experts for all local tokens, psum outputs.

    Returns (out [T, d], aux_loss scalar).
    """
    m = cfg.moe
    T, d = x_flat.shape
    E = m.n_routed
    E_loc = w_in.shape[0]
    k = m.top_k
    C = _capacity(T, cfg)

    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router)  # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # [T,k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # load-balance aux (computed on the full router view; identical on every
    # model shard, so no psum needed for it)
    dispatch_frac = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * k)
    mean_prob = probs.mean(axis=0)
    aux = E * jnp.sum(dispatch_frac * mean_prob)

    if model_axis is not None:
        shard_id = jax.lax.axis_index(model_axis)
    else:
        shard_id = 0
    e_first = shard_id * E_loc

    # flatten (token, k) assignment entries; keep only local experts
    flat_e = top_e.reshape(-1)  # [T*k]
    flat_w = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    local = (flat_e >= e_first) & (flat_e < e_first + E_loc)
    e_loc = jnp.where(local, flat_e - e_first, 0)

    # slot position within each local expert: exclusive cumsum of one-hots
    onehot = jax.nn.one_hot(e_loc, E_loc, dtype=jnp.int32) * local[:, None].astype(
        jnp.int32
    )  # [T*k, E_loc]
    pos = jnp.cumsum(onehot, axis=0) - onehot  # exclusive
    slot = jnp.sum(pos * onehot, axis=-1)  # [T*k]
    keep = local & (slot < C)

    # scatter entries into [E_loc, C] slot tables (dropped entries -> slot C)
    safe_e = jnp.where(keep, e_loc, 0)
    safe_s = jnp.where(keep, slot, C)  # C row is a trash slot
    slot_tok = jnp.zeros((E_loc, C + 1), jnp.int32).at[safe_e, safe_s].set(
        flat_t, mode="drop"
    )[:, :C]
    slot_w = jnp.zeros((E_loc, C + 1), jnp.float32).at[safe_e, safe_s].set(
        jnp.where(keep, flat_w, 0.0), mode="drop"
    )[:, :C]
    slot_valid = jnp.zeros((E_loc, C + 1), jnp.bool_).at[safe_e, safe_s].set(
        keep, mode="drop"
    )[:, :C]

    xg = x_flat[slot_tok] * slot_valid[..., None].astype(x_flat.dtype)  # [E_loc,C,d]
    h = jnp.einsum("ecd,edf->ecf", xg, w_in)
    g = jnp.einsum("ecd,edf->ecf", xg, w_gate)
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, w_out)  # [E_loc,C,d]

    y = y * (slot_w * slot_valid)[..., None].astype(y.dtype)
    out = (
        jnp.zeros((T, d), jnp.float32)
        .at[slot_tok.reshape(-1)]
        .add(y.reshape(-1, d).astype(jnp.float32), mode="drop")
    )
    if model_axis is not None:
        # perf: psum the combined expert outputs in bf16, not f32 — halves
        # the EP collective bytes. Each token sums ≤ top_k (+shared) expert
        # outputs, so the bf16 reduction error is a couple of ulps.
        out = jax.lax.psum(out.astype(x_flat.dtype), model_axis)
    return out.astype(x_flat.dtype), aux


def moe_apply(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,  # [B, S, d]
    *,
    mesh_info=None,
) -> tuple[jax.Array, jax.Array]:
    """Routed experts (+ shared experts) over a full activation tensor.

    Returns (out [B,S,d], aux scalar).
    """
    from repro.models.quant import qweight  # read-through int8 dequant

    b, s, d = x.shape
    # dequantize the expert stacks at entry (per-layer transient under the
    # scan; the router is never quantized — see repro.models.quant); the
    # unquantized path passes the original arrays through untouched
    w_in = qweight(params["w_in"], x.dtype)
    w_gate = qweight(params["w_gate"], x.dtype)
    w_out = qweight(params["w_out"], x.dtype)

    if mesh_info is not None and mesh_info.model_size > 1:
        from jax.sharding import PartitionSpec as P

        batch_axes = mesh_info.batch_axes
        model_axis = mesh_info.model_axis

        def shard_fn(xs, router, w_in, w_gate, w_out):
            t = xs.shape[0] * xs.shape[1]
            out, aux = _moe_shard(
                xs.reshape(t, d), router, w_in, w_gate, w_out, cfg, model_axis
            )
            return out.reshape(xs.shape), aux

        out, aux = jax.shard_map(
            shard_fn,
            mesh=mesh_info.mesh,
            in_specs=(
                P(batch_axes, None, None),
                P(None, None),
                P(model_axis, None, None),
                P(model_axis, None, None),
                P(model_axis, None, None),
            ),
            out_specs=(P(batch_axes, None, None), P()),
            check_vma=False,
        )(x, params["router"], w_in, w_gate, w_out)
        aux = aux  # identical on all shards
    else:
        out_flat, aux = _moe_shard(
            x.reshape(b * s, d),
            params["router"],
            w_in,
            w_gate,
            w_out,
            cfg,
            None,
        )
        out = out_flat.reshape(b, s, d)

    if "shared" in params:
        from repro.models.layers import mlp_apply

        out = out + mlp_apply(params["shared"], x)
    return out, aux


def moe_reference_dense(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Oracle: compute EVERY expert densely and mix by (renormalized) top-k
    weights — no capacity drops. Used by property tests with high capacity."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros_like(probs).at[jnp.arange(xf.shape[0])[:, None], top_e].set(top_p)

    h = jnp.einsum("td,edf->tef", xf, params["w_in"])
    g = jnp.einsum("td,edf->tef", xf, params["w_gate"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    y = jnp.einsum("tef,efd->ted", h, params["w_out"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), w)
    out = out.astype(x.dtype).reshape(b, s, d)
    if "shared" in params:
        from repro.models.layers import mlp_apply

        out = out + mlp_apply(params["shared"], x)
    return out
