"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Train/prefill uses the expanded form (decompress KV, normal attention).
Decode uses the ABSORBED form: only the compressed latent c_kv (rank
``kv_lora_rank``) plus the shared rope key are cached — the whole point of
MLA — and W_uk / W_uv are absorbed into the query/output projections, making
decode an MQA over a (kv_lora + rope_dim)-wide shared "head".

Cache per token = kv_lora + rope_dim floats (e.g. 576 for DeepSeek-V2) vs
2·H·hd for GQA — a 10-100× KV-memory reduction at long context.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.attention import NEG_INF, chunked_attention, dense_attention
from repro.models.layers import Params, apply_rope, dense_init, rms_norm


def mla_init(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_dim = m.nope_head_dim + m.rope_head_dim
    keys = jax.random.split(key, 6)
    p: Params = {}
    if m.q_lora_rank:
        p["wq_a"] = dense_init(keys[0], (d, m.q_lora_rank), dtype)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(keys[1], (m.q_lora_rank, H, qk_dim), dtype, fan_in=m.q_lora_rank)
    else:
        p["wq"] = dense_init(keys[0], (d, H, qk_dim), dtype)
    p["wkv_a"] = dense_init(keys[2], (d, m.kv_lora_rank + m.rope_head_dim), dtype)
    p["kv_norm"] = jnp.ones((m.kv_lora_rank,), dtype)
    p["wkv_b"] = dense_init(
        keys[3],
        (m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim),
        dtype,
        fan_in=m.kv_lora_rank,
    )
    p["wo"] = dense_init(keys[4], (H, m.v_head_dim, d), dtype, fan_in=H * m.v_head_dim)
    return p


def _project_q(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    m = cfg.mla
    if m.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
        cq = rms_norm(cq, params["q_norm"], cfg.norm_eps)
        return jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    return jnp.einsum("bsd,dhk->bshk", x, params["wq"])


def mla_apply(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    return_kv: bool = False,
):
    """Full-sequence MLA (train / prefill), expanded form. x: [B,S,d].

    With ``return_kv`` also returns (c_kv [B,S,r], k_rope [B,S,rope_dim]) —
    the compressed-latent decode cache layout."""
    m = cfg.mla
    H = cfg.n_heads
    if positions.ndim == 1:
        positions = positions[None]

    q = _project_q(params, cfg, x)  # [B,S,H,nope+rope]
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])  # [B,S,kv_lora+rope]
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,rope]

    kv = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b"])
    k_nope, v = jnp.split(kv, [m.nope_head_dim], axis=-1)

    k_rope_b = jnp.broadcast_to(k_rope, (*k_rope.shape[:2], H, m.rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)

    # pad v up to qk width so we can reuse the shared attention primitives,
    # then slice back (cheap: concat of zeros, sliced after).
    qk_dim = m.nope_head_dim + m.rope_head_dim
    if m.v_head_dim < qk_dim:
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
    else:
        v_p = v
    if cfg.attn_impl == "dense":
        o = dense_attention(q_full, k_full, v_p, causal=True)
    else:
        o = chunked_attention(q_full, k_full, v_p, causal=True, chunk=cfg.attn_chunk)
    o = o[..., : m.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    if return_kv:
        return out, (c_kv, k_rope[:, :, 0, :])
    return out


# ---------------------------------------------------------------------------
# Absorbed decode with compressed cache
# ---------------------------------------------------------------------------


def mla_packed(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache_ckv: jax.Array,
    cache_krope: jax.Array,
    tok_slot: jax.Array,
    tok_pos: jax.Array,
    valid: jax.Array | None = None,
    pack_slots: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Packed variable-length MLA step over the compressed latent cache —
    the latent-space twin of ``attention_packed`` (unified serving
    dispatch: decode singletons and prefill chunks as ONE flat batch).

    x: [T, d] packed hidden states; cache_ckv: [B, S_max, kv_lora];
    cache_krope: [B, S_max, rope_dim]; tok_slot/tok_pos: [T] int32. The
    pack's fresh latents are ONE fused O(T) scatter (bucket-padding
    positions drop), then every token attends in absorbed form against
    the compressed cache. With ``pack_slots`` ([P] int32) attention reads
    only those P gathered latent rows. Returns (out [T, d], new_ckv,
    new_krope)."""
    m = cfg.mla
    pos = jnp.asarray(tok_pos, jnp.int32)

    q = _project_q(params, cfg, x[None])[0]  # [T,H,nope+rope]
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    ckv = jnp.einsum("td,dr->tr", x, params["wkv_a"])  # [T,kv_lora+rope]
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, None, :], pos, cfg.rope_theta)[:, 0, :]

    glob_slot = tok_slot if pack_slots is None else pack_slots[tok_slot]
    cache_ckv = cache_ckv.at[glob_slot, pos].set(
        c_kv.astype(cache_ckv.dtype), mode="drop"
    )
    cache_krope = cache_krope.at[glob_slot, pos].set(
        k_rope.astype(cache_krope.dtype), mode="drop"
    )
    if pack_slots is None:
        att_ckv, att_krope = cache_ckv, cache_krope
    else:  # P-row sub-cache view: attention work scales with the pack
        att_ckv, att_krope = cache_ckv[pack_slots], cache_krope[pack_slots]

    # absorb W_uk into q (q_eff [T,H,kv_lora]) and attend in latent space
    w_uk = params["wkv_b"][..., : m.nope_head_dim]  # [r,H,nope]
    q_eff = jnp.einsum("thk,rhk->thr", q_nope, w_uk)
    lat = ops.mla_ragged_attention(
        q_eff, q_rope, att_ckv, att_krope, tok_slot, pos,
        scale=(m.nope_head_dim + m.rope_head_dim) ** -0.5, valid=valid,
    )  # [T,H,r]
    w_uv = params["wkv_b"][..., m.nope_head_dim :]  # [r,H,v]
    o = jnp.einsum("thr,rhv->thv", lat.astype(x.dtype), w_uv)
    out = jnp.einsum("thv,hvd->td", o, params["wo"])
    return out, cache_ckv, cache_krope


def mla_decode(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache_ckv: jax.Array,
    cache_krope: jax.Array,
    cur_len: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step with the latent cache.

    x: [B,1,d]; cache_ckv: [B,S,kv_lora]; cache_krope: [B,S,rope_dim].
    """
    m = cfg.mla
    H = cfg.n_heads
    b = x.shape[0]
    s_max = cache_ckv.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(cur_len), (b,))[:, None]  # [B,1]

    q = _project_q(params, cfg, x)  # [B,1,H,nope+rope]
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]

    from repro.models.attention import _scatter_step

    cache_ckv = _scatter_step(cache_ckv, c_kv, cur_len)
    cache_krope = _scatter_step(cache_krope, k_rope, cur_len)

    # absorb W_uk into q: q_eff [B,1,H,kv_lora]
    w_uk = params["wkv_b"][..., : m.nope_head_dim]  # [r,H,nope]
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)
    scores = (
        jnp.einsum("bshr,btr->bhst", q_eff.astype(jnp.float32), cache_ckv.astype(jnp.float32))
        + jnp.einsum(
            "bshk,btk->bhst", q_rope.astype(jnp.float32), cache_krope.astype(jnp.float32)
        )
    ) * ((m.nope_head_dim + m.rope_head_dim) ** -0.5)
    valid = jnp.arange(s_max)[None, :] <= jnp.broadcast_to(jnp.asarray(cur_len), (b,))[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)

    # attend in latent space, then decompress through W_uv (absorbed output)
    lat = jnp.einsum(
        "bhst,btr->bshr", probs.astype(x.dtype), cache_ckv.astype(x.dtype)
    )
    w_uv = params["wkv_b"][..., m.nope_head_dim :]  # [r,H,v]
    o = jnp.einsum("bshr,rhv->bshv", lat, w_uv)
    out = jnp.einsum("bshv,hvd->bsd", o, params["wo"])
    return out, cache_ckv, cache_krope
