from repro.models.model import LM, input_specs

__all__ = ["LM", "input_specs"]
