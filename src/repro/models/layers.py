"""Shared neural-net layers: norms, RoPE, gated MLP, embeddings.

Conventions:
  * params are nested dicts of jnp arrays; leading ``L`` axis when stacked
    for ``lax.scan`` over layers.
  * weights stored in ``cfg.dtype`` (bf16 by default); math that needs f32
    (norms, softmax, rope phases) upcasts locally.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def dtype_of(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in: int | None = None):
    """Truncated-normal with 1/sqrt(fan_in) scaling (fan_in = shape[0] default)."""
    if fan_in is None:
        fan_in = shape[0]
    scale = fan_in**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(
        dtype
    )


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in f32 with cast back to x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for RoPE, shape [dim//2], f32."""
    return 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]) by position-dependent phases.

    x: [..., S, n, d]  (n = heads axis, may be 1)
    positions: [..., S] int32 — broadcast against x's S axis.
    """
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)  # [d/2]
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [..., S, d/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": dense_init(k1, (d_model, d_ff), dtype),
        "w_gate": dense_init(k2, (d_model, d_ff), dtype),
        "w_out": dense_init(k3, (d_ff, d_model), dtype, fan_in=d_ff),
    }


def mlp_apply(params: Params, x: jax.Array) -> jax.Array:
    from repro.models.quant import qweight  # read-through int8 dequant

    h = jnp.einsum("...d,df->...f", x, qweight(params["w_in"], x.dtype))
    g = jnp.einsum("...d,df->...f", x, qweight(params["w_gate"], x.dtype))
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("...f,fd->...d", h, qweight(params["w_out"], x.dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d_model: int, dtype, tie: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"tok": embed_init(k1, (vocab, d_model), dtype)}
    if not tie:
        p["head"] = dense_init(k2, (d_model, vocab), dtype)
    return p


def embed_tokens(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: Params, x: jax.Array) -> jax.Array:
    if "head" in params:
        return jnp.einsum("...d,dv->...v", x, params["head"])
    return jnp.einsum("...d,vd->...v", x, params["tok"])
