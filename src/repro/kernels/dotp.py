"""Dot-product reduction kernel (the paper's dotp).

Grid of VMEM blocks, each contributing a partial f32 sum; the partials land
in a [grid] output reduced by the wrapper (tree reduction outside keeps the
kernel single-pass and avoids cross-block sequential dependencies). The C
overhang of the tail block is zeroed in-kernel with an iota mask, so the
dispatch layer never pads."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dotp_kernel(x_ref, y_ref, o_ref, *, block: int, c_size: int):
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    if c_size % block:  # tail block: mask the overhang out of the sum
        pos = pl.program_id(1) * block + jax.lax.broadcasted_iota(
            jnp.int32, x.shape, 1
        )
        x = jnp.where(pos < c_size, x, 0.0)
        y = jnp.where(pos < c_size, y, 0.0)
    o_ref[0, 0] = jnp.sum(x * y)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dotp_partials(
    x: jax.Array, y: jax.Array, *, block: int = 2048, interpret: bool = False
) -> jax.Array:
    """x, y: [R, C]; returns [R, cdiv(C, block)] partial sums (f32)."""
    r, c = x.shape
    steps = pl.cdiv(c, block)
    grid = (r, steps)
    return pl.pallas_call(
        functools.partial(_dotp_kernel, block=block, c_size=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, steps), jnp.float32),
        interpret=interpret,
    )(x, y)


def dotp(x: jax.Array, y: jax.Array, *, block: int = 2048, interpret: bool = False):
    return dotp_partials(x, y, block=block, interpret=interpret).sum()
