"""Dot-product reduction kernel (the paper's dotp).

Grid of VMEM blocks, each contributing a partial f32 sum; the partials land
in a [grid] output reduced by the wrapper (tree reduction outside keeps the
kernel single-pass and avoids cross-block sequential dependencies)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dotp_kernel(x_ref, y_ref, o_ref):
    o_ref[0, 0] = jnp.sum(
        x_ref[...].astype(jnp.float32) * y_ref[...].astype(jnp.float32)
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dotp_partials(
    x: jax.Array, y: jax.Array, *, block: int = 2048, interpret: bool = False
) -> jax.Array:
    """x, y: [R, C]; returns [R, C//block] partial sums (f32)."""
    r, c = x.shape
    assert c % block == 0
    grid = (r, c // block)
    return pl.pallas_call(
        _dotp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c // block), jnp.float32),
        interpret=interpret,
    )(x, y)


def dotp(x: jax.Array, y: jax.Array, *, block: int = 2048, interpret: bool = False):
    return dotp_partials(x, y, block=block, interpret=interpret).sum()
