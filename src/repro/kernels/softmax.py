"""Row-softmax kernel (the paper's exp/ML kernel family).

One VMEM block per row-tile; max/exp/sum fused in one pass over the tile
(numerically stable, f32 math on the VPU)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def softmax(
    x: jax.Array, *, block_rows: int = 128, interpret: bool = False
) -> jax.Array:
    """x: [R, C]; whole row per block (rows up to a few K wide fit VMEM).
    Arbitrary R — rows are independent, so tail-block writes mask cleanly."""
    r, c = x.shape
    return pl.pallas_call(
        _softmax_kernel,
        grid=(pl.cdiv(r, block_rows),),
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(x)
