"""Flash attention forward kernels (LM hot-spot; the framework's biggest
compute consumer at prefill).

Blockwise online-softmax attention: Q tiles stay VMEM-resident while K/V
tiles stream HBM→VMEM along the innermost (sequential) grid dim; the
(m, l, acc) online-softmax state lives in f32 VMEM scratch. Causal masking
skips fully-masked K tiles via ``pl.when`` (upper-triangle tiles cost zero
MXU work). This is the Pallas twin of
``repro.models.attention.chunked_attention`` (the XLA fallback), and the
oracle is ``ref.flash_attention``.

Ragged shapes: grids ceil-divide and a key-validity iota mask inside the
kernel drops the K overhang (valid length = the true S), so non-divisible
and non-causal shapes run in-kernel instead of falling back to the oracle.

``gqa_flash_attention`` is the GQA-native variant: the grid iterates KV
heads with the Q-head group as its own (parallel) grid dim, so one K/V tile
serves the whole group and K/V are never physically repeated ``H//KV``-fold.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _online_softmax_update(s, v, m_ref, l_ref, acc_ref):
    """One K-tile's online (max, sum, acc) update. s: [bq, bk] f32 scores."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new


def _flash_tile_body(
    q_tile, k_tile, v_tile, o_ref, m_ref, l_ref, acc_ref, write_out, *,
    qi, ki, causal: bool, k_steps: int, block_q: int, block_k: int, kv_len: int
):
    """Shared per-tile body of the flash kernels: init at the first K step,
    masked score compute + online-softmax update (with the causal tile
    skip), flush at the last. ``q_tile``/``k_tile``/``v_tile`` are thunks
    reading this kernel's block layout; ``write_out`` stores the final
    tile. ``qi``/``ki`` are the Q/K grid positions (axes differ between the
    flat and GQA grids)."""

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_tile().astype(jnp.float32)  # [bq, d]
        k = k_tile().astype(jnp.float32)  # [bk, d]
        v = v_tile().astype(jnp.float32)  # [bk, d]
        d = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (d**-0.5)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < kv_len  # key-validity mask: drops the K overhang
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            valid &= kpos <= qpos
        s = jnp.where(valid, s, NEG_INF)
        if kv_len % block_k:  # overhang rows of v are undefined; p there is 0
            vpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
            v = jnp.where(vpos < kv_len, v, 0.0)
        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    if causal:
        # skip K tiles strictly above the diagonal
        pl.when((ki * block_k) <= (qi * block_q + block_q - 1))(_compute)
    else:
        _compute()

    @pl.when(ki == k_steps - 1)
    def _flush():
        write_out(
            (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30))
        )


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, causal: bool,
    k_steps: int, block_q: int, block_k: int, kv_len: int
):
    def write_out(tile):
        o_ref[0] = tile.astype(o_ref.dtype)

    _flash_tile_body(
        lambda: q_ref[0], lambda: k_ref[0], lambda: v_ref[0],
        o_ref, m_ref, l_ref, acc_ref, write_out,
        qi=pl.program_id(1), ki=pl.program_id(2), causal=causal,
        k_steps=k_steps, block_q=block_q, block_k=block_k, kv_len=kv_len,
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q/k/v: [BH, S, d] (batch·heads flattened). Arbitrary S; tails masked."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    k_steps = pl.cdiv(sk, block_k)
    grid = (bh, pl.cdiv(sq, block_q), k_steps)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            causal=causal,
            k_steps=k_steps,
            block_q=block_q,
            block_k=block_k,
            kv_len=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# GQA-native variant
# ---------------------------------------------------------------------------


def _gqa_flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, causal: bool,
    k_steps: int, block_q: int, block_k: int, kv_len: int
):
    def write_out(tile):
        o_ref[0, 0] = tile.astype(o_ref.dtype)

    # K/V tiles are shared across the group grid dim (axis 1)
    _flash_tile_body(
        lambda: q_ref[0, 0], lambda: k_ref[0], lambda: v_ref[0],
        o_ref, m_ref, l_ref, acc_ref, write_out,
        qi=pl.program_id(2), ki=pl.program_id(3), causal=causal,
        k_steps=k_steps, block_q=block_q, block_k=block_k, kv_len=kv_len,
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def gqa_flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: [BKV, G, Sq, d]; k/v: [BKV, Sk, d] (batch·KV-heads flattened, G =
    H//KV query heads per KV head). The group is a parallel grid dim whose
    K/V BlockSpec ignores it — each K/V tile is fetched once per group, not
    repeated in HBM."""
    bkv, g, sq, d = q.shape
    sk = k.shape[1]
    k_steps = pl.cdiv(sk, block_k)
    grid = (bkv, g, pl.cdiv(sq, block_q), k_steps)
    return pl.pallas_call(
        functools.partial(
            _gqa_flash_kernel,
            causal=causal,
            k_steps=k_steps,
            block_q=block_q,
            block_k=block_k,
            kv_len=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, gi, i, j: (b, gi, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, gi, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, gi, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b, gi, i, j: (b, gi, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((bkv, g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
