"""Flash attention forward kernel (LM hot-spot; the framework's biggest
compute consumer at prefill).

Blockwise online-softmax attention: Q tiles stay VMEM-resident while K/V
tiles stream HBM→VMEM along the innermost (sequential) grid dim; the
(m, l, acc) online-softmax state lives in f32 VMEM scratch. Causal masking
skips fully-masked K tiles via ``pl.when`` (upper-triangle tiles cost zero
MXU work). This is the Pallas twin of
``repro.models.attention.chunked_attention`` (the XLA fallback), and the
oracle is ``ref.flash_attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, causal: bool, k_steps: int,
    block_q: int, block_k: int
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0].astype(jnp.float32)  # [bk, d]
        d = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (d**-0.5)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # skip K tiles strictly above the diagonal
        pl.when((ki * block_k) <= (qi * block_q + block_q - 1))(_compute)
    else:
        _compute()

    @pl.when(ki == k_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q/k/v: [BH, S, d] (batch·heads flattened). S % block == 0."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0
    k_steps = sk // block_k
    grid = (bh, sq // block_q, k_steps)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel,
            causal=causal,
            k_steps=k_steps,
            block_q=block_q,
            block_k=block_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
