"""Tiled matmul Pallas kernel (the paper's fmatmul, TPU-retiled).

MXU-aligned (block_m × block_k) @ (block_k × block_n) tiles staged in VMEM,
f32 accumulator scratch, K as the innermost sequential grid dim. The RVV
kernel's strip-mined loop over vector registers becomes a 2-D systolic tile
schedule (the TPU hardware adaptation).

Shapes need NOT divide the blocks: the grid ceil-divides and tail blocks
mask the K overhang with an iota compare inside the kernel (out-of-bounds
M/N rows/cols are dropped by Pallas' masked writes), so the dispatch layer
never materializes padded copies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(
    a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, block_k: int, k_size: int
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if k_size % block_k:  # K tail: zero the overhang in both operands
        s = pl.program_id(2)
        ka = s * block_k + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        kb = s * block_k + jax.lax.broadcasted_iota(jnp.int32, b.shape, 0)
        a = jnp.where(ka < k_size, a, 0)
        b = jnp.where(kb < k_size, b, 0)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _matmul_q8_kernel(
    a_ref, b_ref, s_ref, o_ref, acc_ref, *, k_steps: int, block_k: int,
    k_size: int
):
    """Int8-RHS variant: the weight tile arrives int8 and widens in-register
    AFTER the VMEM load — the int8 tile is the only RHS HBM traffic. The
    per-output-channel dequant is algebraically a column scaling of the
    finished accumulator (out[m,n] = (Σ_k a[m,k]·q[k,n])·s[n]), so it folds
    into the flush multiply instead of touching every K tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...].astype(a.dtype)
    if k_size % block_k:  # K tail: zero the overhang in both operands
        s = pl.program_id(2)
        ka = s * block_k + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        kb = s * block_k + jax.lax.broadcasted_iota(jnp.int32, b.shape, 0)
        a = jnp.where(ka < k_size, a, 0)
        b = jnp.where(kb < k_size, b, 0)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_q8(
    a: jax.Array,
    b_q8: jax.Array,
    b_scale: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """[M,K] @ int8 [K,N] with per-output-channel f32 scales [N] -> [M,N].

    The quantized-weight-serving matmul: ``b_q8`` is a symmetric int8
    weight (``repro.models.quant``), ``b_scale`` its per-column scale.
    Matches ``matmul(a, dequant(b))`` to f32 tolerance while never
    materializing the dequantized weight."""
    m, k = a.shape
    k2, n = b_q8.shape
    assert k == k2, (a.shape, b_q8.shape)
    s2 = b_scale.reshape(1, n).astype(jnp.float32)
    k_steps = pl.cdiv(k, block_k)
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n), k_steps)
    return pl.pallas_call(
        functools.partial(
            _matmul_q8_kernel, k_steps=k_steps, block_k=block_k, k_size=k
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, block_n), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b_q8, s2)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """[M,K] @ [K,N] -> [M,N]. Arbitrary shapes; tail blocks are masked."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    k_steps = pl.cdiv(k, block_k)
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n), k_steps)
    return pl.pallas_call(
        functools.partial(
            _matmul_kernel, k_steps=k_steps, block_k=block_k, k_size=k
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b)
