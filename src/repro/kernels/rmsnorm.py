"""Fused RMSNorm kernel (LM hot-spot; beyond the paper's six kernels).

mean-square, rsqrt, and scale fused in one VMEM pass — saves two HBM round
trips vs the unfused jnp lowering."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x: [R, C], w: [C]. Arbitrary R (independent rows, masked tail)."""
    r, c = x.shape
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pl.cdiv(r, block_rows),),
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(x, w)
