"""Packed variable-length (ragged) attention kernel: a flat ``[T]`` token
batch — decode singletons and prefill chunks from different sequences mixed
freely — against the batched ``[B, S_max, KV, hd]`` decode cache.

This is the unified-dispatch serving hot path: ONE kernel serves every mix
of admission prefill chunks and decode steps, so the engine never has to
choose between stalling decode for a B=1 prefill and starving admissions.
Each packed token ``t`` carries a descriptor pair read via scalar prefetch:

* ``tok_slot[t]`` — which cache slot (batch row) the token belongs to;
* ``tok_pos[t]``  — its absolute sequence position. The token's K/V have
  already been scattered into the cache at ``(tok_slot, tok_pos)`` (the
  dispatch layer fuses that scatter), so key position ``p`` of the slot is
  valid iff ``p <= tok_pos`` — exactly the ``decode_attention`` convention,
  generalized from "one token per slot" to "any tokens, any slots". A
  prefill chunk is just consecutive tokens of one slot with increasing
  ``tok_pos``: the per-token bound makes the chunk causally exact, and
  chunk-vs-chunk boundaries need no special cases.

The grid is (token, KV head, S tiles); the query-head group rides inside
the block as a ``[G, hd]`` tile, and ``tok_slot`` indexes the cache fetch in
the BlockSpec index map — the packed batch never materializes a gathered
``[T, S_max, KV, hd]`` cache view. Tiles entirely past ``tok_pos`` (or
before the sliding window) are skipped via ``pl.when``, so decode tokens of
short sequences stay cheap inside a long-cache pack.

Padding tokens (pack ragged-to-bucket tail) should point at slot 0 with
``tok_pos >= S_max``: every tile stays live but the output row is ignored
by the caller, and the out-of-bounds scatter was already dropped upstream.

:func:`paged_ragged_attention` generalizes the same kernel from a dense
``[B, S_max, KV, hd]`` cache to a block-paged ``[num_blocks, block_size,
KV, hd]`` pool: the descriptor indirection ``(slot, pos)`` becomes
``(block, offset)`` by routing the BlockSpec's cache fetch through a
``[R, max_blocks]`` block table — S tile ``si`` of sequence row ``r``
streams from pool block ``block_tables[r, si]`` instead of cache row
``r``. Everything else (per-token causal bound, online softmax, tile
skipping) is IDENTICAL, which is the point: one kernel change carries
both packed prefill and the fused k-step decode chunks onto the paged
pool. Unallocated table entries hold the out-of-range sentinel
``num_blocks``; their tiles are provably dead (a request's table covers
every position ≤ its ``tok_pos``) and the index map clamps them in-range
so the prefetch never reads out of bounds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import _online_softmax_update

NEG_INF = -1e30


def _ragged_kernel(
    slot_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
    block_s: int, s_steps: int, window: int
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # this token's absolute position; keys at p <= pos are valid (its own
    # K/V were scattered at pos before the kernel ran)
    pos = pos_ref[pl.program_id(0)]

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bs, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # [bs, d]
        d = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (d**-0.5)
        kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos <= pos
        if window:
            valid &= kpos > pos - window
        s = jnp.where(valid, s, NEG_INF)
        # zero rows of v that can't contribute (overhang reads are undefined)
        vpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v_ok = vpos <= pos
        if window:
            v_ok &= vpos > pos - window
        v = jnp.where(v_ok, v, 0.0)
        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    # skip tiles entirely past the token's position (and before the window)
    live = si * block_s <= pos
    if window:
        live &= (si + 1) * block_s > pos - window
    pl.when(live)(_compute)

    @pl.when(si == s_steps - 1)
    def _flush():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def _ragged_kernel_q8(
    slot_ref, pos_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref,
    l_ref, acc_ref, *, block_s: int, s_steps: int, window: int
):
    """Quantized-cache variant: K/V tiles arrive in the narrow store dtype
    (int8 or float8_e4m3fn — the widen below is dtype-generic) with
    per-(position, head) f32 scale rows riding the same index map; both
    widen in-register after the VMEM load — no dequantized f32 cache copy
    ever exists in HBM."""
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[pl.program_id(0)]

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        # in-register dequant: int8 tile * its per-row scale column
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, :, 0, :]  # [bs, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, :, 0, :]  # [bs, d]
        d = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (d**-0.5)
        kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos <= pos
        if window:
            valid &= kpos > pos - window
        s = jnp.where(valid, s, NEG_INF)
        vpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v_ok = vpos <= pos
        if window:
            v_ok &= vpos > pos - window
        v = jnp.where(v_ok, v, 0.0)
        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    live = si * block_s <= pos
    if window:
        live &= (si + 1) * block_s > pos - window
    pl.when(live)(_compute)

    @pl.when(si == s_steps - 1)
    def _flush():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def _paged_kernel(
    seq_ref, pos_ref, btab_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
    acc_ref, *, block_s: int, s_steps: int, window: int
):
    # same compute as the dense-cache kernel: the paging lives entirely in
    # the BlockSpec index map (tile si already holds the positions
    # [si*block_s, (si+1)*block_s) of this token's sequence), and the block
    # table itself is only consumed there — the body never sees it
    _ragged_kernel(
        seq_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
        block_s=block_s, s_steps=s_steps, window=window,
    )


def _paged_kernel_q8(
    seq_ref, pos_ref, btab_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
    m_ref, l_ref, acc_ref, *, block_s: int, s_steps: int, window: int
):
    # paged + quantized: the scale pools route through the SAME block-table
    # index map as their payload pools, so a COW-shared block's scales are
    # definitionally the ones fetched with it
    _ragged_kernel_q8(
        seq_ref, pos_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref,
        l_ref, acc_ref, block_s=block_s, s_steps=s_steps, window=window,
    )


@functools.partial(
    jax.jit, static_argnames=("window", "block_s", "interpret")
)
def ragged_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    tok_slot: jax.Array,
    tok_pos: jax.Array,
    *,
    window: int = 0,
    block_s: int = 256,
    interpret: bool = False,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """q: [T, KV, G, d] packed queries; k/v: [B, S_max, KV, d] batched cache;
    tok_slot/tok_pos: [T] int32 per-token descriptors. With ``k_scale``/
    ``v_scale`` ([B, S_max, KV, 1] f32) the cache may be int8 — tiles
    dequantize in-register inside the kernel.

    Returns [T, KV, G, d] attention outputs for every packed token."""
    t, kvh, g, d = q.shape
    s_max = k.shape[1]
    s_steps = pl.cdiv(s_max, block_s)
    grid = (t, kvh, s_steps)
    quant = k_scale is not None
    # the slot indirection lives in the index map: each token's K/V
    # tiles stream straight from its cache row, no [T, S, KV, d]
    # gather ever exists
    kv_spec = pl.BlockSpec(
        (1, block_s, 1, d),
        lambda ti, hi, si, slots, poss: (slots[ti], si, hi, 0),
    )
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda ti, hi, si, slots, poss: (ti, hi, 0, 0)),
        kv_spec,
    ]
    if quant:
        scale_spec = pl.BlockSpec(
            (1, block_s, 1, 1),
            lambda ti, hi, si, slots, poss: (slots[ti], si, hi, 0),
        )
        in_specs += [scale_spec, kv_spec, scale_spec]
    else:
        in_specs.append(kv_spec)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda ti, hi, si, slots, poss: (ti, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    tok_slot = jnp.asarray(tok_slot, jnp.int32)
    tok_pos = jnp.asarray(tok_pos, jnp.int32)
    kern = _ragged_kernel_q8 if quant else _ragged_kernel
    operands = (
        (tok_slot, tok_pos, q, k, k_scale, v, v_scale)
        if quant
        else (tok_slot, tok_pos, q, k, v)
    )
    return pl.pallas_call(
        functools.partial(
            kern, block_s=block_s, s_steps=s_steps, window=window
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, kvh, g, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_ragged_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    tok_seq: jax.Array,
    tok_pos: jax.Array,
    block_tables: jax.Array,
    *,
    window: int = 0,
    interpret: bool = False,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Packed ragged attention against a block-paged KV pool.

    q: [T, KV, G, d] packed queries; k/v: [num_blocks, block_size, KV, d]
    pool; tok_seq/tok_pos: [T] int32 — token t belongs to sequence row
    ``tok_seq[t]`` of ``block_tables`` at absolute position ``tok_pos[t]``;
    block_tables: [R, max_blocks] int32 mapping (sequence row, S tile) to a
    pool block (out-of-range sentinel = unallocated). The S tile size IS
    the pool's block_size — the pool layout already tiled the cache for
    the kernel, so no extra blocking choice exists on this path. With
    ``k_scale``/``v_scale`` ([num_blocks, block_size, KV, 1] f32 scale
    pools) the payload pools may be int8: the scales ride the SAME
    block-table index map, so a COW-shared block always travels with the
    scales that describe it.

    Returns [T, KV, G, d] attention outputs for every packed token."""
    t, kvh, g, d = q.shape
    nb, block_s = k.shape[0], k.shape[1]
    s_steps = block_tables.shape[1]
    grid = (t, kvh, s_steps)
    quant = k_scale is not None

    def _kv_map(ti, hi, si, seqs, poss, btab):
        # (slot, pos) -> (block, offset): the tile's pool block comes from
        # the sequence's table; clamp the unallocated sentinel in-range
        # (those tiles are masked dead by the position bound anyway)
        return (jnp.minimum(btab[seqs[ti], si], nb - 1), 0, hi, 0)

    kv_spec = pl.BlockSpec((1, block_s, 1, d), _kv_map)
    in_specs = [
        pl.BlockSpec(
            (1, 1, g, d), lambda ti, hi, si, seqs, poss, btab: (ti, hi, 0, 0)
        ),
        kv_spec,
    ]
    if quant:
        scale_spec = pl.BlockSpec((1, block_s, 1, 1), _kv_map)
        in_specs += [scale_spec, kv_spec, scale_spec]
    else:
        in_specs.append(kv_spec)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda ti, hi, si, seqs, poss, btab: (ti, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    tok_seq = jnp.asarray(tok_seq, jnp.int32)
    tok_pos = jnp.asarray(tok_pos, jnp.int32)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    kern = _paged_kernel_q8 if quant else _paged_kernel
    operands = (
        (tok_seq, tok_pos, block_tables, q, k, k_scale, v, v_scale)
        if quant
        else (tok_seq, tok_pos, block_tables, q, k, v)
    )
    return pl.pallas_call(
        functools.partial(
            kern, block_s=block_s, s_steps=s_steps, window=window
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, kvh, g, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
