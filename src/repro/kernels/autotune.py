"""Block-size autotuner for the Pallas dispatch layer (``ops.py``).

The paper's lever is matching the fabric configuration to the kernel
(split/merge around the workload mix); ours is matching tile/block
configuration to (op, shape, dtype, backend) instead of paying one
hardcoded ``block=128`` for every call. The tuner:

* buckets shapes to powers of two so one sweep covers a family of nearby
  shapes (a 1000-wide matmul and a 1024-wide one share a winner),
* sweeps a per-op candidate list, timing the real kernel on synthetic
  inputs, and
* persists winners to a JSON cache so later processes hit without
  re-sweeping.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``. Sweeping is opt-in via
``REPRO_AUTOTUNE=1`` (a sweep in interpret mode on CPU is expensive);
without it, a cache miss returns the per-op heuristic default and nothing
is written. Entries are keyed on a schema version — bump
``_SCHEMA_VERSION`` to invalidate every cached winner at once.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Optional

import numpy as np

_SCHEMA_VERSION = 1

Config = dict[str, int]

# Heuristic defaults: what ops.py hardcoded before the tuner existed.
DEFAULTS: dict[str, Config] = {
    "matmul": {"block_m": 128, "block_n": 128, "block_k": 128},
    "flash_attention": {"block_q": 128, "block_k": 128},
    "gqa_flash_attention": {"block_q": 128, "block_k": 128},
    "decode_attention": {"block_s": 256},
    "ragged_attention": {"block_s": 256},
    "axpy": {"block": 1024},
    "dotp": {"block": 2048},
    "softmax": {"block_rows": 128},
    "rmsnorm": {"block_rows": 128},
    "fft": {"block_rows": 64},
    "conv2d": {"block_h": 8},
}

CANDIDATES: dict[str, list[Config]] = {
    "matmul": [
        {"block_m": m, "block_n": n, "block_k": k}
        for (m, n, k) in [
            (64, 64, 64), (128, 128, 64), (128, 128, 128),
            (128, 256, 128), (256, 128, 128), (256, 256, 128),
        ]
    ],
    "flash_attention": [
        {"block_q": q, "block_k": k}
        for (q, k) in [(64, 64), (128, 128), (128, 256), (256, 128), (256, 256)]
    ],
    "gqa_flash_attention": [
        {"block_q": q, "block_k": k}
        for (q, k) in [(64, 64), (128, 128), (128, 256), (256, 128), (256, 256)]
    ],
    "decode_attention": [{"block_s": s} for s in (128, 256, 512, 1024)],
    "ragged_attention": [{"block_s": s} for s in (128, 256, 512, 1024)],
    "axpy": [{"block": b} for b in (256, 512, 1024, 2048, 4096)],
    "dotp": [{"block": b} for b in (512, 1024, 2048, 4096)],
    "softmax": [{"block_rows": r} for r in (32, 64, 128, 256)],
    "rmsnorm": [{"block_rows": r} for r in (32, 64, 128, 256)],
    "fft": [{"block_rows": r} for r in (16, 32, 64, 128)],
    "conv2d": [{"block_h": h} for h in (4, 8, 16)],
}


def bucket_dim(n: int) -> int:
    """Round a dimension up to the next power of two (floor 8)."""
    n = max(int(n), 1)
    b = 8
    while b < n:
        b *= 2
    return b


def bucket_shape(shape: tuple[int, ...]) -> tuple[int, ...]:
    return tuple(bucket_dim(d) for d in shape)


def cache_key(
    op: str, shape: tuple[int, ...], dtype: Any, backend: str,
    kv_dtype: Any = None,
) -> str:
    """Stable string key over the bucketed shape: nearby shapes collide by
    design so one sweep serves the whole bucket. ``kv_dtype`` (the KV-cache
    storage dtype, when it differs from the compute path — e.g. int8
    quantized serving) appends a ``|kv<name>`` component ONLY when present,
    so every pre-existing key string is unchanged (no schema bump)."""
    dims = "x".join(str(d) for d in bucket_shape(shape))
    key = f"v{_SCHEMA_VERSION}|{op}|{dims}|{np.dtype(dtype).name}|{backend}"
    if kv_dtype is not None:
        key += f"|kv{np.dtype(kv_dtype).name}"
    return key


def sweep_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "0") not in ("", "0", "false")


def default_cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune.json")


class Autotuner:
    """JSON-backed (op, shape-bucket, dtype, backend) -> block-config cache."""

    def __init__(self, path: Optional[str] = None, *, sweep: Optional[bool] = None):
        self.path = path or default_cache_path()
        self.sweep = sweep_enabled() if sweep is None else sweep
        self._entries: Optional[dict[str, Config]] = None
        self.sweeps_run = 0  # observability: how many sweeps this process ran

    # ------------------------------------------------------------ persistence

    def _load(self) -> dict[str, Config]:
        if self._entries is None:
            self._entries = {}
            try:
                with open(self.path) as f:
                    raw = json.load(f)
                if isinstance(raw, dict):
                    self._entries = {
                        k: v for k, v in raw.items() if isinstance(v, dict)
                    }
            except (OSError, ValueError):
                pass  # missing/corrupt cache == cold cache
        return self._entries

    def save(self) -> None:
        if self._entries is None:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self._entries, f, indent=2, sort_keys=True)
        os.replace(tmp, self.path)  # atomic vs concurrent readers

    # ----------------------------------------------------------------- lookup

    def lookup(self, op, shape, dtype, backend, kv_dtype=None) -> Optional[Config]:
        return self._load().get(cache_key(op, shape, dtype, backend, kv_dtype))

    def store(self, op, shape, dtype, backend, config: Config, kv_dtype=None) -> None:
        self._load()[cache_key(op, shape, dtype, backend, kv_dtype)] = dict(config)
        self.save()

    def get(
        self,
        op: str,
        shape: tuple[int, ...],
        dtype: Any,
        backend: str,
        measure: Optional[Callable[[Config], float]] = None,
        kv_dtype: Any = None,
    ) -> Config:
        """Cached winner, or (if sweeping is enabled) sweep-measure-persist,
        or the heuristic default. ``measure`` maps a candidate config to a
        wall-clock cost; ``None`` disables sweeping for this call."""
        hit = self.lookup(op, shape, dtype, backend, kv_dtype)
        if hit is not None:
            return dict(hit)  # copy: callers must not mutate the cache
        if not self.sweep or measure is None:
            return dict(DEFAULTS[op])
        best_cfg, best_t = None, float("inf")
        for cfg in CANDIDATES.get(op, [DEFAULTS[op]]):
            try:
                t = measure(cfg)
            except Exception:
                continue  # candidate invalid for this shape/backend
            if t < best_t:
                best_cfg, best_t = cfg, t
        if best_cfg is None:
            best_cfg = dict(DEFAULTS[op])
        self.sweeps_run += 1
        self.store(op, shape, dtype, backend, best_cfg, kv_dtype)
        return dict(best_cfg)


# ---------------------------------------------------------------------------
# Synthetic-input measure functions (used by ops.py when sweeping is on).
# They import the kernel modules directly — never ops.py — so there is no
# import cycle, and they time the compiled kernel exactly as dispatched.
# ---------------------------------------------------------------------------


def _time_best(thunk: Callable[[], Any], repeats: int = 3) -> float:
    import jax

    jax.block_until_ready(thunk())  # compile + warmup
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        best = min(best, time.perf_counter() - t0)
    return best


def measure_matmul(shape, dtype, backend) -> Callable[[Config], float]:
    import jax.numpy as jnp

    from repro.kernels import matmul as _k

    m, k, n = bucket_shape(shape)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    interp = backend == "interpret"

    def run(cfg: Config) -> float:
        return _time_best(
            lambda: _k.matmul(
                a, b, block_m=cfg["block_m"], block_n=cfg["block_n"],
                block_k=cfg["block_k"], interpret=interp,
            )
        )

    return run


def measure_flash_attention(shape, dtype, backend) -> Callable[[Config], float]:
    import jax.numpy as jnp

    from repro.kernels import flash_attention as _k

    bh, s, d = bucket_shape(shape)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    kv = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    interp = backend == "interpret"

    def run(cfg: Config) -> float:
        return _time_best(
            lambda: _k.flash_attention(
                q, kv, kv, causal=True, block_q=cfg["block_q"],
                block_k=cfg["block_k"], interpret=interp,
            )
        )

    return run


MEASURES: dict[str, Callable[..., Callable[[Config], float]]] = {
    "matmul": measure_matmul,
    "flash_attention": measure_flash_attention,
}


def measure_for(op: str, shape, dtype, backend):
    """Measure-closure factory, or None when the op has no sweep runner."""
    fn = MEASURES.get(op)
    if fn is None:
        return None
    return fn(shape, dtype, backend)


# ---------------------------------------------------------------------------
# Process-global tuner (what ops.py consults). ``generation`` feeds the
# plan memoizer in ops.py so swapping tuners invalidates memoized plans.
# ---------------------------------------------------------------------------

_tuner: Optional[Autotuner] = None
_generation = 0


def get_tuner() -> Autotuner:
    global _tuner
    if _tuner is None:
        _tuner = Autotuner()
    return _tuner


def set_tuner(tuner: Optional[Autotuner]) -> None:
    """Install a tuner (tests point this at a tmp cache); None resets to the
    env-configured default on next use."""
    global _tuner, _generation
    _tuner = tuner
    _generation += 1


def generation() -> int:
    return _generation
