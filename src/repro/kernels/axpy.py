"""AXPY streaming kernel: y <- alpha*x + y (the paper's LinAlg kernel).

Pure HBM-bandwidth workload: 1-D grid of VMEM-sized blocks, VPU elementwise
math, alpha passed as a scalar-prefetch-style (1,1) block in SMEM-like
fashion (a tiny replicated block)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    a = alpha_ref[0, 0].astype(jnp.float32)
    o_ref[...] = (
        a * x_ref[...].astype(jnp.float32) + y_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def axpy(
    alpha: jax.Array,
    x: jax.Array,
    y: jax.Array,
    *,
    block: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """x, y: [R, C]; arbitrary C (tail blocks are write-masked)."""
    r, c = x.shape
    alpha = jnp.asarray(alpha, x.dtype).reshape(1, 1)
    grid = (r, pl.cdiv(c, block))
    return pl.pallas_call(
        _axpy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(alpha, x, y)
