"""Pallas TPU kernels for the paper's six kernel families + LM hot-spots.

Layout per the deliverable spec: ``<name>.py`` holds the ``pl.pallas_call``
kernel with explicit BlockSpec VMEM tiling, ``ops.py`` the jit'd dispatch
wrappers (TPU → Pallas, CPU → oracle, interpret for validation), ``ref.py``
the pure-jnp oracles.
"""
