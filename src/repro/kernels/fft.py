"""Batched Stockham radix-2 FFT kernel (the paper's sync-critical DSP kernel).

TPU adaptation: complex data is PLANAR (separate re/im f32
arrays — VPU lanes hate interleaved complex), a whole power-of-two row lives
in VMEM per block, and all log2(N) butterfly stages run register/VMEM-
resident inside one kernel invocation — zero HBM round-trips between stages.
The twiddle table ([stages, N/2], precomputed on host) streams in once.
Stockham's autosorting recursion avoids the bit-reversal gather that would
scatter VMEM accesses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import fft_twiddles


def _fft_kernel(re_ref, im_ref, twr_ref, twi_ref, ore_ref, oim_ref, *, n: int):
    b = re_ref.shape[0]
    xr = re_ref[...].astype(jnp.float32)
    xi = im_ref[...].astype(jnp.float32)
    stages = int(np.log2(n))
    for s in range(stages):
        l = 2**s
        g = n // (2 * l)  # butterfly groups
        # Stockham split: even = first half, odd = second half, viewed [g, l]
        er = xr[:, : n // 2].reshape(b, g, l)
        ei = xi[:, : n // 2].reshape(b, g, l)
        orr = xr[:, n // 2 :].reshape(b, g, l)
        oi = xi[:, n // 2 :].reshape(b, g, l)
        twr = twr_ref[s, :].reshape(g, l)
        twi = twi_ref[s, :].reshape(g, l)
        tr = orr * twr - oi * twi
        ti = orr * twi + oi * twr
        xr = jnp.concatenate([er + tr, er - tr], axis=-1).reshape(b, n)
        xi = jnp.concatenate([ei + ti, ei - ti], axis=-1).reshape(b, n)
    ore_ref[...] = xr.astype(ore_ref.dtype)
    oim_ref[...] = xi.astype(oim_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fft(
    re: jax.Array,
    im: jax.Array,
    *,
    block_rows: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Batched FFT over the last dim. re/im: [B, N], N a power of two.
    Arbitrary B (independent rows, masked tail)."""
    b, n = re.shape
    twr, twi = fft_twiddles(n)
    stages = twr.shape[0]
    out_shape = (
        jax.ShapeDtypeStruct((b, n), re.dtype),
        jax.ShapeDtypeStruct((b, n), im.dtype),
    )
    return pl.pallas_call(
        functools.partial(_fft_kernel, n=n),
        grid=(pl.cdiv(b, block_rows),),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((stages, n // 2), lambda i: (0, 0)),
            pl.BlockSpec((stages, n // 2), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(re, im, jnp.asarray(twr), jnp.asarray(twi))
