"""2-D convolution kernel (the paper's fconv2d), implicit-GEMM style.

TPU adaptation: instead of the RVV sliding-window vector loop, each output
row-tile is computed as ΣKH·KW small GEMMs — shifted input slices (VMEM)
against the [C, O] weight plane for that tap, accumulated in f32. This keeps
the MXU fed with [rows·W_out, C] @ [C, O] matmuls rather than VPU-only math.

Grid: (batch, row-tiles), ceil-divided — no host-side padding. Pallas block
index maps are in block units, so an overlapping (block_h + KH - 1)-tall
halo block is not directly expressible; the whole image AND the whole
output plane are staged per batch element (benchmark-scale images fit VMEM)
and both the halo'd input window and the output rows are sliced inside the
kernel. A ragged tail tile is anchored at the image edge instead of masked:
its halo slice starts at ``h_out - block_h`` (always in bounds), recomputing
a few rows the previous tile already produced — the overlapping rows get
identical values, so the rewrite is idempotent and no shifted-row hazard
exists. Larger images would use an explicit double-buffered DMA halo
pipeline. Stride 1, VALID.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, block_h: int):
    # x_ref: [1, H, W, C] (whole image); o_ref: [1, H_out, W_out, O] (whole
    # output plane — rows are written via a dynamic slice so the tail tile
    # can anchor at the edge)
    ri = pl.program_id(1)
    w_in = x_ref.shape[2]
    c = x_ref.shape[3]
    o = w_ref.shape[3]
    h_out = o_ref.shape[1]
    w_out = w_in - kw + 1
    # tail tile: anchor at the last valid start (overlap-recompute, not mask)
    start = jnp.minimum(ri * block_h, h_out - block_h)
    x_tile = jax.lax.dynamic_slice(
        x_ref[0], (start, 0, 0), (block_h + kh - 1, w_in, c)
    )
    acc = jnp.zeros((block_h, w_out, o), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = x_tile[i : i + block_h, j : j + w_out, :].astype(jnp.float32)
            tap = w_ref[i, j].astype(jnp.float32)  # [C, O]
            acc += jnp.dot(
                patch.reshape(block_h * w_out, c),
                tap,
                preferred_element_type=jnp.float32,
            ).reshape(block_h, w_out, o)
    o_ref[0, pl.ds(start, block_h)] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    block_h: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """x: [B, H, W, C]; w: [KH, KW, C, O]; VALID, stride 1.

    Arbitrary H: the grid ceil-divides and the tail tile overlaps the
    previous one (``block_h`` must not exceed H - KH + 1; ``ops.conv2d``
    clamps it)."""
    b, h, wd, c = x.shape
    kh, kw, c2, o = w.shape
    assert c == c2
    h_out, w_out = h - kh + 1, wd - kw + 1
    assert block_h <= h_out, (h_out, block_h)
    grid = (b, pl.cdiv(h_out, block_h))
    return pl.pallas_call(
        functools.partial(_conv2d_kernel, kh=kh, kw=kw, block_h=block_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, wd, c), lambda bi, ri: (bi, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c, o), lambda bi, ri: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, h_out, w_out, o), lambda bi, ri: (bi, 0, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, o), x.dtype),
        interpret=interpret,
    )(x, w)
