"""Dispatch layer for the kernels: Pallas on TPU, interpret-mode Pallas for
validation, jnp oracle fallback for fast CPU execution.

Hot-path contract: no ``jnp.pad`` device copies. Kernels ceil-divide their
grids and mask tail blocks in-kernel (iota compares against the true sizes),
so arbitrary shapes dispatch straight through. Block sizes come from the
autotuner (``repro.kernels.autotune``) unless the caller pins them; the
resolved (mode, blocks) plan is memoized per static shape so repeat calls
skip both the tuner consult and the block arithmetic. ``mode`` resolution:

* ``auto``      — compiled Pallas on TPU, oracle elsewhere (production)
* ``pallas``    — compiled Pallas (TPU only)
* ``interpret`` — Pallas kernel body interpreted on CPU (correctness runs)
* ``ref``       — the jnp oracle
"""

from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.kernels import (
    autotune,
    axpy as _axpy_k,
    conv2d as _conv2d_k,
    decode_attention as _decode_k,
    dotp as _dotp_k,
    fft as _fft_k,
    flash_attention as _flash_k,
    matmul as _matmul_k,
    ragged_attention as _ragged_k,
    rmsnorm as _rmsnorm_k,
    softmax as _softmax_k,
)
from repro.kernels import ref

Mode = Literal["auto", "pallas", "interpret", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: Mode) -> str:
    if mode == "auto":
        return "pallas" if _on_tpu() else "ref"
    return mode


@functools.lru_cache(maxsize=4096)
def _plan(
    op: str, shape: tuple[int, ...], dtype_name: str, backend: str, gen: int,
    kv_dtype_name: Optional[str] = None,
) -> dict[str, int]:
    """Memoized block plan for one static (op, shape, dtype, backend) cell.

    ``gen`` is the tuner generation — swapping tuners (tests) invalidates
    every memoized plan without touching this cache directly.
    ``kv_dtype_name`` keys quantized-cache attention separately (an int8
    cache moves half/quarter the HBM bytes per tile, so its block-size
    winner need not match the f32 cache's).
    """
    tuner = autotune.get_tuner()
    hit = tuner.lookup(op, shape, dtype_name, backend, kv_dtype_name)
    if hit is not None:
        return dict(hit)
    # only build the measure closure (it allocates bucketed synthetic
    # inputs) once we know the lookup missed and a sweep will actually run
    measure = (
        autotune.measure_for(op, shape, dtype_name, backend)
        if tuner.sweep
        else None
    )
    return tuner.get(
        op, shape, dtype_name, backend, measure=measure, kv_dtype=kv_dtype_name
    )


def _blocks(
    op: str, shape: tuple[int, ...], dtype, backend: str, kv_dtype=None
) -> dict[str, int]:
    return _plan(
        op, shape, jnp.dtype(dtype).name, backend, autotune.generation(),
        None if kv_dtype is None else jnp.dtype(kv_dtype).name,
    )


# ---------------------------------------------------------------------------


def matmul(
    a,
    b,
    *,
    mode: Mode = "auto",
    block: Optional[int] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
):
    m = _resolve(mode)
    if m == "ref":
        return ref.matmul(a, b)
    m0, k0 = a.shape
    n0 = b.shape[1]
    if block is not None:
        block_m = block_n = block_k = block
    if block_m is None or block_n is None or block_k is None:
        cfg = _blocks("matmul", (m0, k0, n0), a.dtype, m)  # fill the gaps
        block_m = cfg["block_m"] if block_m is None else block_m
        block_n = cfg["block_n"] if block_n is None else block_n
        block_k = cfg["block_k"] if block_k is None else block_k
    return _matmul_k.matmul(
        a, b,
        block_m=min(block_m, max(m0, 1)),
        block_n=min(block_n, max(n0, 1)),
        block_k=min(block_k, max(k0, 1)),
        interpret=(m == "interpret"),
    )


def matmul_q8(
    a,
    b_q8,
    b_scale,
    *,
    mode: Mode = "auto",
    block: Optional[int] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
):
    """[M,K] @ int8 [K,N] + per-output-channel scales [N] — the quantized
    weight-serving matmul. Block plans key the tuner cache with the int8
    RHS dtype (``|kvint8`` suffix) so f32 winners aren't reused blindly."""
    m = _resolve(mode)
    if m == "ref":
        return ref.matmul_q8(a, b_q8, b_scale)
    m0, k0 = a.shape
    n0 = b_q8.shape[1]
    if block is not None:
        block_m = block_n = block_k = block
    if block_m is None or block_n is None or block_k is None:
        cfg = _blocks("matmul", (m0, k0, n0), a.dtype, m, kv_dtype=b_q8.dtype)
        block_m = cfg["block_m"] if block_m is None else block_m
        block_n = cfg["block_n"] if block_n is None else block_n
        block_k = cfg["block_k"] if block_k is None else block_k
    return _matmul_k.matmul_q8(
        a, b_q8, b_scale,
        block_m=min(block_m, max(m0, 1)),
        block_n=min(block_n, max(n0, 1)),
        block_k=min(block_k, max(k0, 1)),
        interpret=(m == "interpret"),
    )


def axpy(alpha, x, y, *, mode: Mode = "auto", block: Optional[int] = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.axpy(alpha, x, y)
    orig_shape = x.shape
    x2 = x.reshape(1, -1) if x.ndim == 1 else x
    y2 = y.reshape(1, -1) if y.ndim == 1 else y
    if block is None:
        block = _blocks("axpy", x2.shape, x.dtype, m)["block"]
    blk = min(block, x2.shape[-1])
    out = _axpy_k.axpy(alpha, x2, y2, block=blk, interpret=(m == "interpret"))
    return out.reshape(orig_shape)


def dotp(x, y, *, mode: Mode = "auto", block: Optional[int] = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.dotp(x, y)
    x2 = x.reshape(1, -1)
    y2 = y.reshape(1, -1)
    if block is None:
        block = _blocks("dotp", x2.shape, x.dtype, m)["block"]
    blk = min(block, x2.shape[-1])
    return _dotp_k.dotp(x2, y2, block=blk, interpret=(m == "interpret"))


def softmax(x, *, mode: Mode = "auto", block_rows: Optional[int] = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.softmax(x)
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    if block_rows is None:
        block_rows = _blocks("softmax", x2.shape, x.dtype, m)["block_rows"]
    br = min(block_rows, x2.shape[0])
    out = _softmax_k.softmax(x2, block_rows=br, interpret=(m == "interpret"))
    return out.reshape(orig)


def rmsnorm(
    x, w, *, eps: float = 1e-6, mode: Mode = "auto",
    block_rows: Optional[int] = None,
):
    m = _resolve(mode)
    if m == "ref":
        return ref.rmsnorm(x, w, eps)
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    if block_rows is None:
        block_rows = _blocks("rmsnorm", x2.shape, x.dtype, m)["block_rows"]
    br = min(block_rows, x2.shape[0])
    out = _rmsnorm_k.rmsnorm(x2, w, eps=eps, block_rows=br, interpret=(m == "interpret"))
    return out.reshape(orig)


def fft(re, im, *, mode: Mode = "auto", block_rows: Optional[int] = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.fft(re, im)
    if block_rows is None:
        block_rows = _blocks("fft", re.shape, re.dtype, m)["block_rows"]
    br = min(block_rows, re.shape[0])
    return _fft_k.fft(re, im, block_rows=br, interpret=(m == "interpret"))


def conv2d(x, w, *, mode: Mode = "auto", block_h: Optional[int] = None):
    m = _resolve(mode)
    if m == "ref":
        return ref.conv2d(x, w)
    kh = w.shape[0]
    h_out = x.shape[1] - kh + 1
    if block_h is None:
        block_h = _blocks("conv2d", x.shape, x.dtype, m)["block_h"]
    bh = min(block_h, h_out)
    # pad-free: the grid ceil-divides and the kernel anchors the tail tile's
    # halo slice at the image edge (shifted-tile recompute), so ragged H
    # dispatches straight through like every other kernel
    return _conv2d_k.conv2d(x, w, block_h=bh, interpret=(m == "interpret"))


def flash_attention(
    q, k, v, *, causal: bool = True, mode: Mode = "auto",
    block: Optional[int] = None,
    block_q: Optional[int] = None, block_k: Optional[int] = None,
):
    """q/k/v: [B, H, S, d] or [BH, S, d]. Arbitrary S — the kernel's
    key-validity mask covers the K overhang (causal and non-causal alike)."""
    m = _resolve(mode)
    squeeze = False
    if q.ndim == 3:
        q, k, v = q[None], k[None], v[None]
        squeeze = True
    b, h, s, d = q.shape
    if m == "ref":
        out = ref.flash_attention(q, k, v, causal=causal)
        return out[0] if squeeze else out
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, k.shape[2], d)
    vf = v.reshape(b * h, v.shape[2], d)
    if block is not None:
        block_q = block_k = block
    if block_q is None or block_k is None:
        cfg = _blocks("flash_attention", qf.shape, q.dtype, m)  # fill the gaps
        block_q = cfg["block_q"] if block_q is None else block_q
        block_k = cfg["block_k"] if block_k is None else block_k
    out = _flash_k.flash_attention(
        qf, kf, vf, causal=causal,
        block_q=min(block_q, s), block_k=min(block_k, kf.shape[1]),
        interpret=(m == "interpret"),
    )
    out = out.reshape(b, h, s, d)
    return out[0] if squeeze else out


def gqa_flash_attention(
    q, k, v, *, causal: bool = True, mode: Mode = "auto",
    block_q: Optional[int] = None, block_k: Optional[int] = None,
):
    """GQA-native attention: q [B, H, S, d], k/v [B, KV, S, d], H % KV == 0.

    K/V are never expanded to H heads — the kernel broadcasts each KV tile
    across the query-head group via the grid, the oracle via einsum."""
    b, h, sq, d = q.shape
    kvh = k.shape[1]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, d).reshape(b * kvh, g, sq, d)
    kf = k.reshape(b * kvh, k.shape[2], d)
    vf = v.reshape(b * kvh, v.shape[2], d)
    m = _resolve(mode)
    if m == "ref":
        out = ref.gqa_flash_attention(qg, kf, vf, causal=causal)
    else:
        if block_q is None or block_k is None:
            cfg = _blocks("gqa_flash_attention", qg.shape, q.dtype, m)
            block_q = cfg["block_q"] if block_q is None else block_q
            block_k = cfg["block_k"] if block_k is None else block_k
        out = _flash_k.gqa_flash_attention(
            qg, kf, vf, causal=causal,
            block_q=min(block_q, sq), block_k=min(block_k, kf.shape[1]),
            interpret=(m == "interpret"),
        )
    return out.reshape(b, h, sq, d)


def decode_attention(
    q, k, v, cur_len, *, window: int = 0, mode: Mode = "auto",
    block_s: Optional[int] = None, k_scale=None, v_scale=None,
):
    """Batched single-token decode attention against the KV cache.

    q: [B, H, d] (the new token's query heads); k/v: [B, S_max, KV, d]
    (decode-cache layout, possibly lower-precision storage); cur_len: []
    or [B] tokens already cached per slot. ``k_scale``/``v_scale``
    ([B, S_max, KV] f32, the cache-resident scale leaves) mark K/V as
    int8 rows — dequant happens inside the kernel / oracle, never as an
    f32 cache copy. Returns [B, H, d]."""
    b, h, d = q.shape
    s_max, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    m = _resolve(mode)
    if m == "ref":
        out = ref.decode_attention(
            qg, k, v, cur_len, window=window,
            k_scale=k_scale, v_scale=v_scale,
        )
    else:
        if block_s is None:
            block_s = _blocks(
                "decode_attention", k.shape, q.dtype, m,
                kv_dtype=None if k_scale is None else k.dtype,
            )["block_s"]
        # no pre-cast of the cache: the kernel upcasts per-tile (int8/bf16
        # storage reads stay at storage width in HBM); scales gain a
        # trailing singleton so they ride the payloads' BlockSpec maps
        out = _decode_k.decode_attention(
            qg, k, v, cur_len,
            window=window, block_s=min(block_s, s_max),
            interpret=(m == "interpret"),
            k_scale=None if k_scale is None else k_scale[..., None],
            v_scale=None if v_scale is None else v_scale[..., None],
        )
    return out.reshape(b, h, d)


def ragged_attention(
    q, k, v, tok_slot, tok_pos, *, window: int = 0, mode: Mode = "auto",
    block_s: Optional[int] = None, valid=None, k_scale=None, v_scale=None,
):
    """Packed variable-length attention: a flat token batch (decode
    singletons + prefill chunks from any mix of sequences) against the
    batched cache. The unified serving dispatch routes every tick through
    this one op instead of choosing between prefill and decode programs.

    q: [T, H, d] packed query tokens; k/v: [B, S_max, KV, d] (decode-cache
    layout, possibly lower-precision storage) with the packed tokens' K/V
    already scattered at (tok_slot, tok_pos); tok_slot/tok_pos: [T] int32.
    ``valid`` optionally passes a precomputed ``ref.ragged_valid_mask``
    (descriptor-only, so one mask serves every layer of a packed step); the
    Pallas kernel derives its masks in-kernel and ignores it.
    Returns [T, H, d]."""
    t, h, d = q.shape
    s_max, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    qg = q.reshape(t, kvh, g, d)
    m = _resolve(mode)
    if m == "ref":
        out = ref.ragged_attention(
            qg, k, v, tok_slot, tok_pos, window=window, valid=valid,
            k_scale=k_scale, v_scale=v_scale,
        )
    else:
        if block_s is None:
            block_s = _blocks(
                "ragged_attention", k.shape, q.dtype, m,
                kv_dtype=None if k_scale is None else k.dtype,
            )["block_s"]
        # no pre-cast of the cache: the kernel upcasts per-tile (int8/bf16
        # storage reads stay at storage width in HBM)
        out = _ragged_k.ragged_attention(
            qg, k, v, tok_slot, tok_pos,
            window=window, block_s=min(block_s, s_max),
            interpret=(m == "interpret"),
            k_scale=None if k_scale is None else k_scale[..., None],
            v_scale=None if v_scale is None else v_scale[..., None],
        )
    return out.reshape(t, h, d)


def mla_ragged_attention(
    q_eff, q_rope, ckv, krope, tok_slot, tok_pos, *, scale: float,
    mode: Mode = "auto", block_s: Optional[int] = None, valid=None,
):
    """Packed ragged attention over the MLA compressed latent cache.

    q_eff: [T, H, r] absorbed queries; q_rope: [T, H, rope]; ckv:
    [B, S_max, r] latent cache (keys AND values); krope: [B, S_max, rope];
    ``scale`` the absorbed softmax scale ((nope+rope)**-0.5). Returns
    [T, H, r] latent outputs.

    The non-ref modes reuse the existing ragged kernel as a latent-space
    MQA: keys = concat(ckv, krope) under ONE shared KV head, values = ckv
    zero-padded to key width, and the query pre-scaled by
    ``scale * (r+rope)**0.5`` to cancel the kernel's internal
    ``d**-0.5`` — the padded value lanes read back as zeros and are
    sliced off. No MLA-specific kernel needs to exist for the packed
    path to ride the tuned dispatch."""
    t, h, r = q_eff.shape
    m = _resolve(mode)
    if m == "ref":
        return ref.mla_ragged_attention(
            q_eff, q_rope, ckv, krope, tok_slot, tok_pos,
            scale=scale, valid=valid,
        )
    rope = q_rope.shape[-1]
    d_tot = r + rope
    gain = scale * d_tot**0.5  # kernel divides by sqrt(d_tot); we undo it
    q_cat = jnp.concatenate([q_eff * gain, q_rope * gain], axis=-1)
    qg = q_cat.reshape(t, 1, h, d_tot)  # ONE shared latent KV head
    k_cat = jnp.concatenate([ckv, krope], axis=-1)[:, :, None, :]
    v_pad = jnp.concatenate([ckv, jnp.zeros_like(krope)], axis=-1)[:, :, None, :]
    s_max = k_cat.shape[1]
    if block_s is None:
        block_s = _blocks("ragged_attention", k_cat.shape, q_eff.dtype, m)[
            "block_s"
        ]
    out = _ragged_k.ragged_attention(
        qg, k_cat, v_pad, tok_slot, tok_pos,
        window=0, block_s=min(block_s, s_max),
        interpret=(m == "interpret"),
    )  # [T, 1, H, d_tot]
    return out.reshape(t, h, d_tot)[..., :r]


def paged_ragged_attention(
    q, k, v, tok_seq, tok_pos, block_tables, *, window: int = 0,
    mode: Mode = "auto", valid=None, k_scale=None, v_scale=None,
):
    """Packed variable-length attention against a block-paged KV pool: the
    ``(slot, pos)`` descriptor indirection of :func:`ragged_attention`
    generalized to ``(block, offset)`` through per-sequence block tables.

    q: [T, H, d] packed query tokens; k/v: [num_blocks, block_size, KV, d]
    pool with the packed tokens' K/V already scattered at their (block,
    offset); tok_seq/tok_pos: [T] int32 — token t belongs to block-table
    row ``tok_seq[t]`` at absolute position ``tok_pos[t]``; block_tables:
    [R, max_blocks] int32. The oracle/CPU path gathers the tables' dense
    view and reuses the dense oracle (bit-identical to unpaged serving);
    the Pallas kernel streams pool blocks straight through its index map
    — no gathered view ever exists on TPU. Returns [T, H, d]."""
    t, h, d = q.shape
    kvh = k.shape[2]
    assert h % kvh == 0, (h, kvh)
    qg = q.reshape(t, kvh, h // kvh, d)
    m = _resolve(mode)
    if m == "ref":
        out = ref.paged_ragged_attention(
            qg, k, v, tok_seq, tok_pos, block_tables,
            window=window, valid=valid,
            k_scale=k_scale, v_scale=v_scale,
        )
    else:
        out = _ragged_k.paged_ragged_attention(
            qg, k, v, tok_seq, tok_pos, block_tables,
            window=window, interpret=(m == "interpret"),
            k_scale=None if k_scale is None else k_scale[..., None],
            v_scale=None if v_scale is None else v_scale[..., None],
        )
    return out.reshape(t, h, d)


def paged_decode_attention(
    q, k, v, cur_len, block_tables, *, window: int = 0, mode: Mode = "auto",
    k_scale=None, v_scale=None,
):
    """Batched single-token decode attention against a block-paged pool.

    q: [B, H, d]; k/v: [num_blocks, block_size, KV, d]; cur_len: [] or [B];
    block_tables: [B, max_blocks] int32 (row b maps sequence b's S tiles
    to pool blocks). CPU gathers the dense per-sequence view and runs the
    dense decode oracle — bit-identical to the unpaged path; TPU routes
    through the paged ragged kernel with one descriptor per sequence (the
    same ONE kernel carries prefill packs and decode chunks)."""
    b, h, d = q.shape
    kvh = k.shape[2]
    assert h % kvh == 0, (h, kvh)
    m = _resolve(mode)
    if m == "ref":
        qg = q.reshape(b, kvh, h // kvh, d)
        out = ref.paged_decode_attention(
            qg, k, v, cur_len, block_tables, window=window,
            k_scale=k_scale, v_scale=v_scale,
        )
        return out.reshape(b, h, d)
    cur = jnp.broadcast_to(jnp.asarray(cur_len), (b,))
    return paged_ragged_attention(
        q, k, v, jnp.arange(b, dtype=jnp.int32), cur, block_tables,
        window=window, mode=mode, k_scale=k_scale, v_scale=v_scale,
    )
