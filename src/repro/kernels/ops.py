"""Dispatch layer for the kernels: Pallas on TPU, interpret-mode Pallas for
validation, jnp oracle fallback for fast CPU execution.

Every op pads arbitrary shapes to the kernel's block grid and unpads the
result, so callers never see the tiling constraints. ``mode`` resolution:

* ``auto``      — compiled Pallas on TPU, oracle elsewhere (production)
* ``pallas``    — compiled Pallas (TPU only)
* ``interpret`` — Pallas kernel body interpreted on CPU (correctness runs)
* ``ref``       — the jnp oracle
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import (
    axpy as _axpy_k,
    conv2d as _conv2d_k,
    dotp as _dotp_k,
    fft as _fft_k,
    flash_attention as _flash_k,
    matmul as _matmul_k,
    rmsnorm as _rmsnorm_k,
    softmax as _softmax_k,
)
from repro.kernels import ref

Mode = Literal["auto", "pallas", "interpret", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: Mode) -> str:
    if mode == "auto":
        return "pallas" if _on_tpu() else "ref"
    return mode


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


# ---------------------------------------------------------------------------


def matmul(a, b, *, mode: Mode = "auto", block: int = 128):
    m = _resolve(mode)
    if m == "ref":
        return ref.matmul(a, b)
    a_p, m0 = _pad_to(a, 0, block)
    a_p, k0 = _pad_to(a_p, 1, block)
    b_p, _ = _pad_to(b, 0, block)
    b_p, n0 = _pad_to(b_p, 1, block)
    out = _matmul_k.matmul(
        a_p, b_p, block_m=block, block_n=block, block_k=block,
        interpret=(m == "interpret"),
    )
    return out[:m0, :n0]


def axpy(alpha, x, y, *, mode: Mode = "auto", block: int = 1024):
    m = _resolve(mode)
    if m == "ref":
        return ref.axpy(alpha, x, y)
    orig_shape = x.shape
    x2 = x.reshape(1, -1) if x.ndim == 1 else x
    y2 = y.reshape(1, -1) if y.ndim == 1 else y
    blk = min(block, x2.shape[-1]) if x2.shape[-1] % block else block
    if x2.shape[-1] % blk:
        blk = x2.shape[-1]  # tiny inputs: one block
    x_p, c0 = _pad_to(x2, 1, blk)
    y_p, _ = _pad_to(y2, 1, blk)
    out = _axpy_k.axpy(alpha, x_p, y_p, block=blk, interpret=(m == "interpret"))
    return out[:, :c0].reshape(orig_shape)


def dotp(x, y, *, mode: Mode = "auto", block: int = 2048):
    m = _resolve(mode)
    if m == "ref":
        return ref.dotp(x, y)
    x2 = x.reshape(1, -1)
    y2 = y.reshape(1, -1)
    blk = min(block, x2.shape[-1])
    x_p, _ = _pad_to(x2, 1, blk)
    y_p, _ = _pad_to(y2, 1, blk)  # zero padding contributes 0 to the sum
    return _dotp_k.dotp(x_p, y_p, block=blk, interpret=(m == "interpret"))


def softmax(x, *, mode: Mode = "auto", block_rows: int = 128):
    m = _resolve(mode)
    if m == "ref":
        return ref.softmax(x)
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    br = min(block_rows, x2.shape[0])
    x_p, r0 = _pad_to(x2, 0, br)
    out = _softmax_k.softmax(x_p, block_rows=br, interpret=(m == "interpret"))
    return out[:r0].reshape(orig)


def rmsnorm(x, w, *, eps: float = 1e-6, mode: Mode = "auto", block_rows: int = 128):
    m = _resolve(mode)
    if m == "ref":
        return ref.rmsnorm(x, w, eps)
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    br = min(block_rows, x2.shape[0])
    x_p, r0 = _pad_to(x2, 0, br)
    out = _rmsnorm_k.rmsnorm(x_p, w, eps=eps, block_rows=br, interpret=(m == "interpret"))
    return out[:r0].reshape(orig)


def fft(re, im, *, mode: Mode = "auto", block_rows: int = 64):
    m = _resolve(mode)
    if m == "ref":
        return ref.fft(re, im)
    br = min(block_rows, re.shape[0])
    re_p, b0 = _pad_to(re, 0, br)
    im_p, _ = _pad_to(im, 0, br)
    o_re, o_im = _fft_k.fft(re_p, im_p, block_rows=br, interpret=(m == "interpret"))
    return o_re[:b0], o_im[:b0]


def conv2d(x, w, *, mode: Mode = "auto", block_h: int = 8):
    m = _resolve(mode)
    if m == "ref":
        return ref.conv2d(x, w)
    kh = w.shape[0]
    h_out = x.shape[1] - kh + 1
    bh = min(block_h, h_out)
    pad = (-h_out) % bh
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = _conv2d_k.conv2d(x, w, block_h=bh, interpret=(m == "interpret"))
    return out[:, :h_out]


def flash_attention(
    q, k, v, *, causal: bool = True, mode: Mode = "auto", block: int = 128
):
    """q/k/v: [B, H, S, d] or [BH, S, d]."""
    m = _resolve(mode)
    squeeze = False
    if q.ndim == 3:
        q, k, v = q[None], k[None], v[None]
        squeeze = True
    b, h, s, d = q.shape
    if m == "ref":
        out = ref.flash_attention(q, k, v, causal=causal)
        return out[0] if squeeze else out
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, k.shape[2], d)
    vf = v.reshape(b * h, v.shape[2], d)
    bq = min(block, s)
    bk = min(block, kf.shape[1])
    # pad S to block multiples; padded q rows are discarded, padded k cols are
    # masked by causality only when causal — for non-causal we must mask, so
    # fall back to oracle when padding is needed on K and not causal.
    if (s % bq or kf.shape[1] % bk) and not causal:
        out = ref.flash_attention(q, k, v, causal=causal)
        return out[0] if squeeze else out
    qf, s0 = _pad_to(qf, 1, bq)
    kf, _ = _pad_to(kf, 1, bk)
    vf, _ = _pad_to(vf, 1, bk)
    out = _flash_k.flash_attention(
        qf, kf, vf, causal=causal, block_q=bq, block_k=bk,
        interpret=(m == "interpret"),
    )
    out = out[:, :s0].reshape(b, h, s0, d)
    return out[0] if squeeze else out
