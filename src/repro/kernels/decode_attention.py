"""Batched GQA decode-attention kernel: one query token per sequence against
the full [B, S_max, KV, hd] cache, per-slot valid lengths.

This is the serving-engine hot path: every engine tick runs one of these per
layer over all batch slots. The grid is (batch, KV head, S tiles); the
query-head group rides inside the block (a [G, hd] tile — G = H//KV query
heads share one KV head), so the cache is never expanded ``G``-fold. The
per-slot length arrives as a scalar-prefetch-style SMEM operand and gates
whole tiles: tiles entirely past ``cur_len`` are skipped (``pl.when``), so
short slots in a long cache cost proportionally less.

Semantics match ``ref.decode_attention``: key position ``t`` is valid iff
``t <= cur_len`` (the new token was just scattered at index ``cur_len``),
windowed by ``t > cur_len - window`` when ``window > 0``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import _online_softmax_update

NEG_INF = -1e30


def _decode_kernel(
    lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
    block_s: int, s_steps: int, window: int
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # this slot's cached-token count; the new token sits at index cur
    cur = lens_ref[pl.program_id(0)]

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bs, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # [bs, d]
        d = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (d**-0.5)
        kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos <= cur
        if window:
            valid &= kpos > cur - window
        s = jnp.where(valid, s, NEG_INF)
        # zero rows of v that can't contribute (overhang reads are undefined)
        vpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v_ok = vpos <= cur
        if window:
            v_ok &= vpos > cur - window
        v = jnp.where(v_ok, v, 0.0)
        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    # skip tiles entirely past the valid prefix (and before the window)
    live = si * block_s <= cur
    if window:
        live &= (si + 1) * block_s > cur - window
    pl.when(live)(_compute)

    @pl.when(si == s_steps - 1)
    def _flush():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def _decode_kernel_q8(
    lens_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref,
    acc_ref, *, block_s: int, s_steps: int, window: int
):
    """The int8-cache variant: K/V tiles arrive int8 alongside their
    per-(position, head) f32 scale rows; both widen in-register AFTER the
    VMEM load, so no dequantized f32 cache copy ever exists in HBM — the
    whole point of quantized serving on a memory-bound decode."""
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = lens_ref[pl.program_id(0)]

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        # in-register dequant: int8 tile * its per-row scale column
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[0, :, 0, :]  # [bs, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[0, :, 0, :]  # [bs, d]
        d = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (d**-0.5)
        kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos <= cur
        if window:
            valid &= kpos > cur - window
        s = jnp.where(valid, s, NEG_INF)
        vpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v_ok = vpos <= cur
        if window:
            v_ok &= vpos > cur - window
        v = jnp.where(v_ok, v, 0.0)
        _online_softmax_update(s, v, m_ref, l_ref, acc_ref)

    live = si * block_s <= cur
    if window:
        live &= (si + 1) * block_s > cur - window
    pl.when(live)(_compute)

    @pl.when(si == s_steps - 1)
    def _flush():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_s", "interpret")
)
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cur_len: jax.Array,
    *,
    window: int = 0,
    block_s: int = 256,
    interpret: bool = False,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """q: [B, KV, G, d]; k/v: [B, S_max, KV, d]; cur_len: [B] int32.

    With ``k_scale``/``v_scale`` ([B, S_max, KV, 1] f32 — trailing
    singleton so the scale rides the same 4-D BlockSpec index map as its
    payload) K/V may be int8: tiles dequantize in-register inside the
    kernel. Returns [B, KV, G, d] attention outputs for the new token."""
    b, kvh, g, d = q.shape
    s_max = k.shape[1]
    s_steps = pl.cdiv(s_max, block_s)
    grid = (b, kvh, s_steps)
    quant = k_scale is not None
    kv_spec = pl.BlockSpec(
        (1, block_s, 1, d), lambda bi, hi, si, lens: (bi, si, hi, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda bi, hi, si, lens: (bi, hi, 0, 0)),
        kv_spec,
    ]
    if quant:
        scale_spec = pl.BlockSpec(
            (1, block_s, 1, 1), lambda bi, hi, si, lens: (bi, si, hi, 0)
        )
        in_specs += [scale_spec, kv_spec, scale_spec]
    else:
        in_specs.append(kv_spec)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda bi, hi, si, lens: (bi, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    cur_len = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    kern = _decode_kernel_q8 if quant else _decode_kernel
    operands = (
        (cur_len, q, k, k_scale, v, v_scale) if quant else (cur_len, q, k, v)
    )
    return pl.pallas_call(
        functools.partial(
            kern, block_s=block_s, s_steps=s_steps, window=window
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
