"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are also the CPU fallback implementations used by ``ops.py`` when the
backend is not TPU, so the whole framework runs (slowly) anywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """[M,K] @ [K,N] with f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def matmul_q8(a: jax.Array, b_q8: jax.Array, b_scale: jax.Array) -> jax.Array:
    """[M,K] @ int8 [K,N] with per-output-channel f32 scales [N].

    Oracle for the fused kernel: scale the finished f32 accumulator by the
    output column's scale (algebraically identical to dequantizing the
    weight first, but matching the kernel's flush-time multiply exactly)."""
    acc = jnp.dot(
        a, b_q8.astype(a.dtype), preferred_element_type=jnp.float32
    )
    return (acc * b_scale.reshape(1, -1).astype(jnp.float32)).astype(a.dtype)


def axpy(alpha, x: jax.Array, y: jax.Array) -> jax.Array:
    return (alpha * x.astype(jnp.float32) + y.astype(jnp.float32)).astype(x.dtype)


def dotp(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def softmax(x: jax.Array) -> jax.Array:
    """Row softmax over the last dim, numerically stable, f32 math."""
    xf = x.astype(jnp.float32)
    m = xf.max(axis=-1, keepdims=True)
    e = jnp.exp(xf - m)
    return (e / e.sum(axis=-1, keepdims=True)).astype(x.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def fft(re: jax.Array, im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched complex FFT over the last dim, planar (re, im) f32 layout."""
    z = jnp.fft.fft(re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64), axis=-1)
    return jnp.real(z).astype(re.dtype), jnp.imag(z).astype(im.dtype)


def conv2d(x: jax.Array, w: jax.Array) -> jax.Array:
    """NHWC x HWIO VALID conv, stride 1, f32 accumulation."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out.astype(x.dtype)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """[B,H,S,hd] attention oracle (dense softmax)."""
    b, h, s, d = q.shape
    scale = d**-0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[2]), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def gqa_flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """GQA oracle. q: [BKV, G, Sq, d]; k/v: [BKV, Sk, d] (no head repeat)."""
    bkv, g, sq, d = q.shape
    sk = k.shape[1]
    scale = d**-0.5
    scores = jnp.einsum("bgqd,bkd->bgqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgqk,bkd->bgqd", p.astype(v.dtype), v)


def dequant_kv(k: jax.Array, k_scale: jax.Array | None, dtype) -> jax.Array:
    """Widen a (possibly int8) KV tensor to ``dtype`` and apply per-row
    scales (one scale per ``[..., d]`` row, i.e. ``k.shape[:-1]``). With
    ``k_scale=None`` this is the plain dtype cast the unquantized oracles
    always did; with all-ones f32 scales it is bit-identical to that cast
    (``x * 1.0 == x``), which is what makes the quantized machinery testable
    at ``kv_dtype=f32``."""
    kf = k.astype(dtype)
    if k_scale is None:
        return kf
    return kf * k_scale[..., None].astype(dtype)


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cur_len: jax.Array,
    *,
    window: int = 0,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Single-token grouped decode attention oracle.

    q: [B, KV, G, d]; k/v: [B, S_max, KV, d]; cur_len: [] or [B] tokens
    already cached (the new token was scattered at index cur_len, so key
    position t is valid iff t <= cur_len). Optional ``k_scale``/``v_scale``
    ([B, S_max, KV] f32) dequantize int8 K/V rows in-math — garbage scales
    at invalid positions are as harmless as garbage K/V (masked lanes).
    Returns [B, KV, G, d] in f32 softmax math, cast back to q.dtype.
    """
    k = dequant_kv(k, k_scale, q.dtype)
    v = dequant_kv(v, v_scale, q.dtype)
    b, kvh, g, d = q.shape
    s_max = k.shape[1]
    scale = d**-0.5
    scores = (
        jnp.einsum("bkgd,btkd->bkgt", q, k.astype(q.dtype)).astype(jnp.float32)
        * scale
    )  # [B,KV,G,S]
    kpos = jnp.arange(s_max)[None, :]
    cur = jnp.broadcast_to(jnp.asarray(cur_len), (b,))[:, None]
    valid = kpos <= cur
    if window:
        valid &= kpos > cur - window
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgt,btkd->bkgd", probs, v.astype(q.dtype))


def ragged_valid_mask(
    tok_slot: jax.Array,
    tok_pos: jax.Array,
    b: int,
    s_max: int,
    window: int = 0,
) -> jax.Array:
    """[T, B, S_max] bool: which cache entries each packed token may attend.

    Key position p of slot ``tok_slot[t]`` is valid iff p <= tok_pos[t]
    (windowed by p > tok_pos[t] - window) — the per-token generalization of
    the ``decode_attention`` convention. Descriptor-only, so the serving
    path computes it ONCE per pack and reuses it across every layer."""
    kpos = jnp.arange(s_max)[None, :]
    pos = jnp.asarray(tok_pos)[:, None]
    valid_s = kpos <= pos  # [T, S]
    if window:
        valid_s &= kpos > pos - window
    slot_hit = jnp.asarray(tok_slot)[:, None] == jnp.arange(b)[None, :]  # [T, B]
    return slot_hit[:, :, None] & valid_s[:, None, :]


def ragged_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    tok_slot: jax.Array,
    tok_pos: jax.Array,
    *,
    window: int = 0,
    valid: jax.Array | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Packed variable-length attention oracle (the unified-dispatch path).

    q: [T, KV, G, d] packed query tokens (decode singletons and prefill
    chunks mixed); k/v: [B, S_max, KV, d] batched cache with the packed
    tokens' K/V already scattered at (tok_slot, tok_pos); tok_slot/tok_pos:
    [T] int32; ``valid`` optionally passes a precomputed
    :func:`ragged_valid_mask`; ``k_scale``/``v_scale`` ([B, S_max, KV] f32)
    dequantize int8 caches per row. Returns [T, KV, G, d] in f32 softmax
    math, cast back to q.dtype.

    Full-cross formulation: every packed token scores against EVERY slot's
    cache in one batched matmul per KV head, and the B-1 wrong slots are
    masked away before a softmax over the joint (slot, position) axes —
    only the token's own slot survives, so this IS the per-slot softmax.
    B is small in serving (a handful of cache slots), so the B× extra MACs
    are far cheaper on CPU than a per-token cache gather followed by T tiny
    batched dots, and the whole oracle is two dot_generals + one where.
    """
    k = dequant_kv(k, k_scale, q.dtype)
    v = dequant_kv(v, v_scale, q.dtype)
    t, kvh, g, d = q.shape
    b, s_max = k.shape[0], k.shape[1]
    scale = d**-0.5
    if valid is None:
        valid = ragged_valid_mask(tok_slot, tok_pos, b, s_max, window)
    # explicit [KV]-batched [T·G, d] @ [d, B·S] matmuls: XLA CPU lowers this
    # shape well at every pack size (the equivalent 5-D einsum does not)
    qf = q.transpose(1, 0, 2, 3).reshape(kvh, t * g, d).astype(jnp.float32)
    kf = k.transpose(2, 0, 1, 3).reshape(kvh, b * s_max, d).astype(jnp.float32)
    scores = jnp.einsum("hqd,hsd->hqs", qf, kf) * scale  # [KV, T*G, B*S]
    valid_tg = jnp.broadcast_to(
        valid.reshape(t, 1, b * s_max), (t, g, b * s_max)
    ).reshape(t * g, b * s_max)
    scores = jnp.where(valid_tg[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    vf = v.transpose(2, 0, 1, 3).reshape(kvh, b * s_max, d).astype(jnp.float32)
    out = jnp.einsum("hqs,hsd->hqd", probs, vf)  # [KV, T*G, d]
    return out.reshape(kvh, t, g, d).transpose(1, 0, 2, 3).astype(q.dtype)


def mla_ragged_attention(
    q_eff: jax.Array,
    q_rope: jax.Array,
    ckv: jax.Array,
    krope: jax.Array,
    tok_slot: jax.Array,
    tok_pos: jax.Array,
    *,
    scale: float,
    valid: jax.Array | None = None,
) -> jax.Array:
    """Packed ragged attention in MLA latent space (absorbed decode form).

    q_eff: [T, H, r] queries pre-absorbed through W_uk (r = kv_lora_rank);
    q_rope: [T, H, rope] decoupled-RoPE queries; ckv: [B, S_max, r]
    compressed latent cache (doubles as K and V); krope: [B, S_max, rope]
    shared rope keys; tok_slot/tok_pos: [T] int32 pack descriptors;
    ``scale`` is the softmax scale — (nope + rope)**-0.5, NOT derived from
    the latent width (the latent dot replaces an H-head nope-dim dot, so
    the head-dim scale survives absorption). Returns [T, H, r] latent
    outputs; the caller decompresses through W_uv.

    Same full-cross formulation as :func:`ragged_attention`, specialized to
    MLA's MQA structure: ONE shared latent "head" serves every query head,
    scores are the sum of the latent and rope dots, and the value readout
    re-reads the latent cache itself.
    """
    t, h, r = q_eff.shape
    b, s_max = ckv.shape[0], ckv.shape[1]
    if valid is None:
        valid = ragged_valid_mask(tok_slot, tok_pos, b, s_max)
    qe = q_eff.transpose(1, 0, 2).astype(jnp.float32)  # [H, T, r]
    qr = q_rope.transpose(1, 0, 2).astype(jnp.float32)  # [H, T, rope]
    kl = ckv.reshape(b * s_max, r).astype(jnp.float32)  # [B·S, r]
    kr = krope.reshape(b * s_max, krope.shape[-1]).astype(jnp.float32)
    scores = (
        jnp.einsum("htr,sr->hts", qe, kl) + jnp.einsum("htk,sk->hts", qr, kr)
    ) * scale  # [H, T, B·S]
    valid_ts = valid.reshape(t, b * s_max)
    scores = jnp.where(valid_ts[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hts,sr->htr", probs, kl)  # latent-space readout
    return out.transpose(1, 0, 2).astype(q_eff.dtype)


def paged_gather(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Materialize dense cache rows from a block-paged pool.

    pool: [num_blocks, block_size, ...]; block_tables: [R, max_blocks]
    int32 (out-of-range sentinel = unallocated). Returns [R, max_blocks *
    block_size, ...] — exactly the dense ``[B, S_max, ...]`` cache layout,
    where position ``p`` of row ``r`` is ``pool[block_tables[r, p //
    block_size], p % block_size]``.

    Unallocated table entries clamp to the last real block, so their
    positions hold arbitrary (finite) pool contents — every consumer below
    masks by the same position bounds as the dense path, under which an
    identity-mapped pool reproduces the dense cache BIT-EXACTLY: masked
    score lanes contribute exp(NEG_INF - max) == 0 regardless of what the
    garbage positions hold. This gather is the oracle/CPU formulation; the
    Pallas path (``ragged_attention.paged_ragged_attention``) consumes the
    pool directly through its BlockSpec index map and never builds it.
    """
    nb, bs = pool.shape[0], pool.shape[1]
    r, maxb = block_tables.shape
    view = pool[jnp.minimum(block_tables, nb - 1)]  # [R, maxb, bs, ...]
    return view.reshape(r, maxb * bs, *pool.shape[2:])


def paged_decode_attention(
    q: jax.Array,
    pool_k: jax.Array,
    pool_v: jax.Array,
    cur_len: jax.Array,
    block_tables: jax.Array,
    *,
    window: int = 0,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Paged single-token decode oracle: gather the per-sequence dense view
    from the pool, then the EXACT dense decode oracle — the paged engine's
    greedy streams stay bit-identical to the slot-cache engine on CPU.
    Scale pools ([num_blocks, block_size, KV] f32) ride the SAME gather
    (``paged_gather`` is trailing-dim agnostic), so scales travel with their
    blocks through tables, COW sharing and re-homing by construction."""
    return decode_attention(
        q,
        paged_gather(pool_k, block_tables),
        paged_gather(pool_v, block_tables),
        cur_len,
        window=window,
        k_scale=None if k_scale is None else paged_gather(k_scale, block_tables),
        v_scale=None if v_scale is None else paged_gather(v_scale, block_tables),
    )


def paged_ragged_attention(
    q: jax.Array,
    pool_k: jax.Array,
    pool_v: jax.Array,
    tok_seq: jax.Array,
    tok_pos: jax.Array,
    block_tables: jax.Array,
    *,
    window: int = 0,
    valid: jax.Array | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Paged packed ragged oracle: the dense :func:`ragged_attention` over
    the block tables' gathered view (same masks, same math, bit-identical
    to the dense path wherever positions are valid). Scale pools gather
    through the same tables as their payload blocks."""
    return ragged_attention(
        q,
        paged_gather(pool_k, block_tables),
        paged_gather(pool_v, block_tables),
        tok_seq,
        tok_pos,
        window=window,
        valid=valid,
        k_scale=None if k_scale is None else paged_gather(k_scale, block_tables),
        v_scale=None if v_scale is None else paged_gather(v_scale, block_tables),
    )


def fft_twiddles(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-stage Stockham radix-2 twiddle table [log2(n), n//2] (re, im).

    Stage s (s=0 the first) multiplies odd halves by W_{2L}^{j mod L} where
    L = 2**s; entries are tiled so every stage reads row s directly.
    """
    stages = int(np.log2(n))
    assert 2**stages == n, f"n={n} must be a power of 2"
    tw_re = np.ones((stages, n // 2), np.float32)
    tw_im = np.zeros((stages, n // 2), np.float32)
    for s in range(stages):
        l = 2**s
        j = np.arange(n // 2) % l
        ang = -2.0 * np.pi * j / (2 * l)
        tw_re[s] = np.cos(ang).astype(np.float32)
        tw_im[s] = np.sin(ang).astype(np.float32)
    return tw_re, tw_im


def fft_stockham(re: jax.Array, im: jax.Array) -> tuple[jax.Array, jax.Array]:
    """jnp Stockham radix-2 (the exact algorithm the Pallas kernel runs).

    Kept separate from :func:`fft` (which defers to jnp.fft) so kernel bugs
    can be localized: kernel ↔ fft_stockham ↔ jnp.fft.
    """
    b, n = re.shape
    stages = int(np.log2(n))
    tw_re, tw_im = fft_twiddles(n)
    xr = re.astype(jnp.float32)
    xi = im.astype(jnp.float32)
    for s in range(stages):
        l = 2**s
        g = n // (2 * l)
        # Stockham split: even = first half, odd = second half, viewed [g, l]
        er = xr[:, : n // 2].reshape(b, g, l)
        ei = xi[:, : n // 2].reshape(b, g, l)
        orr = xr[:, n // 2 :].reshape(b, g, l)
        oi = xi[:, n // 2 :].reshape(b, g, l)
        twr = tw_re[s].reshape(g, l)
        twi = tw_im[s].reshape(g, l)
        tr = orr * twr - oi * twi
        ti = orr * twi + oi * twr
        xr = jnp.concatenate([er + tr, er - tr], axis=-1).reshape(b, n)
        xi = jnp.concatenate([ei + ti, ei - ti], axis=-1).reshape(b, n)
    return xr.astype(re.dtype), xi.astype(im.dtype)
