"""Forward-compat shims for older jax images.

The codebase targets the jax ≥ 0.6 surface (``jax.shard_map`` with
``check_vma``, ``jax.sharding.AxisType``, ``jax.make_mesh(axis_types=...)``).
The pinned container ships jax 0.4.37, where those spellings don't exist yet;
this module installs equivalents onto the ``jax`` namespace so the same source
runs on both. Importing :mod:`repro` (any submodule) activates it. Every shim
is a no-op when the real API is already present.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        # 0.4.x spells replication checking `check_rep`; default it off — the
        # old inference rejects valid ppermute-based programs.
        if check_vma is not None:
            kwargs.setdefault("check_rep", check_vma)
        else:
            kwargs.setdefault("check_rep", False)
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )

    jax.shard_map = shard_map


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        return
    _make_mesh = jax.make_mesh

    @functools.wraps(_make_mesh)
    def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kwargs):
        del axis_types  # 0.4.x meshes are implicitly Auto on every axis
        return _make_mesh(axis_shapes, axis_names, *args, **kwargs)

    jax.make_mesh = make_mesh


def _install_pallas_compiler_params() -> None:
    try:
        import jax.experimental.pallas.tpu as pltpu
    except ImportError:  # pragma: no cover - pallas always ships in our images
        return
    if not hasattr(pltpu, "CompilerParams") and hasattr(pltpu, "TPUCompilerParams"):
        # renamed TPUCompilerParams -> CompilerParams in newer jax
        pltpu.CompilerParams = pltpu.TPUCompilerParams


def install() -> None:
    _install_shard_map()
    _install_axis_type()
    _install_make_mesh()
    _install_pallas_compiler_params()


install()
