"""Architecture/shape registry. Importing this package registers all archs."""

from repro.configs.base import (
    ARCHS,
    SHAPES,
    ArchConfig,
    MLAConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    all_cells,
    applicable,
    get_arch,
    get_shape,
    register_arch,
)

# Import every arch module for registration side effects.
from repro.configs import (  # noqa: F401
    chameleon_34b,
    codeqwen15_7b,
    deepseek_v2_lite_16b,
    falcon_mamba_7b,
    llama4_scout_17b,
    minicpm3_4b,
    mistral_large_123b,
    musicgen_large,
    qwen3_32b,
    zamba2_2p7b,
)

ARCH_NAMES = tuple(sorted(ARCHS))

__all__ = [
    "ARCHS",
    "ARCH_NAMES",
    "SHAPES",
    "ArchConfig",
    "MLAConfig",
    "MoEConfig",
    "ShapeConfig",
    "SSMConfig",
    "TrainConfig",
    "all_cells",
    "applicable",
    "get_arch",
    "get_shape",
    "register_arch",
]
