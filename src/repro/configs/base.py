"""Config system: frozen dataclasses + a registry keyed by --arch id.

Every assigned architecture registers an :class:`ArchConfig` via
:func:`register_arch` in its own ``configs/<id>.py`` module. Input-shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are :class:`ShapeConfig`
entries in :data:`SHAPES`. ``applicable(arch, shape)`` encodes the brief's
skip rules (long_500k only for sub-quadratic archs).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (DeepSeek/Llama4-style)."""

    n_routed: int  # number of routed experts
    top_k: int  # experts per token
    n_shared: int = 0  # always-on shared experts
    expert_ff: int = 0  # hidden width of each routed/shared expert
    capacity_factor: float = 1.25  # EP dispatch capacity multiplier
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.001  # load-balance auxiliary loss


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""

    kv_lora_rank: int
    q_lora_rank: int = 0  # 0 => dense q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """State-space (Mamba) block config."""

    variant: str  # 'mamba1' | 'mamba2'
    state: int  # N: SSM state size
    conv_kernel: int = 4
    expand: int = 2  # d_inner = expand * d_model
    head_dim: int = 64  # mamba2 only
    n_groups: int = 1  # mamba2 only
    chunk: int = 256  # mamba2 SSD chunk length


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid'
    modality: str = "text"  # 'text' | 'audio' | 'vlm'
    source: str = ""  # provenance string from the assignment

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0  # 0 => d_model // n_heads
    d_ff: int = 0
    vocab_size: int = 0

    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    first_k_dense: int = 0  # leading dense layers in an MoE stack
    dense_ff: int = 0  # d_ff of those dense layers (0 => d_ff)
    shared_attn_every: int = 0  # hybrid: shared attn block cadence (zamba2)
    sliding_window: int = 0  # 0 => full attention

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # runtime knobs (not architecture identity)
    dtype: str = "bfloat16"
    attn_impl: str = "chunked"  # 'dense' | 'chunked' (online-softmax scan)
    attn_chunk: int = 512  # KV block for chunked attention
    remat: str = "block"  # 'none' | 'block' (remat each scanned layer)
    kv_cache_dtype: str = ""  # '' => dtype; 'float8_e4m3fn' halves KV memory

    # ---- derived ----
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def uses_attention(self) -> bool:
        return self.family in ("dense", "moe") or self.shared_attn_every > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode (long_500k) is in this arch's regime."""
        return self.family in ("ssm", "hybrid") or (
            self.sliding_window > 0 and self.family in ("dense", "moe")
        )

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            # mamba1: in_proj (d -> 2*d_in), conv, x_proj (d_in -> dt+2N), dt_proj,
            # A (d_in, N), D, out_proj
            if s.variant == "mamba1":
                dt_rank = max(d // 16, 1)
                per_layer = (
                    d * 2 * d_in
                    + s.conv_kernel * d_in
                    + d_in * (dt_rank + 2 * s.state)
                    + dt_rank * d_in
                    + d_in * s.state
                    + d_in
                    + d_in * d
                )
            else:  # mamba2
                n_heads = d_in // s.head_dim
                conv_dim = d_in + 2 * s.n_groups * s.state
                per_layer = (
                    d * (2 * d_in + 2 * s.n_groups * s.state + n_heads)
                    + s.conv_kernel * conv_dim
                    + 3 * n_heads  # A, D, dt_bias
                    + d_in * d
                )
            per_layer += d  # norm
            total = emb + L * per_layer + d
            return int(total)

        # attention params
        if self.mla is not None:
            m = self.mla
            qk_dim = m.nope_head_dim + m.rope_head_dim
            if m.q_lora_rank:
                q_p = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_dim
            else:
                q_p = d * self.n_heads * qk_dim
            kv_p = (
                d * (m.kv_lora_rank + m.rope_head_dim)
                + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
            )
            o_p = self.n_heads * m.v_head_dim * d
            attn = q_p + kv_p + o_p
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # gated SwiGLU: in, gate, out

        norms = 2 * d
        if self.family == "moe":
            assert self.moe is not None
            moe_ff = self.moe.expert_ff or f
            routed = self.moe.n_routed * mlp_params(moe_ff)
            shared = self.moe.n_shared * mlp_params(moe_ff)
            router = d * self.moe.n_routed
            moe_layers = L - self.first_k_dense
            dense_layers = self.first_k_dense
            dff = self.dense_ff or f
            total = (
                emb
                + moe_layers * (attn + routed + shared + router + norms)
                + dense_layers * (attn + mlp_params(dff) + norms)
                + d
            )
            return int(total)

        if self.family == "hybrid":
            # zamba2-style: L mamba2 blocks + ONE shared attn+mlp block (tied)
            s = self.ssm
            d_in = s.expand * d
            n_h = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.state
            mamba = (
                d * (2 * d_in + 2 * s.n_groups * s.state + n_h)
                + s.conv_kernel * conv_dim
                + 3 * n_h
                + d_in * d
                + d
            )
            shared_block = attn + mlp_params(f) + norms
            return int(emb + L * mamba + shared_block + d)

        return int(emb + L * (attn + mlp_params(f) + norms) + d)

    def num_active_params(self) -> int:
        """Active (per-token) parameters — differs from num_params for MoE."""
        if self.family != "moe":
            return self.num_params()
        assert self.moe is not None
        d, L = self.d_model, self.n_layers
        moe_ff = self.moe.expert_ff or self.d_ff
        inactive = (
            (L - self.first_k_dense)
            * (self.moe.n_routed - self.moe.top_k)
            * 3
            * d
            * moe_ff
        )
        return int(self.num_params() - inactive)

    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=2,
            d_model=64,
            d_ff=128,
            vocab_size=256,
            d_head=16,
        )
        if self.n_heads:
            kw["n_heads"] = 4
            kw["n_kv_heads"] = max(1, min(4, 4 * self.n_kv_heads // max(self.n_heads, 1)))
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe, n_routed=4, top_k=min(self.moe.top_k, 2), expert_ff=64
            )
            kw["first_k_dense"] = min(self.first_k_dense, 1)
            kw["dense_ff"] = 128 if self.first_k_dense else 0
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                kv_lora_rank=32,
                q_lora_rank=32 if self.mla.q_lora_rank else 0,
                rope_head_dim=8,
                nope_head_dim=16,
                v_head_dim=16,
            )
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, state=8, head_dim=16, chunk=32)
        if self.shared_attn_every:
            kw["n_layers"] = 4
            kw["shared_attn_every"] = 2
        kw["dtype"] = "float32"
        kw["attn_chunk"] = 64
        kw["name"] = self.name + "-reduced"
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCHS: dict[str, ArchConfig] = {}


def register_arch(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in ARCHS:
        raise ValueError(f"duplicate arch {cfg.name}")
    ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    # ensure all config modules are imported (registry populated)
    import repro.configs  # noqa: F401

    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig(
        "prefill_32k", seq_len=32768, global_batch=32, kind="prefill"
    ),
    "decode_32k": ShapeConfig(
        "decode_32k", seq_len=32768, global_batch=128, kind="decode"
    ),
    "long_500k": ShapeConfig(
        "long_500k", seq_len=524288, global_batch=1, kind="decode"
    ),
}


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def applicable(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """Brief rules: long_500k only for sub-quadratic archs; decoder archs run all."""
    if shape.name == "long_500k":
        return arch.sub_quadratic
    return True


def all_cells() -> list[tuple[str, str]]:
    """Every live (arch, shape) cell per the applicability rules."""
    import repro.configs  # noqa: F401

    cells = []
    for aname in sorted(ARCHS):
        for sname in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if applicable(ARCHS[aname], SHAPES[sname]):
                cells.append((aname, sname))
    return cells


# ---------------------------------------------------------------------------
# Training hyperparameters (runtime, not architecture identity)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_accum: int = 1  # microbatch count
    compress_grads: bool = False  # int8 + error-feedback all-reduce
    seed: int = 0
    log_every: int = 10
    ckpt_every: int = 200
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
