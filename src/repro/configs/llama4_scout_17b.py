"""llama4-scout-17b-a16e — MoE (16 experts, top-1) with GQA; early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192(expert) vocab=202048, MoE 16e top-1
+ 1 always-on shared expert (Llama-4 style).
"""

from repro.configs.base import ArchConfig, MoEConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab_size=202048,
        moe=MoEConfig(
            n_routed=16,
            top_k=1,
            n_shared=1,
            expert_ff=8192,
            capacity_factor=1.5,  # top-1 routing needs more slack
            aux_loss_coef=0.001,
        ),
        rope_theta=500_000.0,
    )
)
