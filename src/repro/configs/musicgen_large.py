"""musicgen-large — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284; hf]
48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048

Audio modality: the EnCodec tokenizer/frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings (B, S, d_model) as the model input; the
backbone and the (B, S, vocab) codebook logits head are real.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="musicgen-large",
        family="dense",
        modality="audio",
        source="arXiv:2306.05284; hf",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_head=64,
        d_ff=8192,
        vocab_size=2048,
    )
)
