"""codeqwen1.5-7b — dense MHA (kv == heads) transformer, qwen1.5 arch.

[hf:Qwen/CodeQwen1.5-7B; hf]
32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="codeqwen1.5-7b",
        family="dense",
        source="hf:Qwen/CodeQwen1.5-7B; hf",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_head=128,
        d_ff=13440,
        vocab_size=92416,
        rope_theta=1_000_000.0,
        norm_eps=1e-6,
    )
)
