"""deepseek-v2-lite-16b — MoE transformer with MLA.

[arXiv:2405.04434; hf]
27L d_model=2048 16H (GQA kv=16) d_ff=1408(expert) vocab=102400,
MoE 64 routed top-6 + 2 shared — MLA kv_lora=512.

The brief's primary numbers (64e top-6) are used; its "160 routed" aside
belongs to the full V2. First layer is dense (ff=10944) per the HF config.
"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        source="arXiv:2405.04434; hf",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=192,  # nope(128) + rope(64)
        d_ff=1408,
        vocab_size=102400,
        first_k_dense=1,
        dense_ff=10944,
        moe=MoEConfig(
            n_routed=64,
            top_k=6,
            n_shared=2,
            expert_ff=1408,
            capacity_factor=1.25,
            aux_loss_coef=0.001,
        ),
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=0,  # V2-Lite: dense q projection
            rope_head_dim=64,
            nope_head_dim=128,
            v_head_dim=128,
        ),
        norm_eps=1e-6,
    )
)
