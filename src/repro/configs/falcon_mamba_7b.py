"""falcon-mamba-7b — pure Mamba1 SSM LM (attention-free).

[arXiv:2410.05355; unverified]
64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16 — mamba1 arch
"""

from repro.configs.base import ArchConfig, SSMConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="falcon-mamba-7b",
        family="ssm",
        source="arXiv:2410.05355; unverified",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=65024,
        ssm=SSMConfig(
            variant="mamba1",
            state=16,
            conv_kernel=4,
            expand=2,
        ),
        tie_embeddings=True,
    )
)
