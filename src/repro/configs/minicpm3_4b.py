"""minicpm3-4b — dense transformer with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf]
62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448 — MLA

MLA sub-config (q_lora=768, kv_lora=256, rope=32, nope=64, v=64) from the HF
config where the assignment brief is silent.
"""

from repro.configs.base import ArchConfig, MLAConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="minicpm3-4b",
        family="dense",
        source="hf:openbmb/MiniCPM3-4B; hf",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_head=96,  # nope + rope
        d_ff=6400,
        vocab_size=73448,
        mla=MLAConfig(
            kv_lora_rank=256,
            q_lora_rank=768,
            rope_head_dim=32,
            nope_head_dim=64,
            v_head_dim=64,
        ),
        tie_embeddings=True,
        norm_eps=1e-6,
    )
)
