"""zamba2-2.7b — hybrid: Mamba2 backbone + shared (weight-tied) attention block.

[arXiv:2411.15242; hf]
54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64

Every 6th Mamba2 block is followed by an invocation of the single shared
attention+MLP block (weights tied across invocations). The real model's
per-invocation LoRA deltas are simplified to pure weight tying.
"""

from repro.configs.base import ArchConfig, SSMConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        source="arXiv:2411.15242; hf",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_head=80,
        d_ff=10240,
        vocab_size=32000,
        shared_attn_every=6,
        ssm=SSMConfig(
            variant="mamba2",
            state=64,
            conv_kernel=4,
            expand=2,
            head_dim=64,
            n_groups=1,
            chunk=256,
        ),
        tie_embeddings=True,
    )
)
