"""chameleon-34b — early-fusion VLM: VQ image tokens share the text vocab.

[arXiv:2405.09818; unverified]
48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536

VLM modality: images arrive as discrete VQ-VAE codes inside the 65536-entry
vocab (early fusion), so the token pipeline is uniform; the VQ image tokenizer
itself is a stub (tokens arrive pre-quantized). qk_norm per the Chameleon
paper's training-stability fix.
"""

from repro.configs.base import ArchConfig, register_arch

CONFIG = register_arch(
    ArchConfig(
        name="chameleon-34b",
        family="dense",
        modality="vlm",
        source="arXiv:2405.09818; unverified",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=22016,
        vocab_size=65536,
        qk_norm=True,
    )
)
