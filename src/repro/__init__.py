"""Spatzformer-JAX: a reconfigurable multi-pod JAX training/inference framework.

Reproduction + extension of "Spatzformer: An Efficient Reconfigurable Dual-Core
RISC-V V Cluster for Mixed Scalar-Vector Workloads" (Perotti et al., 2024),
adapted to TPU v5e multi-pod meshes.

The paper's split/merge reconfigurability is implemented over the mesh `pod`
axis (``repro.core``): SPLIT partitions the fabric into independent sub-mesh
tenants, each with its own controller; MERGE fuses the fabric under a single
controller and frees the remaining controllers for scalar/control work that
overlaps with device compute.
"""

from repro import compat as _compat  # noqa: F401  (installs jax 0.4.x shims)

__version__ = "1.0.0"
