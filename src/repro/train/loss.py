"""Cross-entropy LM loss (next-token), vocab-shard friendly."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_loss(
    logits: jax.Array, labels: jax.Array, *, shift: bool = True
) -> jax.Array:
    """Mean CE of logits [B,S,V] against labels [B,S].

    shift=True: predict labels[:, t+1] from logits[:, t] (causal LM).
    The logsumexp form keeps the math stable and lowers to collectives
    cleanly when V is sharded on the model axis.
    """
    if shift:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # gather-free target pick: iota-compare-reduce fuses under SPMD without
    # materializing/gathering the vocab-sharded logits (take_along_axis would)
    v = lf.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    tgt = jnp.sum(
        jnp.where(iota == labels[..., None].astype(jnp.int32), lf, 0.0), axis=-1
    )
    return jnp.mean(lse - tgt)
