from repro.train.loss import next_token_loss
from repro.train.optimizer import (
    AdamWState,
    adamw_init,
    adamw_update,
    warmup_cosine,
)
from repro.train.step import (
    make_compressed_dp_train_step,
    make_loss_fn,
    make_train_step,
)

__all__ = [
    "next_token_loss",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "warmup_cosine",
    "make_loss_fn",
    "make_train_step",
    "make_compressed_dp_train_step",
]
