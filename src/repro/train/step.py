"""Train-step factories: the pjit path (production) and the compressed-DP
shard_map path (gradient compression demo at pure-DP scale).

``make_train_step(model, tcfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings from ``repro.dist.sharding`` — gradient
accumulation over microbatches happens inside (lax.scan over microbatch
slices), so the global batch arrives as one array and HBM sees one
microbatch of activations at a time.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.dist import compression as comp
from repro.models.model import LM
from repro.train.loss import next_token_loss
from repro.train.optimizer import AdamWState, adamw_update, warmup_cosine


def make_loss_fn(model: LM) -> Callable:
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss = next_token_loss(logits, batch["labels"])
        if model.cfg.moe is not None:
            loss = loss + model.cfg.moe.aux_loss_coef * aux
        return loss, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(model: LM, tcfg: TrainConfig) -> Callable:
    """pjit-path train step with optional microbatch gradient accumulation."""
    loss_fn = make_loss_fn(model)
    schedule = warmup_cosine(tcfg)
    n_micro = max(tcfg.grad_accum, 1)

    def train_step(params, opt_state: AdamWState, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            # microbatch split along global batch dim; scan accumulates f32 grads
            def micro(carry, mb):
                acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / n_micro, acc, g
                )
                return acc, m

            micro_batches = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                batch,
            )
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(micro, zero, micro_batches)
            metrics = jax.tree.map(lambda x: x.mean(), ms)
            loss = metrics["loss"]

        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, tcfg, schedule
        )
        metrics = dict(metrics, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


# ---------------------------------------------------------------------------
# compressed-DP path: explicit shard_map over the data axes so the gradient
# all-reduce is OURS (int8 ring + error feedback) instead of XLA's implicit
# psum. Params replicated, batch sharded — pure DP (used by examples/ and
# integration tests; production TP cells use the pjit path above).
# ---------------------------------------------------------------------------


def make_compressed_dp_train_step(
    model: LM, tcfg: TrainConfig, mesh, data_axis: str = "data"
) -> Callable:
    from jax.sharding import PartitionSpec as P

    loss_fn = make_loss_fn(model)
    schedule = warmup_cosine(tcfg)

    def shard_body(params, opt_state, ef_residual, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        # error feedback + int8 ring all-reduce (mean over data shards)
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, ef_residual
        )
        reduced = comp.allreduce_pytree_q8(corrected, data_axis)
        new_resid = jax.tree.map(lambda c, r: c - r, corrected, reduced)
        new_params, new_opt, opt_metrics = adamw_update(
            reduced, opt_state, params, tcfg, schedule
        )
        metrics = dict(metrics, **opt_metrics)
        metrics = jax.tree.map(lambda x: jax.lax.pmean(x, data_axis), metrics)
        return new_params, new_opt, new_resid, metrics

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def train_step(params, opt_state, ef_residual, batch):
        p_spec = specs_like(params, P())
        o_spec = specs_like(opt_state, P())
        e_spec = specs_like(ef_residual, P())
        b_spec = specs_like(batch, P(data_axis))
        m_spec = P()
        fn = jax.shard_map(
            shard_body,
            mesh=mesh,
            in_specs=(p_spec, o_spec, e_spec, b_spec),
            out_specs=(p_spec, o_spec, e_spec, specs_like({"loss": 0, "aux": 0, "grad_norm": 0, "lr": 0}, m_spec)),
            check_vma=False,
        )
        return fn(params, opt_state, ef_residual, batch)

    return jax.jit(train_step)
