"""AdamW with decoupled weight decay + cosine/warmup schedules.

Self-contained (no optax in the image): state is a pytree-of-pytrees
{mu, nu, step}; moments are f32 regardless of param dtype (bf16-safe).
Optimizer state inherits the parameter sharding (moments shard like their
parameter), which `repro.dist.sharding.opt_shardings` encodes.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # first moment (f32, like params)
    nu: Any  # second moment (f32)


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def warmup_cosine(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
        prog = (step - cfg.warmup_steps) / jnp.maximum(
            cfg.total_steps - cfg.warmup_steps, 1
        )
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog)
        )
        return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)

    return schedule


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: TrainConfig,
    schedule: Callable[[jax.Array], jax.Array],
) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    b1, b2 = cfg.betas
    step = state.step + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = schedule(step)

    mu = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
    )
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu,
        grads,
    )
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, mu=mu, nu=nu), metrics
