"""Batched serving engine with continuous batching (slot-based).

A fixed pool of ``batch_slots`` cache slots; requests are admitted into free
slots via single-sequence prefill (scattered into the batched cache at the
slot index), and every engine tick advances ALL active slots one token with
one jitted ``decode_step`` (per-slot ``cur_len`` vector — the decode paths
mask per-slot). Finished slots free immediately and the next waiting request
is admitted: classic continuous batching, sized down.

Notes:
* prefill compiles per distinct prompt length (exact-length prefill keeps
  SSM states clean — right-padding would pollute the recurrence; production
  TPU serving would bucket attention-only archs).
* sampling (greedy / temperature) happens host-side on the [B, V] logits.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    temperature: float = 0.0
    generated: list[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


@dataclass
class ServeStats:
    total_tokens: int = 0
    total_requests: int = 0
    wall_seconds: float = 0.0
    ticks: int = 0

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_seconds, 1e-9)


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_len = np.zeros(batch_slots, np.int32)
        self.last_token = np.zeros(batch_slots, np.int32)
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self.rng = np.random.default_rng(seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill_cache = {}
        self._insert = jax.jit(self._insert_fn)

    # ------------------------------------------------------------ internals

    @staticmethod
    def _insert_fn(cache, one_cache, slot):
        """Scatter a B=1 prefilled cache into batched cache at ``slot``."""

        def leaf(c, o):
            return jax.lax.dynamic_update_slice_in_dim(c, o.astype(c.dtype), slot, axis=1)

        return jax.tree.map(leaf, cache, one_cache)

    def _prefill_one(self, req: Request, slot: int) -> np.ndarray:
        s = len(req.prompt)
        if s not in self._prefill_cache:
            self._prefill_cache[s] = jax.jit(
                lambda p, b: self.model.prefill(p, b, self.max_len)
            )
        logits, one_cache = self._prefill_cache[s](
            self.params, {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        )
        self.cache = self._insert(self.cache, one_cache, jnp.int32(slot))
        return np.asarray(logits[0, -1])  # last-position logits

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        if temperature <= 0:
            return int(np.argmax(logits))
        z = logits.astype(np.float64) / temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    # ------------------------------------------------------------------ API

    def submit(self, req: Request) -> None:
        req.submitted_at = time.perf_counter()
        self.waiting.append(req)

    def _admit(self) -> None:
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.waiting:
                req = self.waiting.pop(0)
                last_logits = self._prefill_one(req, slot)
                tok = self._sample(last_logits, req.temperature)
                req.generated.append(tok)
                req.first_token_at = time.perf_counter()
                self.slot_req[slot] = req
                self.slot_len[slot] = len(req.prompt)
                self.last_token[slot] = tok

    def run(self) -> ServeStats:
        """Drain all submitted requests; returns throughput stats."""
        stats = ServeStats()
        t0 = time.perf_counter()
        self._admit()
        while any(r is not None for r in self.slot_req) or self.waiting:
            active = [i for i, r in enumerate(self.slot_req) if r is not None]
            tokens = jnp.asarray(self.last_token, jnp.int32)[:, None]
            cur_len = jnp.asarray(self.slot_len, jnp.int32)
            logits, self.cache = self._decode(
                self.params, self.cache, {"tokens": tokens}, cur_len
            )
            logits_np = np.asarray(logits[:, 0])
            stats.ticks += 1
            for i in active:
                req = self.slot_req[i]
                self.slot_len[i] += 1
                tok = self._sample(logits_np[i], req.temperature)
                req.generated.append(tok)
                stats.total_tokens += 1
                full = self.slot_len[i] + 1 >= self.max_len
                if len(req.generated) >= req.max_new or full:
                    req.done_at = time.perf_counter()
                    self.finished.append(req)
                    self.slot_req[i] = None
                    self.slot_len[i] = 0
                    stats.total_requests += 1
                else:
                    self.last_token[i] = tok
            self._admit()
        stats.wall_seconds = time.perf_counter() - t0
        return stats
