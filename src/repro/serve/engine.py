"""Batched serving engine with continuous batching (slot-based) and a
unified ragged prefill+decode dispatch (merge-mode serving).

A fixed pool of ``batch_slots`` cache slots; requests are admitted into free
slots and every engine tick advances work with one jitted fused dispatch.
Two dispatch shapes exist, chosen per tick from the workload mix — the
temporal analogue of Spatzformer's split/merge reconfiguration:

* **packed tick** (merge mode — any admission in flight): a flat
  ``[T_bucket]`` token batch packs up to ``prefill_budget`` prompt tokens
  from the admitting requests (Sarathi-style chunked prefill) through
  ``LM.packed_step`` → the ragged varlen attention kernel with per-token
  ``(slot, position)`` descriptors; new K/V are scattered at (slot, pos) in
  one fused O(T) write — no B=1 prefill, no full-cache insert copy, no
  blocking logits transfer + host sample per admission (a completing
  chunk's first token is sampled on device from its final prompt row). In
  the SAME loop iteration every decoding slot advances through a fused
  decode chunk, so decode NEVER stalls behind an admission. A handful of T
  buckets replaces the per-prompt-length prefill compile zoo.
* **decode chunk** (split mode — steady state, no admission work): decode +
  device-side sampling (greedy argmax / gumbel-max per-slot temperature)
  + the per-slot ``cur_len`` advance fused and scanned ``k`` steps deep,
  where ``k`` (bucketed to powers of two up to ``max_chunk``) is the
  largest chunk in which no slot can finish — termination depends only on
  counts, so the host knows ``k`` in advance and chunking is
  output-invariant. A steady-state chunk ships zero host arrays to the
  device, so merge-mode reconfigurability costs the split-mode steady
  state nothing (the paper's C3 parity).

Shared hot-path structure:

* every host→device crossing (params/cache placement, tick state, the
  per-tick staging uploads, program compilation) goes through a pluggable
  :mod:`repro.serve.backend` — the same loop serves the default device, a
  pinned split-mode replica, or a tensor-parallel mesh (merge-mode
  cluster serving, :mod:`repro.serve.cluster`);
* tick state (last tokens, cur_len, PRNG key) is device-resident; host
  bookkeeping tracks counts only and harvests tick t-1's token values while
  tick t computes (termination depends on counts, never on token values);
* the decode cache is donated through every dispatch — the engine never
  holds two copies of the KV cache;
* SSM/hybrid/MLA archs (no positional KV cache to scatter into) keep the
  legacy path: exact-length (SSM) or pow2-bucketed (attention) B=1 prefill
  with per-slot insert, plus the same fused decode chunks.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.serve.backend import PlacementBackend, resolve_backend


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    temperature: float = 0.0
    tenant: Optional[str] = None  # cluster router affinity key (optional)
    generated: list[int] = field(default_factory=list)
    n_generated: int = 0  # tokens sampled so far (values may still be in flight)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


def percentile(xs: list[float], q: float) -> float:
    """Latency percentile with the empty-sample sentinel (0.0) — shared by
    ServeStats and the cluster's ClusterStats so the two never diverge."""
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclass
class ServeStats:
    total_tokens: int = 0
    total_requests: int = 0
    wall_seconds: float = 0.0
    ticks: int = 0
    prefill_compiles: int = 0
    # per-request latency samples for the requests finished in this run:
    # TTFT = first token available - submitted; TPOT = mean inter-token time
    ttfts: list[float] = field(default_factory=list)
    tpots: list[float] = field(default_factory=list)

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_seconds, 1e-9)

    @property
    def ttft_p50(self) -> float:
        return percentile(self.ttfts, 50)

    @property
    def ttft_p99(self) -> float:
        return percentile(self.ttfts, 99)

    @property
    def tpot_p50(self) -> float:
        return percentile(self.tpots, 50)

    @property
    def tpot_p99(self) -> float:
        return percentile(self.tpots, 99)


def _bucket_len(s: int, max_len: int) -> int:
    """Next power of two ≥ s, capped at max_len (prefill compile buckets)."""
    b = 1
    while b < s:
        b *= 2
    return min(b, max_len) if b > s else b


# packed-tick size buckets: a 1.5x ladder keeps padding waste ≤ ~33% while a
# handful of compiled T variants covers every workload mix
_T_BUCKETS = (8, 16, 24, 32, 48, 64, 96, 128)

# max admitting slots per pack (the P in the sub-cache gather); admissions
# beyond it join the next tick's pack
_PACK_WIDTH = 2


def _bucket_tokens(t: int) -> int:
    for b in _T_BUCKETS:
        if t <= b:
            return b
    b = _T_BUCKETS[-1]
    while b < t:
        b *= 2
    return b


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
        unified: Optional[bool] = None,
        prefill_budget: int = 64,
        max_chunk: int = 8,
        backend: Optional[PlacementBackend] = None,
    ):
        self.model = model
        # EVERY host→device crossing goes through the backend: the engine
        # itself is placement-agnostic (single device, pinned replica
        # device, or tensor-parallel mesh — see serve/backend.py)
        self.backend = resolve_backend(backend)
        self.params = self.backend.put_params(model, params)
        self.B = batch_slots
        self.max_len = max_len
        self.seed = seed
        # unified ragged dispatch needs a positional KV cache (dense/moe,
        # non-MLA); other families keep the legacy prefill+insert path
        self.unified = model.supports_packed if unified is None else unified
        if self.unified and not model.supports_packed:
            raise ValueError(
                f"family {model.cfg.family!r}/mla has no packed path"
            )
        self.prefill_budget = max(int(prefill_budget), 1)
        self.max_chunk = max(int(max_chunk), 1)
        self.cache = self.backend.put_cache(model, model.init_cache(batch_slots, max_len))
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_len = np.zeros(batch_slots, np.int32)  # host mirror (counts)
        self.slot_fed = np.zeros(batch_slots, np.int32)  # prompt tokens fed
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        self.rng = np.random.default_rng(seed)
        self._prefill_cache = {}
        self._prefilling: list[int] = []  # slots mid-prefill, admission order
        self._packed_shapes: set[int] = set()  # compiled T buckets
        self._admit_shapes: set[int] = set()  # compiled fused-admission buckets
        self._done_now: list[Request] = []  # requests finished in this run()
        # the cache is donated through all consumers — the engine never
        # holds two copies of the KV cache
        self._insert = self.backend.jit(self._insert_fn, donate_argnums=(0,))
        self._tick = self.backend.jit(
            self._tick_fn, donate_argnums=(1,),
            static_argnames=("n_steps", "has_temp"),
        )
        self._packed = self.backend.jit(
            self._packed_fn, donate_argnums=(1,), static_argnames=("has_temp",)
        )
        self._admit_prog = self.backend.jit(
            self._admit_fn, donate_argnums=(1,), static_argnames=("has_temp",)
        )
        # device-resident tick state: sampled tokens, per-slot lengths, PRNG
        self._last_tok = self.backend.put_state(jnp.zeros(batch_slots, jnp.int32))
        self._cur_len = self.backend.put_state(jnp.zeros(batch_slots, jnp.int32))
        self._rng_key = self.backend.put_state(jax.random.key(seed))
        # event-driven device arrays (re-uploaded only when slots change):
        # lanes rows are (ov_mask, ov_tok, ov_len, active) — one combined
        # upload instead of five tiny ones
        self._lanes_idle = self.backend.put_state(
            jnp.zeros((4, batch_slots), jnp.int32)
        )
        self._temps = self.backend.put_state(jnp.zeros(batch_slots, jnp.float32))
        self._ov_mask_h = np.zeros(batch_slots, bool)  # staged override lanes
        self._ov_tok_h = np.zeros(batch_slots, np.int32)
        self._ov_len_h = np.zeros(batch_slots, np.int32)
        self._dirty = False  # overrides/active/temps pending upload
        # right-padded prefill is only safe when nothing recurrent sees the
        # pad tokens: attention masks them (causal + cur_len), SSM states don't
        self._bucket_prefill = model.cfg.family in ("dense", "moe")

    # ------------------------------------------------------------ internals

    @staticmethod
    def _insert_fn(cache, one_cache, slot):
        """Scatter a B=1 prefilled cache into batched cache at ``slot``."""

        def leaf(c, o):
            return jax.lax.dynamic_update_slice_in_dim(c, o.astype(c.dtype), slot, axis=1)

        return jax.tree.map(leaf, cache, one_cache)

    @staticmethod
    def _sample_or_greedy(logits, temps, key, has_temp: bool):
        """Shared sampling tail of every dispatch kind: gumbel-max at
        per-slot temperature when ``has_temp``, else plain argmax with no
        PRNG split (the greedy fast path skips threefry entirely). The
        split-per-sample discipline is what keeps chunking output-invariant
        — change it here, not in the callers. Returns (tokens, key)."""
        if has_temp:
            key, sub = jax.random.split(key)
            return ServeEngine._sample_batch_fn(logits, temps, sub), key
        tok = jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
        return tok, key

    @staticmethod
    def _sample_batch_fn(logits, temps, key):
        """One device-side sample for every slot. logits: [B, V] (any float
        dtype), temps: [B] f32. Greedy slots take argmax; temperature slots
        take gumbel-max (categorical) at their own temperature."""
        logits = logits.astype(jnp.float32)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gumbel = jax.random.gumbel(key, logits.shape, jnp.float32)
        scaled = logits / jnp.maximum(temps, 1e-6)[:, None] + gumbel
        sampled = jnp.argmax(scaled, axis=-1).astype(jnp.int32)
        return jnp.where(temps > 0, sampled, greedy)

    def _tick_fn(self, params, cache, last_tok, cur_len, lanes, temps, key,
                 n_steps: int = 1, has_temp: bool = True):
        """One fused decode-chunk dispatch: fold the admission override lanes
        into the device state, then run ``n_steps`` decode+sample steps as a
        device-side scan. Everything stays on device; the per-dispatch
        overhead (and, without donation, the KV-cache copy) amortizes over
        the whole chunk. ``lanes`` is ONE [4, B] int32 array — rows
        (ov_mask, ov_tok, ov_len, active) — because every tiny host→device
        upload costs real wall time on small hosts. Returns toks
        [n_steps, B].

        ``has_temp=False`` is the all-greedy fast path: plain argmax, no
        per-step PRNG split and no gumbel draw (threefry is a real cost on
        small hosts). Inactive slots keep their ``last_tok`` (mid-prefill
        slots ride the batch inertly — their sampled garbage must not
        clobber a first token the packed dispatch just wrote).

        Chunking never changes results: the host only chooses ``n_steps``
        such that no slot can finish (and hence no admission can land)
        inside the chunk, and the PRNG split chain per step is identical to
        n_steps=1 dispatches.
        """
        ov_mask = lanes[0].astype(bool)
        active = lanes[3].astype(bool)
        last_tok = jnp.where(ov_mask, lanes[1], last_tok)
        cur_len = jnp.where(ov_mask, lanes[2], cur_len)
        adv = lanes[3]

        def step(carry, _):
            tok, cl, cache, key = carry
            logits, cache = self.model.decode_step(
                params, cache, {"tokens": tok[:, None]}, cl
            )
            new, key = self._sample_or_greedy(logits[:, 0], temps, key, has_temp)
            tok = jnp.where(active, new, tok)
            return (tok, cl + adv, cache, key), tok

        (last_tok, cur_len, cache, key), toks = jax.lax.scan(
            step, (last_tok, cur_len, cache, key), None, length=n_steps
        )
        return toks, last_tok, cur_len, cache, key

    def _packed_fn(self, params, cache, last_tok, desc, meta, temps, key,
                   has_temp: bool = True):
        """One ragged prefill dispatch: a flat [T_bucket] pack of prompt
        chunk tokens from every admitting slot runs through the packed
        model step; a slot whose prompt COMPLETES in this pack samples its
        first token from its final prompt position, device-side, alongside
        everyone else's work — the legacy engine's blocking logits transfer
        + host sample per admission disappears.

        The host-built arrays arrive as TWO int32 uploads (tiny device_puts
        dominate small-host dispatch): ``desc`` [3, T_bucket] rows
        (chunk token, local slot, position), ``meta`` [3B + pack width]
        = new_len | sample_idx | sample_mask | pack_slots, where new_len is
        the host-computed per-slot cache count after this pack (the host
        knows every count in advance). Returns (sampled [B], last_tok,
        cur_len, cache, key)."""
        b = self.B
        new_len = meta[:b]
        sample_idx = meta[b : 2 * b]
        sample_mask = meta[2 * b : 3 * b].astype(bool)
        pack_slots = meta[3 * b :]
        logits, cache = self.model.packed_step(
            params, cache, desc[0], desc[1], desc[2],
            out_rows=sample_idx, pack_slots=pack_slots,
        )
        sampled, key = self._sample_or_greedy(logits, temps, key, has_temp)
        last_tok = jnp.where(sample_mask, sampled, last_tok)
        return sampled, last_tok, new_len, cache, key

    def _admit_fn(self, params, cache, toks, slot, last_pos, last_tok,
                  cur_len, temp, key, has_temp: bool = False):
        """One fused async admission (unified mode, prompt ≤ budget): dense
        prefill + cache insert + the first token sampled on device from the
        last REAL prompt position + tick-state update, all in ONE dispatch
        that nothing waits on. The legacy path's blocking logits transfer +
        host-side sample per admission — the pipeline bubble that stalls
        every decode slot — does not exist here; the newly admitted slot
        starts decoding in the same loop iteration."""
        logits, one_cache = self.model.prefill(
            params, {"tokens": toks}, self.max_len
        )
        cache = self._insert_fn(cache, one_cache, slot)
        row = logits[0, last_pos]  # [V]
        toks1, key = self._sample_or_greedy(row[None], temp[None], key, has_temp)
        tok = toks1[0]
        last_tok = last_tok.at[slot].set(tok)
        cur_len = cur_len.at[slot].set(last_pos + 1)
        return tok, last_tok, cur_len, cache, key

    def _prefill_one(self, req: Request, slot: int, stats: Optional[ServeStats]) -> np.ndarray:
        s = len(req.prompt)
        sb = _bucket_len(s, self.max_len) if self._bucket_prefill else s
        sb = max(sb, s)
        if sb not in self._prefill_cache:
            self._prefill_cache[sb] = self.backend.jit(
                lambda p, b: self.model.prefill(p, b, self.max_len)
            )
            if stats is not None:
                stats.prefill_compiles += 1
        toks = np.zeros((1, sb), np.int32)
        toks[0, :s] = req.prompt
        logits, one_cache = self._prefill_cache[sb](
            self.params, {"tokens": self.backend.put_host(toks)}
        )
        self.cache = self._insert(self.cache, one_cache, jnp.int32(slot))
        return np.asarray(logits[0, s - 1])  # last REAL position's logits

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        """Host-side single sample (legacy prefill first-token path)."""
        if temperature <= 0:
            return int(np.argmax(logits))
        z = np.asarray(logits, np.float64) / temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _harvest(self, entry) -> None:
        """Blockingly pull one dispatch's sampled tokens and credit the
        slots' requests. Called one dispatch behind, so this host transfer
        overlaps the next dispatch's device compute. Packed entries also
        stamp first-token availability (TTFT) — the value provably exists
        on the host at harvest time."""
        kind, tok_dev, items = entry
        toks = np.asarray(tok_dev)
        now = time.perf_counter()

        def stamp(req):
            # done_at was stamped at dispatch-enqueue (counts-only
            # bookkeeping); pull it forward to when the values actually
            # reached the host so TPOT never goes negative and the final
            # chunk's device compute is not silently excluded
            if req.done_at is not None:
                req.done_at = max(req.done_at, now)

        if kind == "admit":  # fused admission: one scalar first token
            slot, req = items
            req.generated.append(int(toks))
            if req.first_token_at is None:
                req.first_token_at = now
            stamp(req)
        elif kind == "packed":  # [B] one sample per flagged slot
            for slot, req, is_first in items:
                req.generated.append(int(toks[slot]))
                if is_first and req.first_token_at is None:
                    req.first_token_at = now
                stamp(req)
        else:  # decode chunk: [n_steps, B]
            for slot, req in items:
                req.generated.extend(int(t) for t in toks[:, slot])
                stamp(req)

    def _flush_events(self):
        """Upload pending slot changes; returns this tick's [4, B] lanes."""
        if not self._dirty:
            return self._lanes_idle
        lanes = np.zeros((4, self.B), np.int32)
        # one-shot override rows: fresh numpy every flush — CPU device_put
        # of a numpy array can be zero-copy/deferred, so handing jax a live
        # staging buffer the host later mutates races the in-flight
        # dispatch (observed as override lanes reading zeros mid-run)
        lanes[0] = self._ov_mask_h
        lanes[1] = self._ov_tok_h
        lanes[2] = self._ov_len_h
        # active == DECODING: a mid-prefill slot rides decode chunks inertly
        # (no cur_len advance, last_tok preserved) until its pack completes
        lanes[3] = [
            r is not None and self.slot_fed[i] >= len(r.prompt)
            for i, r in enumerate(self.slot_req)
        ]
        self._temps = self.backend.put_host(
            np.asarray(
                [r.temperature if r is not None else 0.0 for r in self.slot_req],
                np.float32,
            )
        )
        # the overrides apply exactly once; later idle ticks reuse a cached
        # ov-zeroed copy with the same active row
        idle = lanes.copy()
        idle[:3] = 0
        self._lanes_idle = self.backend.put_host(idle)
        self._ov_mask_h[:] = False
        self._dirty = False
        return self.backend.put_host(lanes)

    # ------------------------------------------------------------------ API

    def prewarm(self, sampling: bool = False) -> None:
        """Compile every dispatch variant this engine can hit, before any
        request arrives (production serving compiles once, then serves):
        the decode-chunk scan depths up to ``max_chunk`` and — in unified
        mode — every packed T bucket up to ``prefill_budget`` plus the
        fused-admission prompt buckets. A compile landing inside a live
        arrival stream stalls every queued request's TTFT; this moves all
        of them off the serving path. ``sampling=True`` additionally
        compiles the temperature (``has_temp``) variants — greedy-only
        deployments skip them, a mixed-sampling deployment should not let
        its first temperature request pay the compile. Call on an IDLE
        engine (before serving): the dummy fused-admission dispatches
        overwrite slot 0's cache row."""
        key = self.backend.put_state(jax.random.key(0))
        temp_variants = (False, True) if sampling else (False,)
        k = 1
        while k <= self.max_chunk:
            for ht in temp_variants:
                toks, _lt, _cl, self.cache, _k = self._tick(
                    self.params, self.cache, self._last_tok, self._cur_len,
                    self._lanes_idle, self._temps, key, n_steps=k, has_temp=ht,
                )
                jax.block_until_ready(toks)
            k *= 2
        if not self.unified:
            return
        # the EXACT T-bucket ladder _bucket_tokens can produce, including
        # the doubling tail beyond _T_BUCKETS for very large budgets
        top = _bucket_tokens(self.prefill_budget)
        tb_ladder = [b for b in _T_BUCKETS if b <= top]
        b = _T_BUCKETS[-1]
        while b < top:
            b *= 2
            tb_ladder.append(b)
        for tb in tb_ladder:
            if tb in self._packed_shapes:
                continue
            # an all-padding pack: scatters dropped (pos = max_len), no
            # slot sampled, cur_len passed through unchanged
            desc = np.zeros((3, tb), np.int32)
            desc[2] = self.max_len
            meta = np.concatenate(
                [
                    self.slot_len,
                    np.zeros(2 * self.B, np.int32),
                    np.zeros(_PACK_WIDTH, np.int32),
                ]
            )
            for ht in temp_variants:
                toks, _lt, _cl, self.cache, _k = self._packed(
                    self.params, self.cache, self._last_tok,
                    self.backend.put_host(desc), self.backend.put_host(meta),
                    self.backend.put_host(np.zeros(self.B, np.float32)),
                    key, has_temp=ht,
                )
                jax.block_until_ready(toks)
            self._packed_shapes.add(tb)
        # the EXACT prompt buckets _admit_unified can produce: every power
        # of two up to the fused-tier limit, plus the max_len-capped bucket
        # a non-pow2 max_len introduces
        top_prompt = min(self.prefill_budget, self.max_len - 1)
        sizes = [top_prompt]
        b = 1
        while b <= top_prompt:
            sizes.append(b)
            b *= 2
        for sb in sorted({_bucket_len(s, self.max_len) for s in sizes}):
            if sb in self._admit_shapes:
                continue
            for ht in temp_variants:
                tok, _lt, _cl, self.cache, _k = self._admit_prog(
                    self.params, self.cache,
                    self.backend.put_host(np.zeros((1, sb), np.int32)),
                    jnp.int32(0), jnp.int32(sb - 1), self._last_tok,
                    self._cur_len, jnp.float32(0.0), key, has_temp=ht,
                )
                jax.block_until_ready(tok)
            self._admit_shapes.add(sb)

    def reset(self) -> None:
        """Return an IDLE engine to its just-constructed serving state.

        Device tick state, override staging and slot bookkeeping are
        re-zeroed; compiled programs and the (garbage-tolerant) KV cache
        survive, so re-entering a previously-built cluster mode costs no
        recompiles and no cache realloc — the warm half of the paper's
        cheap CSR-write reconfiguration. Refuses to reset mid-flight."""
        assert all(r is None for r in self.slot_req), "reset() on a busy engine"
        self.slot_len[:] = 0
        self.slot_fed[:] = 0
        self.waiting.clear()
        self.finished = []
        self._prefilling.clear()
        self._done_now = []
        self.rng = np.random.default_rng(self.seed)
        self._last_tok = self.backend.put_state(jnp.zeros(self.B, jnp.int32))
        self._cur_len = self.backend.put_state(jnp.zeros(self.B, jnp.int32))
        self._rng_key = self.backend.put_state(jax.random.key(self.seed))
        self._lanes_idle = self.backend.put_state(jnp.zeros((4, self.B), jnp.int32))
        self._temps = self.backend.put_state(jnp.zeros(self.B, jnp.float32))
        self._ov_mask_h[:] = False
        self._ov_tok_h[:] = 0
        self._ov_len_h[:] = 0
        self._dirty = False

    def submit(self, req: Request) -> None:
        assert len(req.prompt) < self.max_len, (len(req.prompt), self.max_len)
        req.submitted_at = time.perf_counter()
        self.waiting.append(req)

    def _finish(self, req: Request, slot: int, stats: Optional[ServeStats]) -> None:
        req.done_at = time.perf_counter()
        self.finished.append(req)
        self._done_now.append(req)
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        if stats is not None:
            stats.total_requests += 1
        self._dirty = True

    def _admit(self, stats: Optional[ServeStats] = None) -> None:
        """Legacy admission: synchronous B=1 prefill + cache insert."""
        for slot in range(self.B):
            while self.slot_req[slot] is None and self.waiting:
                req = self.waiting.popleft()
                last_logits = self._prefill_one(req, slot, stats)
                tok = self._sample(last_logits, req.temperature)
                req.generated.append(tok)
                req.n_generated = len(req.generated)
                req.first_token_at = time.perf_counter()
                if req.n_generated >= req.max_new:
                    # nothing left to decode (max_new=1): finish without
                    # ever occupying the slot
                    req.done_at = req.first_token_at
                    self.finished.append(req)
                    self._done_now.append(req)
                    if stats is not None:
                        stats.total_requests += 1
                    continue
                self.slot_req[slot] = req
                self.slot_len[slot] = len(req.prompt)
                self.slot_fed[slot] = len(req.prompt)
                self._ov_mask_h[slot] = True
                self._ov_tok_h[slot] = tok
                self._ov_len_h[slot] = len(req.prompt)
                self._dirty = True

    def _admit_unified(self, stats, pending: deque) -> None:
        """Unified admission — two tiers, neither of which ever blocks the
        host or stalls a decode slot:

        * prompt ≤ ``prefill_budget``: ONE fused async dispatch (dense
          prefill + insert + device-side first-token sample); the slot is
          decoding by the next dispatch in the same loop iteration.
        * longer prompts: bound to the slot and fed as ragged packed
          chunks of ≤ budget tokens per tick (Sarathi-style), so a long
          admission costs each tick only one bounded pack.
        """
        for slot in range(self.B):
            while self.slot_req[slot] is None and self.waiting:
                req = self.waiting.popleft()
                s = len(req.prompt)
                self.slot_req[slot] = req
                self._dirty = True
                if s > self.prefill_budget:  # chunked ragged tier
                    self.slot_len[slot] = 0
                    self.slot_fed[slot] = 0
                    self._prefilling.append(slot)
                    continue
                sb = _bucket_len(s, self.max_len) if self._bucket_prefill else s
                if sb not in self._admit_shapes:
                    self._admit_shapes.add(sb)
                    if stats is not None:
                        stats.prefill_compiles += 1
                toks = np.zeros((1, sb), np.int32)
                toks[0, :s] = req.prompt
                tok, self._last_tok, self._cur_len, self.cache, self._rng_key = (
                    self._admit_prog(
                        self.params, self.cache, self.backend.put_host(toks),
                        jnp.int32(slot), jnp.int32(s - 1), self._last_tok,
                        self._cur_len,
                        jnp.float32(req.temperature), self._rng_key,
                        has_temp=req.temperature > 0,
                    )
                )
                self.slot_len[slot] = s
                self.slot_fed[slot] = s
                req.n_generated += 1  # first token (in flight; counts-only
                pending.append(("admit", tok, (slot, req)))
                if req.n_generated >= req.max_new:  # bookkeeping, as ever)
                    self._finish(req, slot, stats)

    # ------------------------------------------------------------ tick paths

    def _packed_tick(self, stats: ServeStats, pending: deque) -> None:
        """Build and dispatch one ragged prefill pack: up to
        ``prefill_budget`` prompt tokens (FCFS across the admitting slots),
        padded to a T bucket. Decode slots are untouched here — the run
        loop rides a fused decode chunk alongside every pack, so admission
        work and decode progress share each loop iteration instead of
        queueing behind each other."""
        entries: list[tuple[int, int, int]] = []  # (token, LOCAL slot, pos)
        sample_idx = np.zeros(self.B, np.int32)
        sample_mask = np.zeros(self.B, bool)
        # the pack spans at most _PACK_WIDTH admitting slots: attention work
        # (and the compile count — one variant) scales with the pack, not
        # the slot pool; later admissions simply join the next tick's pack
        pack_slots = np.zeros(_PACK_WIDTH, np.int32)
        budget = self.prefill_budget
        completed: list[int] = []
        for local, i in enumerate(self._prefilling[:_PACK_WIDTH]):
            if budget <= 0:
                break
            pack_slots[local] = i
            req = self.slot_req[i]
            fed = int(self.slot_fed[i])
            n = min(budget, len(req.prompt) - fed)
            budget -= n
            for j in range(n):
                entries.append((int(req.prompt[fed + j]), local, fed + j))
            self.slot_fed[i] = fed + n
            self.slot_len[i] = fed + n
            if fed + n == len(req.prompt):
                sample_idx[i] = len(entries) - 1  # the final prompt token
                sample_mask[i] = True
                completed.append(i)
                self._prefilling.remove(i)
                self._dirty = True  # becomes an active decoder
        tb = _bucket_tokens(len(entries))
        if tb not in self._packed_shapes:
            self._packed_shapes.add(tb)
            stats.prefill_compiles += 1
        # TWO combined uploads, built fresh every tick (CPU device_put can
        # be zero-copy, so jax must never see a buffer the host mutates
        # later). Padding tokens scatter out of bounds (dropped) and attend
        # slot 0 with an all-valid mask; their output rows are never sampled
        desc = np.zeros((3, tb), np.int32)
        desc[2] = self.max_len
        for t, (tok, sl, pos) in enumerate(entries):
            desc[0, t] = tok
            desc[1, t] = sl
            desc[2, t] = pos
        meta = np.concatenate(
            [self.slot_len, sample_idx, sample_mask.astype(np.int32), pack_slots]
        )
        temps = np.asarray(
            [r.temperature if r is not None else 0.0 for r in self.slot_req],
            np.float32,
        )
        has_temp = any(
            self.slot_req[i].temperature > 0 for i in completed
        )

        toks, self._last_tok, self._cur_len, self.cache, self._rng_key = (
            self._packed(
                self.params, self.cache, self._last_tok,
                self.backend.put_host(desc), self.backend.put_host(meta),
                self.backend.put_host(temps),
                self._rng_key, has_temp=has_temp,
            )
        )
        stats.ticks += 1

        if completed:
            items = []
            for i in completed:
                req = self.slot_req[i]
                req.n_generated += 1  # the request's first token (not counted
                items.append((i, req, True))  # in total_tokens, like legacy)
            pending.append(("packed", toks, items))
            for i in completed:
                req = self.slot_req[i]
                # no capacity check: admission guarantees prompt < max_len,
                # so one decode write at position len(prompt) always fits
                if req.n_generated >= req.max_new:
                    self._finish(req, i, stats)

    def _chunk_tick(self, stats: ServeStats, pending: deque, active: list[int]) -> None:
        """One fused multi-step decode chunk: as long as no active slot can
        finish inside the chunk, k decode steps are one dispatch (bucketed
        to powers of two ≤ ``max_chunk`` so few tick variants compile)."""
        rem = min(
            min(
                self.slot_req[i].max_new - self.slot_req[i].n_generated,
                self.max_len - 1 - int(self.slot_len[i]),
            )
            for i in active
        )
        cap = max(1, min(rem, self.max_chunk))
        k = 1
        while k * 2 <= cap:
            k *= 2
        has_temp = any(self.slot_req[i].temperature > 0 for i in active)
        lanes = self._flush_events()
        toks, self._last_tok, self._cur_len, self.cache, self._rng_key = (
            self._tick(
                self.params, self.cache, self._last_tok, self._cur_len,
                lanes, self._temps, self._rng_key, n_steps=k,
                has_temp=has_temp,
            )
        )
        stats.ticks += k
        pending.append(("chunk", toks, [(i, self.slot_req[i]) for i in active]))
        # bookkeeping needs only COUNTS — token values are harvested a
        # chunk later, overlapping this chunk's device compute
        for i in active:
            req = self.slot_req[i]
            self.slot_len[i] += k
            req.n_generated += k
            stats.total_tokens += k
            if req.n_generated >= req.max_new or self.slot_len[i] + 1 >= self.max_len:
                self._finish(req, i, stats)

    # ------------------------------------------------------------------- run

    def run(self, arrivals=None) -> ServeStats:
        """Drain all submitted requests; returns throughput + latency stats.

        ``arrivals`` optionally simulates an open-loop request stream: an
        iterable of ``(t_offset_seconds, Request)`` submitted once the run
        clock passes each offset (mixed-arrival benchmarking)."""
        stats = ServeStats()
        self._done_now = []
        t0 = time.perf_counter()
        arr: deque = deque(
            sorted(arrivals, key=lambda a: a[0]) if arrivals else ()
        )
        pending: deque = deque()
        while True:
            now = time.perf_counter() - t0
            while arr and arr[0][0] <= now:
                t_off, req = arr.popleft()
                self.submit(req)
                # the TTFT clock starts at the SCHEDULED arrival, not at
                # whenever the loop got around to polling the deque —
                # otherwise time spent inside a blocking dispatch hides
                # queueing delay from the latency stats
                req.submitted_at = t0 + t_off
            if not (
                any(r is not None for r in self.slot_req) or self.waiting or arr
            ):
                break
            if self.unified:
                self._admit_unified(stats, pending)
            else:
                self._admit(stats)
            active = [i for i, r in enumerate(self.slot_req) if r is not None]
            if not active:
                if arr:  # idle until the next scheduled arrival
                    wait = arr[0][0] - (time.perf_counter() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.001))
                continue
            if self.unified and self._prefilling:
                # merge mode: one ragged prefill pack, and — in the same
                # loop iteration — a fused decode chunk for every decoding
                # slot (including one whose prompt just completed in this
                # very pack). Admission never stalls decode.
                self._packed_tick(stats, pending)
                decoding = [
                    i for i, r in enumerate(self.slot_req)
                    if r is not None and self.slot_fed[i] >= len(r.prompt)
                ]
                if decoding:
                    self._chunk_tick(stats, pending, decoding)
            else:
                self._chunk_tick(stats, pending, active)
            while len(pending) > 1:
                self._harvest(pending.popleft())
        while pending:
            self._harvest(pending.popleft())
        stats.wall_seconds = time.perf_counter() - t0
        for req in self._done_now:
            if req.first_token_at is not None:
                stats.ttfts.append(req.first_token_at - req.submitted_at)
                if req.done_at is not None and req.n_generated >= 2:
                    stats.tpots.append(
                        max(req.done_at - req.first_token_at, 0.0)
                        / (req.n_generated - 1)
                    )
        return stats
