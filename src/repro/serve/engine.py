"""Batched serving engine with continuous batching (slot-based).

A fixed pool of ``batch_slots`` cache slots; requests are admitted into free
slots via single-sequence prefill (scattered into the batched cache at the
slot index), and every engine tick advances ALL active slots one token with
one jitted fused tick (per-slot ``cur_len`` vector — the decode paths mask
per-slot). Finished slots free immediately and the next waiting request is
admitted: classic continuous batching, sized down.

Hot-path structure (what makes a serving token cheap here):

* ONE jitted dispatch per CHUNK of ticks: decode + device-side sampling
  (greedy argmax / gumbel-max per-slot temperature over the [B, V] logits)
  + the per-slot ``cur_len`` advance are fused and scanned ``k`` steps
  deep, where ``k`` (bucketed to {1,2,4,8}) is the largest chunk in which
  no slot can finish — termination depends only on counts, so the host
  knows ``k`` in advance and chunking is output-invariant. A steady-state
  chunk ships zero host arrays to the device and no [B, V] logits to the
  host, and the per-dispatch overhead amortizes ``k``-fold;
* tick state (last tokens, cur_len, PRNG key) is device-resident; host
  bookkeeping tracks counts only and harvests tick t-1's token values while
  tick t computes (termination depends on counts, never on token values);
  admission/finish events update the device state through small "override
  lane" arrays that are cached device zeros between events;
* the decode cache is donated to each chunk — the engine never holds two
  copies of the KV cache;
* prefill lengths are bucketed to powers of two for attention-only archs
  (causal masking + per-slot cur_len make right-padding invisible), so a
  stream of ragged prompts hits a handful of compiled prefills instead of
  one per distinct length. SSM/hybrid archs keep exact-length prefill —
  right-padding would pollute the recurrent state.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    temperature: float = 0.0
    generated: list[int] = field(default_factory=list)
    n_generated: int = 0  # tokens sampled so far (values may still be in flight)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


@dataclass
class ServeStats:
    total_tokens: int = 0
    total_requests: int = 0
    wall_seconds: float = 0.0
    ticks: int = 0
    prefill_compiles: int = 0

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_seconds, 1e-9)


def _bucket_len(s: int, max_len: int) -> int:
    """Next power of two ≥ s, capped at max_len (prefill compile buckets)."""
    b = 1
    while b < s:
        b *= 2
    return min(b, max_len) if b > s else b


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_len = np.zeros(batch_slots, np.int32)  # host mirror (counts)
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self.rng = np.random.default_rng(seed)
        self._prefill_cache = {}
        # the cache is donated through both consumers — the engine never
        # holds two copies of the KV cache
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))
        self._tick = jax.jit(
            self._tick_fn, donate_argnums=(1,), static_argnames=("n_steps",)
        )
        # device-resident tick state: sampled tokens, per-slot lengths, PRNG
        self._last_tok = jnp.zeros(batch_slots, jnp.int32)
        self._cur_len = jnp.zeros(batch_slots, jnp.int32)
        self._rng_key = jax.random.key(seed)
        # event-driven device arrays (re-uploaded only when slots change)
        self._active = jnp.zeros(batch_slots, bool)
        self._temps = jnp.zeros(batch_slots, jnp.float32)
        self._zero_mask = jnp.zeros(batch_slots, bool)
        self._zero_i32 = jnp.zeros(batch_slots, jnp.int32)
        self._ov_mask_h = np.zeros(batch_slots, bool)  # staged override lanes
        self._ov_tok_h = np.zeros(batch_slots, np.int32)
        self._ov_len_h = np.zeros(batch_slots, np.int32)
        self._dirty = False  # overrides/active/temps pending upload
        # right-padded prefill is only safe when nothing recurrent sees the
        # pad tokens: attention masks them (causal + cur_len), SSM states don't
        self._bucket_prefill = model.cfg.family in ("dense", "moe")

    # ------------------------------------------------------------ internals

    @staticmethod
    def _insert_fn(cache, one_cache, slot):
        """Scatter a B=1 prefilled cache into batched cache at ``slot``."""

        def leaf(c, o):
            return jax.lax.dynamic_update_slice_in_dim(c, o.astype(c.dtype), slot, axis=1)

        return jax.tree.map(leaf, cache, one_cache)

    @staticmethod
    def _sample_batch_fn(logits, temps, key):
        """One device-side sample for every slot. logits: [B, V] (any float
        dtype), temps: [B] f32. Greedy slots take argmax; temperature slots
        take gumbel-max (categorical) at their own temperature."""
        logits = logits.astype(jnp.float32)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gumbel = jax.random.gumbel(key, logits.shape, jnp.float32)
        scaled = logits / jnp.maximum(temps, 1e-6)[:, None] + gumbel
        sampled = jnp.argmax(scaled, axis=-1).astype(jnp.int32)
        return jnp.where(temps > 0, sampled, greedy)

    def _tick_fn(self, params, cache, last_tok, cur_len, ov_mask, ov_tok, ov_len,
                 active, temps, key, n_steps: int = 1):
        """One fused engine dispatch: fold the admission override lanes into
        the device state, then run ``n_steps`` decode+sample steps as a
        device-side scan. Everything stays on device; the per-dispatch
        overhead (and, without donation, the KV-cache copy) amortizes over
        the whole chunk. Returns toks [n_steps, B].

        Chunking never changes results: the host only chooses ``n_steps``
        such that no slot can finish (and hence no admission can land)
        inside the chunk, and the PRNG split chain per step is identical to
        n_steps=1 dispatches.
        """
        last_tok = jnp.where(ov_mask, ov_tok, last_tok)
        cur_len = jnp.where(ov_mask, ov_len, cur_len)
        adv = active.astype(jnp.int32)

        def step(carry, _):
            tok, cl, cache, key = carry
            logits, cache = self.model.decode_step(
                params, cache, {"tokens": tok[:, None]}, cl
            )
            key, sub = jax.random.split(key)
            tok = self._sample_batch_fn(logits[:, 0], temps, sub)
            return (tok, cl + adv, cache, key), tok

        (last_tok, cur_len, cache, key), toks = jax.lax.scan(
            step, (last_tok, cur_len, cache, key), None, length=n_steps
        )
        return toks, last_tok, cur_len, cache, key

    def _prefill_one(self, req: Request, slot: int, stats: Optional[ServeStats]) -> np.ndarray:
        s = len(req.prompt)
        sb = _bucket_len(s, self.max_len) if self._bucket_prefill else s
        sb = max(sb, s)
        if sb not in self._prefill_cache:
            self._prefill_cache[sb] = jax.jit(
                lambda p, b: self.model.prefill(p, b, self.max_len)
            )
            if stats is not None:
                stats.prefill_compiles += 1
        toks = np.zeros(sb, np.int32)
        toks[:s] = req.prompt
        logits, one_cache = self._prefill_cache[sb](
            self.params, {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        )
        self.cache = self._insert(self.cache, one_cache, jnp.int32(slot))
        return np.asarray(logits[0, s - 1])  # last REAL position's logits

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        """Host-side single sample (prefill first-token path)."""
        if temperature <= 0:
            return int(np.argmax(logits))
        z = np.asarray(logits, np.float64) / temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _harvest(self, entry) -> None:
        """Blockingly pull one chunk's sampled tokens and credit the slots'
        requests. Called one chunk behind the dispatch, so this host transfer
        overlaps the next chunk's device compute."""
        tok_dev, items = entry
        toks = np.asarray(tok_dev)  # [n_steps, B]
        for slot, req in items:
            req.generated.extend(int(t) for t in toks[:, slot])

    def _flush_events(self):
        """Upload pending slot changes; returns this tick's override lanes."""
        if not self._dirty:
            return self._zero_mask, self._zero_i32, self._zero_i32
        self._active = jnp.asarray(
            np.asarray([r is not None for r in self.slot_req]), bool
        )
        self._temps = jnp.asarray(
            np.asarray(
                [r.temperature if r is not None else 0.0 for r in self.slot_req],
                np.float32,
            )
        )
        # hand jax PRIVATE copies: CPU device_put of a numpy array can be
        # zero-copy/deferred, so converting the live staging arrays and then
        # mutating them below (or at the next admission) races the in-flight
        # dispatch — observed as override lanes reading zeros mid-run
        ov = (
            jnp.asarray(self._ov_mask_h.copy()),
            jnp.asarray(self._ov_tok_h.copy()),
            jnp.asarray(self._ov_len_h.copy()),
        )
        self._ov_mask_h[:] = False
        self._dirty = False
        return ov

    # ------------------------------------------------------------------ API

    def submit(self, req: Request) -> None:
        req.submitted_at = time.perf_counter()
        self.waiting.append(req)

    def _admit(self, stats: Optional[ServeStats] = None) -> None:
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.waiting:
                req = self.waiting.pop(0)
                last_logits = self._prefill_one(req, slot, stats)
                tok = self._sample(last_logits, req.temperature)
                req.generated.append(tok)
                req.n_generated = len(req.generated)
                req.first_token_at = time.perf_counter()
                self.slot_req[slot] = req
                self.slot_len[slot] = len(req.prompt)
                self._ov_mask_h[slot] = True
                self._ov_tok_h[slot] = tok
                self._ov_len_h[slot] = len(req.prompt)
                self._dirty = True

    def run(self) -> ServeStats:
        """Drain all submitted requests; returns throughput stats."""
        stats = ServeStats()
        t0 = time.perf_counter()
        self._admit(stats)
        pending: deque = deque()
        while any(r is not None for r in self.slot_req) or self.waiting:
            active = [i for i, r in enumerate(self.slot_req) if r is not None]
            if not active:
                self._admit(stats)
                continue
            # multi-step chunk: as long as no active slot can finish inside
            # the chunk, k decode steps are one dispatch (bucketed to powers
            # of two so at most 4 tick variants ever compile)
            rem = min(
                min(
                    self.slot_req[i].max_new - self.slot_req[i].n_generated,
                    self.max_len - 1 - int(self.slot_len[i]),
                )
                for i in active
            )
            k = 8 if rem >= 8 else (4 if rem >= 4 else (2 if rem >= 2 else 1))
            ov_mask, ov_tok, ov_len = self._flush_events()
            toks, self._last_tok, self._cur_len, self.cache, self._rng_key = (
                self._tick(
                    self.params, self.cache, self._last_tok, self._cur_len,
                    ov_mask, ov_tok, ov_len, self._active, self._temps,
                    self._rng_key, n_steps=k,
                )
            )
            stats.ticks += k
            pending.append((toks, [(i, self.slot_req[i]) for i in active]))
            # bookkeeping needs only COUNTS — token values are harvested a
            # chunk later, overlapping this chunk's device compute
            for i in active:
                req = self.slot_req[i]
                self.slot_len[i] += k
                req.n_generated += k
                stats.total_tokens += k
                full = self.slot_len[i] + 1 >= self.max_len
                if req.n_generated >= req.max_new or full:
                    req.done_at = time.perf_counter()
                    self.finished.append(req)
                    self.slot_req[i] = None
                    self.slot_len[i] = 0
                    stats.total_requests += 1
                    self._dirty = True
            if len(pending) > 1:
                self._harvest(pending.popleft())
            self._admit(stats)
        while pending:
            self._harvest(pending.popleft())
        stats.wall_seconds = time.perf_counter() - t0
        return stats
