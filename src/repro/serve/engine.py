"""Batched serving engine with continuous batching (slot-based) and a
unified ragged prefill+decode dispatch (merge-mode serving).

A fixed pool of ``batch_slots`` cache slots; requests are admitted into free
slots and every engine tick advances work with one jitted fused dispatch.
Two dispatch shapes exist, chosen per tick from the workload mix — the
temporal analogue of Spatzformer's split/merge reconfiguration:

* **packed tick** (merge mode — any admission in flight): a flat
  ``[T_bucket]`` token batch packs up to ``prefill_budget`` prompt tokens
  from the admitting requests (Sarathi-style chunked prefill) through
  ``LM.packed_step`` → the ragged varlen attention kernel with per-token
  ``(slot, position)`` descriptors; new K/V are scattered at (slot, pos) in
  one fused O(T) write — no B=1 prefill, no full-cache insert copy, no
  blocking logits transfer + host sample per admission (a completing
  chunk's first token is sampled on device from its final prompt row). In
  the SAME loop iteration every decoding slot advances through a fused
  decode chunk, so decode NEVER stalls behind an admission. A handful of T
  buckets replaces the per-prompt-length prefill compile zoo.
* **decode chunk** (split mode — steady state, no admission work): decode +
  device-side sampling + the per-slot ``cur_len`` advance fused and
  scanned ``k`` steps deep, where ``k`` (bucketed to powers of two up to
  ``max_chunk``) is the largest chunk in which no slot can finish —
  count-based termination depends only on counts, so the host knows ``k``
  in advance and chunking is output-invariant. A steady-state chunk ships
  zero host arrays to the device, so merge-mode reconfigurability costs
  the split-mode steady state nothing (the paper's C3 parity).

Sampling is request-level configuration (:mod:`repro.serve.sampling`):
every request carries a frozen :class:`SamplingParams` (temperature,
top-k, top-p, seed, max_new, stop ids, logit bias); the per-slot parameter
rows live device-resident and are re-uploaded only on slot-change events,
and each dispatch runs one of a finite zoo of compiled sampler variants
(``smode``) chosen per tick by a host ``if`` over the active slots. Every
draw is keyed ``fold_in(key(request_seed), position)`` — no shared PRNG
chain — so seeded streams are reproducible across chunk sizes, across the
legacy/unified engines, and across cluster modes, and a neighbour slot
being admitted or cancelled never perturbs anyone else's tokens. The
all-greedy fast path (smode 0) skips threefry/bias/sort entirely and is
bit-identical to the pre-SamplingParams engine.

Speculative decoding (``speculate=``, :mod:`repro.serve.speculate`) rides
the same packed ragged dispatch: a host-side drafter (n-gram prompt
lookup, or a small draft model) proposes up to ``k`` tokens per decoding
slot, ONE packed dispatch scores all ``k+1`` positions per slot
(scattering the proposals' K/V at their hypothetical positions), and the
seeded fold_in sampler draws the target token at every position in the
same dispatch. Because every draw is a pure function of (context, seed,
position), acceptance is plain exact-match — a speculated stream is
bit-identical to its non-speculated twin BY CONSTRUCTION, not merely in
distribution. Rejected positions need no rollback: host bookkeeping never
advanced past the committed prefix, and stale K/V beyond ``cur_len`` is
masked by the position predicate until overwritten (the paged engine
releases nothing — block tables reserve the worst case at admission).

Request lifecycle: :meth:`ServeEngine.submit` returns a
:class:`RequestHandle` — an incremental token iterator with ``cancel()``;
``run()`` is rebuilt on the same per-iteration step machinery
(:meth:`ServeEngine.step`). Stop tokens are detected at harvest time (the
host-side value crossing that already exists), so count-based chunk
sizing — and with it chunking invariance — survives value-dependent
termination at the cost of at most one discarded in-flight chunk.

Shared hot-path structure:

* every host→device crossing (params/cache placement, tick state, the
  per-tick staging uploads, program compilation) goes through a pluggable
  :mod:`repro.serve.backend` — the same loop serves the default device, a
  pinned split-mode replica, or a tensor-parallel mesh (merge-mode
  cluster serving, :mod:`repro.serve.cluster`);
* tick state (last tokens, cur_len) is device-resident; host bookkeeping
  tracks counts only and harvests tick t-1's token values while tick t
  computes (count-based termination never waits on token values);
* the decode cache is donated through every dispatch — the engine never
  holds two copies of the KV cache;
* MLA archs ride the same packed dispatch with a latent cache: one
  compressed ``c_kv`` row (+ decoupled-RoPE key) per position instead of
  per-head K/V, attention as the latent-MQA specialization of the ragged
  kernel, the scatter writing one latent row per token;
* SSM archs serve with NO positional cache at all: per-slot
  ``(conv_state, ssd_state)``, chunked prefill as single-slot
  state-passing scans through the same T-bucket ladder, constant
  resident bytes (paged/quantized/speculate are typed refusals);
* hybrid archs (attention + SSM interleaved) keep the legacy
  per-request tier, flagged by a one-time RuntimeWarning per process.
"""

from __future__ import annotations

import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from repro.models.quant import quantize_params
from repro.serve.backend import PlacementBackend, resolve_backend
from repro.serve.kv_pool import BlockPool, blocks_for
from repro.serve.prefix_cache import RadixPrefixCache
from repro.serve.sampling import (
    SMODE_GREEDY,
    SamplingParams,
    bias_row,
    fused_sample,
    param_rows,
    spec_verify,
)
from repro.serve.speculate import SpeculateConfig, build_drafter


class AdmissionRejected(ValueError):
    """Typed submit-time rejection: the request was never queued.

    ``reason`` distinguishes the four admission outcomes so clients and
    the cluster router can react differently to each:

    * ``"infeasible"``   — could never be served (e.g. needs more KV
      blocks than the paged pool holds); retrying is pointless.
    * ``"shed_deadline"`` — predicted TTFT exceeds the request's
      ``deadline_s``; admitting it would only make it miss late.
    * ``"rate_limited"``  — the tenant's token bucket is empty; retry
      after backoff.
    * ``"queue_full"``    — bounded-queue backpressure; retry after
      backoff or raise the request's priority.

    Subclasses :class:`ValueError` so pre-existing callers that caught
    the bare infeasible-paged-request raise keep working.
    """

    REASONS = ("infeasible", "shed_deadline", "rate_limited", "queue_full")

    def __init__(self, reason: str, detail: str = "") -> None:
        assert reason in self.REASONS, reason
        self.reason = reason
        super().__init__(f"{reason}: {detail}" if detail else reason)


@dataclass(eq=False)
class Request:
    """One serving request. Identity-based equality/hash: a Request is a
    live lifecycle object (queues, slot tables, handle maps key on it),
    not a value.

    Sampling/termination configuration lives in ``params``
    (:class:`SamplingParams`). The bare ``max_new=``/``temperature=``
    kwargs are the pre-SamplingParams surface, kept as deprecation shims:
    they build (and stay mirrored from) ``params`` so old callers and the
    router's cost model keep working."""

    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: Optional[int] = None  # deprecated: use params=SamplingParams(...)
    temperature: Optional[float] = None  # deprecated: use params=...
    params: Optional[SamplingParams] = None
    tenant: Optional[str] = None  # cluster router affinity key (optional)
    model: Optional[str] = None  # heterogeneous cluster: pin to a named model
    deadline_s: Optional[float] = None  # TTFT budget: shed if predicted to miss
    generated: list[int] = field(default_factory=list)
    n_generated: int = 0  # tokens sampled so far (values may still be in flight)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    finish_reason: Optional[str] = None  # "length"|"stop"|"cancelled"|"rejected"
    reject_reason: Optional[str] = None  # AdmissionRejected.reason when rejected

    def __post_init__(self):
        explicit = (
            self.params is not None
            or self.max_new is not None
            or self.temperature is not None
        )
        if self.params is None:
            if self.max_new is not None or self.temperature is not None:
                warnings.warn(
                    "Request(max_new=..., temperature=...) is deprecated; "
                    "pass params=SamplingParams(max_new=..., temperature=...)",
                    DeprecationWarning,
                    stacklevel=3,
                )
            self.params = SamplingParams(
                temperature=self.temperature if self.temperature is not None else 0.0,
                max_new=self.max_new if self.max_new is not None else 16,
            )
        elif self.max_new is not None or self.temperature is not None:
            raise ValueError("pass either params= or the legacy kwargs, not both")
        # whether the caller configured sampling at all: a cluster's
        # per-tenant default only fills requests that did not
        self._explicit_params = explicit
        self._sync_mirrors()

    def _sync_mirrors(self) -> None:
        self.max_new = self.params.max_new
        self.temperature = self.params.temperature

    def apply_default_params(self, params: SamplingParams) -> None:
        """Fill in a default ``SamplingParams`` (e.g. a cluster's per-tenant
        default) — a no-op when the caller configured the request."""
        if self._explicit_params:
            return
        self.params = params
        self._explicit_params = True
        self._sync_mirrors()

    @property
    def complete(self) -> bool:
        """Finished AND every token value harvested to the host."""
        return (
            self.finish_reason is not None
            and len(self.generated) >= self.n_generated
        )


class RequestHandle:
    """Streaming view of one submitted request: an incremental token
    iterator plus ``cancel()``. Tokens become visible as the engine
    harvests them (one dispatch behind the device, by design); iterating
    from the submitting thread *drives* the engine (``step()``) when
    nothing else is, and politely polls when a controller thread (cluster
    split mode) owns the serving loop."""

    def __init__(self, request: Request, owner) -> None:
        self.request = request
        self._owner = owner  # ServeEngine or ServeCluster
        self.replica = None  # split-mode routing target (set by ServeCluster)

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def params(self) -> SamplingParams:
        return self.request.params

    @property
    def done(self) -> bool:
        return self.request.complete

    @property
    def finish_reason(self) -> Optional[str]:
        return self.request.finish_reason

    def cancel(self) -> None:
        """Abort the request: dequeue it if waiting, free its slot if
        decoding. In-flight token values are discarded; no other slot's
        output is perturbed (sampling keys are per-request, never shared)."""
        self._owner.cancel(self.request)

    def tokens(self) -> Iterator[int]:
        """Yield generated token ids incrementally until the request
        finishes (length/stop) or is cancelled."""
        i = 0
        while True:
            if i < len(self.request.generated):
                yield self.request.generated[i]
                i += 1
            elif self.done:
                # completion may have been a side effect of a batch-mate's
                # streaming — give the owner its bookkeeping hook (the
                # cluster prunes its request→engine ownership map here)
                done_hook = getattr(self._owner, "_handle_done", None)
                if done_hook is not None:
                    done_hook(self.request)
                return
            else:
                self._owner._handle_pump(self.request)

    __iter__ = tokens

    def result(self) -> list[int]:
        """Block (driving the engine if needed) until complete; returns the
        full generated token list."""
        for _ in self.tokens():
            pass
        return self.request.generated


def percentile(xs: list[float], q: float) -> float:
    """Latency percentile with the empty-sample sentinel (0.0) — shared by
    ServeStats and the cluster's ClusterStats so the two never diverge."""
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclass
class ServeStats:
    total_tokens: int = 0
    total_requests: int = 0
    cancelled: int = 0  # requests aborted via handle.cancel()
    wall_seconds: float = 0.0
    ticks: int = 0
    prefill_compiles: int = 0
    # speculative decoding telemetry (0 unless the engine speculates):
    # proposed = draft tokens dispatched to verify, accepted = drafts that
    # matched their seeded target draw (the bonus token is neither)
    spec_proposed: int = 0
    spec_accepted: int = 0
    spec_ticks: int = 0
    # backpressure / robustness telemetry: queue high-water mark and paged
    # allocation failures are engine-level (filled by run()); shed /
    # rejected / re-homed counts only become nonzero at the cluster layer,
    # which owns admission control and failure recovery
    queue_peak: int = 0
    alloc_failures: int = 0
    # peak resident KV bytes observed over the run, dtype-aware (an int8
    # cache reports ~4x fewer bytes than f32 for the same positions):
    # dense = the constant cache allocation, paged = peak used_blocks x
    # measured bytes_per_block — the number capacity planning should read
    kv_bytes_resident: int = 0
    shed: int = 0  # deadline-based load shedding (shed_deadline)
    rejected: int = 0  # rate_limited + queue_full rejections
    rehomed: int = 0  # live requests moved off a dead replica
    # per-request latency samples for the requests finished in this run:
    # TTFT = first token available - submitted; TPOT = mean inter-token time
    ttfts: list[float] = field(default_factory=list)
    tpots: list[float] = field(default_factory=list)

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_seconds, 1e-9)

    @property
    def spec_acceptance(self) -> float:
        """Fraction of dispatched draft tokens that matched their target."""
        return self.spec_accepted / max(self.spec_proposed, 1)

    @property
    def ttft_p50(self) -> float:
        return percentile(self.ttfts, 50)

    @property
    def ttft_p99(self) -> float:
        return percentile(self.ttfts, 99)

    @property
    def tpot_p50(self) -> float:
        return percentile(self.tpots, 50)

    @property
    def tpot_p99(self) -> float:
        return percentile(self.tpots, 99)


def _norm_kv_dtype(kv_dtype):
    """Engine-level kv_dtype normalization: ``None`` means the plain
    (scale-less) cache; ``"f32"``/``"float32"`` opts into the quantized-row
    machinery with an f32 store and identity scales (the bit-identity test
    lane); ``"fp8"``/``"float8_e4m3"`` stores rows as float8_e4m3fn (same
    per-row scales, wider dynamic range than int8 at the same byte cost);
    anything else must resolve to int8."""
    if kv_dtype is None:
        return None
    if isinstance(kv_dtype, str):
        if kv_dtype in ("f32", "float32"):
            return jnp.float32
        if kv_dtype in ("f8", "fp8", "float8", "float8_e4m3", "float8_e4m3fn"):
            return jnp.float8_e4m3fn
        kv_dtype = "int8" if kv_dtype == "i8" else kv_dtype
    try:
        dt = jnp.dtype(kv_dtype)
    except TypeError as e:
        raise ValueError(f"unsupported kv_dtype: {kv_dtype!r}") from e
    if dt not in (
        jnp.dtype(jnp.float32),
        jnp.dtype(jnp.int8),
        jnp.dtype(jnp.float8_e4m3fn),
    ):
        raise ValueError(f"unsupported kv_dtype: {kv_dtype!r}")
    return dt


def _bucket_len(s: int, max_len: int) -> int:
    """Next power of two ≥ s, capped at max_len (prefill compile buckets)."""
    b = 1
    while b < s:
        b *= 2
    return min(b, max_len) if b > s else b


# packed-tick size buckets: a 1.5x ladder keeps padding waste ≤ ~33% while a
# handful of compiled T variants covers every workload mix
_T_BUCKETS = (8, 16, 24, 32, 48, 64, 96, 128)

# max admitting slots per pack (the P in the sub-cache gather); admissions
# beyond it join the next tick's pack
_PACK_WIDTH = 2

# family tags already warned about riding the legacy tier (once per process)
_LEGACY_WARNED: set = set()


def _warn_legacy_tier(tag: str) -> None:
    """One-time heads-up that a family serves on the slow legacy tier:
    blocking B=1 prefill + full-cache insert + host-side first-token sample
    per admission, no packed ragged dispatch. Correct, but every admission
    stalls all decode slots."""
    if tag in _LEGACY_WARNED:
        return
    _LEGACY_WARNED.add(tag)
    warnings.warn(
        f"family {tag!r} has no packed path; serving on the legacy "
        "prefill+insert tier (each admission blocks the decode slots)",
        RuntimeWarning,
        stacklevel=3,
    )


def _bucket_tokens(t: int) -> int:
    for b in _T_BUCKETS:
        if t <= b:
            return b
    b = _T_BUCKETS[-1]
    while b < t:
        b *= 2
    return b


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
        unified: Optional[bool] = None,
        prefill_budget: int = 64,
        max_chunk: int = 8,
        backend: Optional[PlacementBackend] = None,
        kv_block_size: Optional[int] = None,
        num_blocks: Optional[int] = None,
        prefix_cache: bool = False,
        speculate=None,
        kv_dtype=None,
        weight_dtype=None,
    ):
        self.model = model
        # EVERY host→device crossing goes through the backend: the engine
        # itself is placement-agnostic (single device, pinned replica
        # device, or tensor-parallel mesh — see serve/backend.py)
        self.backend = resolve_backend(backend)
        # quantized serving knobs (both opt-in; None = today's exact path):
        # * kv_dtype: "int8" stores K/V rows quantized with per-(pos, head)
        #   f32 scales resident in the cache pytree, dequantized inside the
        #   attention kernels; "f32" keeps the full scale machinery but an
        #   f32 store (identity scales) — the bit-identity test lane. None
        #   is the plain cache: no scale leaves, byte-identical to before.
        # * weight_dtype: "int8" rewrites eligible stacked matmul weights
        #   to {"q8", "scale"} sub-dicts (repro.models.quant); consuming
        #   einsums dequantize per layer via the qweight read-through.
        self.kv_dtype = _norm_kv_dtype(kv_dtype)
        self.weight_dtype = weight_dtype
        self.quant_kv = self.kv_dtype is not None
        self.params = self.backend.put_params(
            model, quantize_params(params, weight_dtype)
        )
        self.B = batch_slots
        self.max_len = max_len
        self.seed = seed
        # unified ragged dispatch covers positional-KV attention (dense/
        # moe), the MLA compressed-latent cache, and single-slot SSM state
        # chunks; only hybrid (attention+SSM interleaved per block) keeps
        # the legacy prefill+insert path
        self.unified = model.supports_packed if unified is None else unified
        if self.unified and not model.supports_packed:
            raise ValueError(
                f"family {model.family_tag!r} has no packed path "
                "(pass unified=False to serve it on the legacy tier)"
            )
        if not self.unified:
            _warn_legacy_tier(model.family_tag)
        # recurrent-state packs are single-stream: ONE slot per pack, so
        # the whole [T] chunk is a contiguous run of that slot's positions
        # and the state-passing chunk scan applies verbatim. Attention
        # packs keep the multi-slot width.
        self._pack_width = 1 if model.cfg.family == "ssm" else _PACK_WIDTH
        # families whose admissions must ALWAYS ride the chunked packed
        # tier (never the fused prefill+insert dispatch): quantized KV
        # (the packed scatter is the one write path that quantizes rows)
        # and SSM (exact-length B=1 prefill would compile per prompt
        # length; the chunk scan reuses the T-bucket ladder instead)
        self._chunk_only_admit = (
            self.kv_dtype is not None or model.cfg.family == "ssm"
        )
        self.prefill_budget = max(int(prefill_budget), 1)
        self.max_chunk = max(int(max_chunk), 1)
        if self.quant_kv and not self.unified:
            # model.prefill builds a scale-less B=1 cache — the legacy
            # insert path cannot carry scales. Quantized KV rides the
            # packed/chunked tier exclusively (see _admit_unified).
            raise ValueError("kv_dtype requires the unified packed engine")
        # speculative decoding (serve/speculate.py): a drafter proposes up
        # to spec_k tokens per decoding slot and ONE packed verify dispatch
        # scores every (slot, offset) row; accepted prefixes commit through
        # the normal harvest path. `speculate` accepts a CLI-style string
        # ("ngram" | "draft[:<arch>]"), a SpeculateConfig, or a bound
        # Drafter instance (anything with .propose).
        self.spec: Optional[SpeculateConfig] = None
        self.drafter = None
        if speculate not in (None, False, "off"):
            if not self.unified:
                raise ValueError(
                    "speculative decoding needs the unified packed dispatch"
                )
            if model.cfg.family == "ssm":
                # attention verify rows are free to reject (stale K/V past
                # cur_len is masked); a recurrent state has no position
                # axis, so rejected draft rows would need a state rollback
                raise ValueError(
                    f"family {model.family_tag!r} cannot speculate: "
                    "rejected drafts would need recurrent-state rollback"
                )
            if hasattr(speculate, "propose"):  # a pre-built Drafter
                self.spec = SpeculateConfig(
                    mode="draft" if getattr(speculate, "name", "") == "draft"
                    else "ngram"
                )
                self.drafter = speculate
            else:
                self.spec = SpeculateConfig.coerce(speculate)
                self.drafter = build_drafter(self.spec, model, params)
            self.spec_k = min(int(self.spec.k), max(max_len // 2, 1))
            self.drafter.setup(
                self.backend, batch_slots, max_len, model.cfg.vocab_size
            )
            # per-slot acceptance EWMA drives the adaptive depth (optimistic
            # start: a fresh slot tries the full depth, misses shrink it)
            self._spec_ewma = np.ones(batch_slots)
            self._spec_shapes: set[tuple[int, int]] = set()
        # block-paged KV mode (kv_block_size set): the dense [B, S_max]
        # cache becomes a [num_blocks, block_size] pool + per-slot block
        # tables (serve/kv_pool.py). Opt-in — the dense path below stays
        # byte-identical for existing callers (and the gated steady bench).
        self.kv_block_size = int(kv_block_size) if kv_block_size else 0
        self.paged = bool(self.kv_block_size)
        if self.paged:
            if not self.unified:
                raise ValueError("paged KV serving requires the unified engine")
            if max_len % self.kv_block_size:
                raise ValueError(
                    f"max_len={max_len} must be a multiple of "
                    f"kv_block_size={self.kv_block_size}"
                )
            self._maxb = max_len // self.kv_block_size  # table width
            # default pool = byte parity with the dense cache; capacity
            # deployments pass more slots than the pool could worst-case
            # hold and let admission wait on pool pressure instead
            self.num_blocks = (
                int(num_blocks) if num_blocks else batch_slots * self._maxb
            )
            self.pool = BlockPool(self.num_blocks, self.kv_block_size)
            self.prefix = (
                RadixPrefixCache(self.pool, self.kv_block_size)
                if prefix_cache else None
            )
            self.cache = self.backend.put_cache(
                model,
                model.init_kv_pool(
                    self.num_blocks, self.kv_block_size, kv_dtype=self.kv_dtype
                ),
            )
            # dtype-aware byte accounting: measure ONE block's HBM weight
            # from the live pool leaves (K + V payloads + scale planes over
            # all L layers) — never assume blocks are f32
            self.pool.bytes_per_block = sum(
                int(leaf.nbytes) // self.num_blocks
                for leaf in jax.tree.leaves(self.cache)
            )
            # per-slot block lists (host) + the [B, max_blocks] device
            # table; unallocated entries hold the out-of-range sentinel
            # num_blocks, so their scatters drop and their tiles are dead
            self._slot_blocks: list[list[int]] = [[] for _ in range(batch_slots)]
            self._btab_h = np.full(
                (batch_slots, self._maxb), self.num_blocks, np.int32
            )
            self._btab = self.backend.put_host(self._btab_h.copy())
            self._btab_dirty = False
        else:
            if prefix_cache:
                raise ValueError("prefix_cache=True requires kv_block_size")
            self.pool = None
            self.prefix = None
            self.cache = self.backend.put_cache(
                model,
                model.init_cache(batch_slots, max_len, kv_dtype=self.kv_dtype),
            )
        # dense cache bytes are allocation-constant; paged residency is
        # used_blocks x bytes_per_block (see kv_bytes_resident)
        self._dense_kv_bytes = (
            0 if self.paged
            else sum(int(l.nbytes) for l in jax.tree.leaves(self.cache))
        )
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_len = np.zeros(batch_slots, np.int32)  # host mirror (counts)
        self.slot_fed = np.zeros(batch_slots, np.int32)  # prompt tokens fed
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        self.rng = np.random.default_rng(seed)
        self._prefill_cache = {}
        self._prefilling: list[int] = []  # slots mid-prefill, admission order
        self._packed_shapes: set[int] = set()  # compiled T buckets
        self._admit_shapes: set[int] = set()  # compiled fused-admission buckets
        self._done_now: list[Request] = []  # requests finished in this run()
        # streaming/cancellation plumbing: pending holds dispatched-but-
        # unharvested entries, cancels is the cross-thread abort inbox
        self._pending: deque = deque()
        self._cancels: list[Request] = []
        self._cancel_lock = threading.Lock()
        # serializes state-machine drivers: run() loop iterations, step()
        # (handle-driven streaming), and inline cancellation application —
        # a cancel that read _running=False just as run() starts blocks
        # here until the in-flight iteration finishes instead of mutating
        # the slot table underneath it. Uncontended acquire per tick is
        # noise next to a ~ms dispatch.
        self._drive_lock = threading.RLock()
        self._running = False  # a run() loop (possibly another thread) drives
        # poison pill for replica-failure recovery: a cluster that declared
        # this engine dead sets it so a stuck controller thread that later
        # resumes aborts its run() at the next iteration boundary without
        # touching state the survivors have already re-homed
        self._poisoned = False
        self._stream_stats = ServeStats()  # accumulator for step()-driven serving
        # the cache is donated through all consumers — the engine never
        # holds two copies of the KV cache
        self._insert = self.backend.jit(self._insert_fn, donate_argnums=(0,))
        self._tick = self.backend.jit(
            self._tick_fn, donate_argnums=(1,),
            static_argnames=("n_steps", "smode"),
        )
        self._packed = self.backend.jit(
            self._packed_fn, donate_argnums=(1,), static_argnames=("smode",)
        )
        self._admit_prog = self.backend.jit(
            self._admit_fn, donate_argnums=(1,), static_argnames=("smode",)
        )
        if self.paged:
            # the paged twins of _tick/_packed: identical programs with the
            # block table threaded through to the (block, offset) dispatch
            self._tick_paged = self.backend.jit(
                self._tick_paged_fn, donate_argnums=(1,),
                static_argnames=("n_steps", "smode"),
            )
            self._packed_paged = self.backend.jit(
                self._packed_paged_fn, donate_argnums=(1,),
                static_argnames=("smode",),
            )
        if self.spec is not None:
            # verify programs compile at EXACT T = B*(K+1) per depth bucket
            # (K in {1,2,4,..,spec_k}): a handful of depths, so exact shapes
            # beat ladder padding — every padded row is a wasted model+
            # sampler row on the verify hot path
            self._spec_prog = self.backend.jit(
                self._spec_fn, donate_argnums=(1,),
                static_argnames=("depth_k", "smode"),
            )
            if self.paged:
                self._spec_prog_paged = self.backend.jit(
                    self._spec_paged_fn, donate_argnums=(1,),
                    static_argnames=("depth_k", "smode"),
                )
        # the legacy first-token path jits the SAME fused sampler on a
        # one-row batch: host and device sampling cannot drift apart.
        # sampf = [temperature, top_p] f32, sampi = [top_k, seed] i32 —
        # one combined upload each instead of four scalar device_puts
        self._sample1 = self.backend.jit(
            lambda row, sampf, sampi, pos, bt, bv, smode: fused_sample(
                row[None], sampf[:1], sampi[:1], sampf[1:], sampi[1:],
                pos[None], bt, bv, smode=smode,
            )[0],
            static_argnames=("smode",),
        )
        # device-resident tick state: sampled tokens, per-slot lengths
        self._last_tok = self.backend.put_state(jnp.zeros(batch_slots, jnp.int32))
        self._cur_len = self.backend.put_state(jnp.zeros(batch_slots, jnp.int32))
        # event-driven device arrays (re-uploaded only when slots change):
        # lanes rows are (ov_mask, ov_tok, ov_len, active) — one combined
        # upload instead of five tiny ones — and the per-slot sampling
        # parameter rows (temperature/top_p, top_k/seed, logit-bias pairs)
        self._lanes_idle = self.backend.put_state(
            jnp.zeros((4, batch_slots), jnp.int32)
        )
        self._put_sp(*param_rows([None] * batch_slots, np.zeros(batch_slots)))
        # cached all-zero sampler operands: every greedy (smode 0) dispatch
        # reuses these device-resident constants — the sampler arguments are
        # DEAD in the compiled greedy program, so the all-greedy hot path
        # must not pay fresh uploads for them (tiny device_puts dominate
        # small-host dispatch; C3 parity for the gated steady-state row)
        self._sp0 = (self._spf, self._spi, self._btok, self._bval)
        self._samp0f = self.backend.put_state(jnp.zeros(2, jnp.float32))
        self._samp0i = self.backend.put_state(jnp.zeros(2, jnp.int32))
        self._bias1_0t = self._btok[:1]
        self._bias1_0v = self._bval[:1]
        self._ov_mask_h = np.zeros(batch_slots, bool)  # staged override lanes
        self._ov_tok_h = np.zeros(batch_slots, np.int32)
        self._ov_len_h = np.zeros(batch_slots, np.int32)
        self._dirty = False  # overrides/active/sampling rows pending upload
        # right-padded prefill is only safe when nothing recurrent sees the
        # pad tokens: attention masks them (causal + cur_len), SSM states don't
        self._bucket_prefill = model.cfg.family in ("dense", "moe")

    # ------------------------------------------------------------ internals

    @staticmethod
    def _insert_fn(cache, one_cache, slot):
        """Scatter a B=1 prefilled cache into batched cache at ``slot``."""

        def leaf(c, o):
            return jax.lax.dynamic_update_slice_in_dim(c, o.astype(c.dtype), slot, axis=1)

        return jax.tree.map(leaf, cache, one_cache)

    def _put_sp(self, spf, spi, btok, bval) -> None:
        """Place the per-slot sampling parameter rows on device."""
        self._spf = self.backend.put_host(spf)
        self._spi = self.backend.put_host(spi)
        self._btok = self.backend.put_host(btok)
        self._bval = self.backend.put_host(bval)
        # rows stay fresh until a NEW request occupies a slot (a freed
        # slot's stale row is harmless: inactive slots' draws are masked)
        self._sp_fresh = True

    def _sp_rows(self):
        """Host-built per-slot sampling rows for the CURRENT slot pool."""
        return param_rows(
            [r.params if r is not None else None for r in self.slot_req],
            [getattr(r, "_seed", 0) if r is not None else 0 for r in self.slot_req],
        )

    def _bind(self, req: Request) -> None:
        """Resolve per-request derived sampling state once, at admission:
        the effective seed (engine-assigned when the caller left it None)
        and the precomputed stop set / sampler variant."""
        if getattr(req, "_bound", False):
            return
        p = req.params
        req._seed = p.seed if p.seed is not None else int(self.rng.integers(1 << 31))
        req._stop = frozenset(p.stop)
        req._smode = p.smode
        # per-tenant speculation toggle resolves once, at admission: an
        # opted-out tenant's slots ride the verify dispatch at depth 0
        # (exactly one sequential token per tick, nothing perturbed)
        req._spec = self.spec is not None and self.spec.enabled_for(req.tenant)
        req._bound = True

    def _tick_fn(self, params, cache, last_tok, cur_len, lanes, spf, spi,
                 btok, bval, n_steps: int = 1, smode: int = 0):
        """One fused decode-chunk dispatch: fold the admission override lanes
        into the device state, then run ``n_steps`` decode+sample steps as a
        device-side scan. Everything stays on device; the per-dispatch
        overhead (and, without donation, the KV-cache copy) amortizes over
        the whole chunk. ``lanes`` is ONE [4, B] int32 array — rows
        (ov_mask, ov_tok, ov_len, active) — because every tiny host→device
        upload costs real wall time on small hosts. Returns toks
        [n_steps, B].

        ``smode=0`` is the all-greedy fast path: plain argmax, no PRNG key
        folds and no gumbel draw (threefry is a real cost on small hosts).
        Inactive slots keep their ``last_tok`` (mid-prefill slots ride the
        batch inertly — their sampled garbage must not clobber a first
        token the packed dispatch just wrote).

        Chunking never changes results: the host only chooses ``n_steps``
        such that no slot can count-finish (and hence no admission can
        land) inside the chunk, and every sample's PRNG key is a pure
        function of (request seed, position) — identical to n_steps=1
        dispatches by construction.
        """
        ov_mask = lanes[0].astype(bool)
        active = lanes[3].astype(bool)
        last_tok = jnp.where(ov_mask, lanes[1], last_tok)
        cur_len = jnp.where(ov_mask, lanes[2], cur_len)
        adv = lanes[3]

        def step(carry, _):
            tok, cl, cache = carry
            logits, new_cache = self.model.decode_step(
                params, cache, {"tokens": tok[:, None]}, cl
            )
            if self.model.cfg.family == "ssm":
                # recurrent state has no position axis: an inactive (mid-
                # prefill or empty) slot must not fold the batch's rider
                # token into its state — mask its update. Attention slots
                # instead rely on the next pack overwriting the garbage
                # row at cur_len.
                new_cache = jax.tree.map(
                    lambda n, c: jnp.where(
                        active.reshape((1, -1) + (1,) * (n.ndim - 2)), n, c
                    ),
                    new_cache, cache,
                )
            cache = new_cache
            new = fused_sample(
                logits[:, 0], spf[0], spi[0], spf[1], spi[1], cl,
                btok, bval, smode=smode,
            )
            tok = jnp.where(active, new, tok)
            return (tok, cl + adv, cache), tok

        (last_tok, cur_len, cache), toks = jax.lax.scan(
            step, (last_tok, cur_len, cache), None, length=n_steps
        )
        return toks, last_tok, cur_len, cache

    def _tick_paged_fn(self, params, cache, btab, last_tok, cur_len, lanes,
                       spf, spi, btok, bval, n_steps: int = 1, smode: int = 0):
        """The decode-chunk program over the block-paged pool: identical to
        :meth:`_tick_fn` except the model step resolves every (slot,
        cur_len) through ``btab`` — the per-request reconfiguration is a
        host-written table consulted by the index maps, never a hot-loop
        cost (a chunk with an unchanged slot set re-uses the resident
        table and ships ZERO host arrays, exactly like the dense path)."""
        ov_mask = lanes[0].astype(bool)
        active = lanes[3].astype(bool)
        last_tok = jnp.where(ov_mask, lanes[1], last_tok)
        cur_len = jnp.where(ov_mask, lanes[2], cur_len)
        adv = lanes[3]

        def step(carry, _):
            tok, cl, cache = carry
            logits, cache = self.model.decode_step(
                params, cache, {"tokens": tok[:, None]}, cl, block_tables=btab
            )
            new = fused_sample(
                logits[:, 0], spf[0], spi[0], spf[1], spi[1], cl,
                btok, bval, smode=smode,
            )
            tok = jnp.where(active, new, tok)
            return (tok, cl + adv, cache), tok

        (last_tok, cur_len, cache), toks = jax.lax.scan(
            step, (last_tok, cur_len, cache), None, length=n_steps
        )
        return toks, last_tok, cur_len, cache

    def _packed_paged_fn(self, params, cache, btab, last_tok, desc, meta,
                         spf, spi, btok, bval, smode: int = 0):
        """The ragged-pack program over the block-paged pool: identical to
        :meth:`_packed_fn` with the block table threaded to the paged
        scatter/attention. Same descriptors, same meta layout, same
        sampling — which is why paged greedy streams are bit-identical to
        the dense engine's."""
        b = self.B
        new_len = meta[:b]
        sample_idx = meta[b : 2 * b]
        sample_mask = meta[2 * b : 3 * b].astype(bool)
        pack_slots = meta[3 * b :]
        logits, cache = self.model.packed_step(
            params, cache, desc[0], desc[1], desc[2],
            out_rows=sample_idx, pack_slots=pack_slots, block_tables=btab,
        )
        sampled = fused_sample(
            logits, spf[0], spi[0], spf[1], spi[1], new_len - 1,
            btok, bval, smode=smode,
        )
        last_tok = jnp.where(sample_mask, sampled, last_tok)
        return sampled, last_tok, new_len, cache

    def _packed_fn(self, params, cache, last_tok, desc, meta, spf, spi,
                   btok, bval, smode: int = 0):
        """One ragged prefill dispatch: a flat [T_bucket] pack of prompt
        chunk tokens from every admitting slot runs through the packed
        model step; a slot whose prompt COMPLETES in this pack samples its
        first token from its final prompt position, device-side, alongside
        everyone else's work — the legacy engine's blocking logits transfer
        + host sample per admission disappears.

        The host-built arrays arrive as combined int32 uploads (tiny
        device_puts dominate small-host dispatch): ``desc`` [3, T_bucket]
        rows (chunk token, local slot, position), ``meta`` [3B + pack
        width] = new_len | sample_idx | sample_mask | pack_slots, where
        new_len is the host-computed per-slot cache count after this pack
        (the host knows every count in advance); the sampled first token's
        PRNG position is its final prompt index, ``new_len - 1``. Returns
        (sampled [B], last_tok, cur_len, cache)."""
        b = self.B
        new_len = meta[:b]
        sample_idx = meta[b : 2 * b]
        sample_mask = meta[2 * b : 3 * b].astype(bool)
        pack_slots = meta[3 * b :]
        logits, cache = self.model.packed_step(
            params, cache, desc[0], desc[1], desc[2],
            out_rows=sample_idx, pack_slots=pack_slots, max_len=self.max_len,
        )
        sampled = fused_sample(
            logits, spf[0], spi[0], spf[1], spi[1], new_len - 1,
            btok, bval, smode=smode,
        )
        last_tok = jnp.where(sample_mask, sampled, last_tok)
        return sampled, last_tok, new_len, cache

    def _spec_fn(self, params, cache, last_tok, cur_len, pack, spf,
                 spi, btok, bval, depth_k: int = 1, smode: int = 0):
        """One draft-and-verify dispatch: the packed ragged model step over
        slot-major verify rows ``[last_token, draft_1 .. draft_K]`` per
        slot (T = B*(K+1), exact — no bucket padding), then the seeded
        exact-match acceptance (:func:`spec_verify`) device-side.  The
        verify pack reuses the SAME descriptors, scatter and ragged
        attention as the prefill pack — row (i, j) scatters at (slot i,
        pos cl+j) and attends kpos <= tok_pos, so each row sees exactly
        the context plus the drafts before it, and the packed logits are
        bitwise equal to j sequential decode steps.

        ``pack`` is ONE [3, T + B] i32 upload — the first T columns the
        usual (token, slot, position) descriptor triples, the trailing B
        columns the per-slot meta rows (depth, active, cl); fusing them
        halves the fixed per-upload dispatch cost, which profiles as a
        measurable slice of the host-blocking verify tick.  Descriptor
        rows past a slot's depth carry the out-of-range position sentinel
        (scatter dropped) and a depth-masked acceptance.  Rejected rows
        need no rollback: their K/V sits at positions >= the committed
        ``cur_len``, invisible to every masked read and overwritten by the
        next dispatch's scatters — the argument slot reuse already relies
        on.  Inactive slots (mid-prefill neighbours) pass through
        untouched.  Returns (targets [B, K+1], commit [B], last_tok,
        cur_len, cache)."""
        b, w = self.B, depth_k + 1
        desc, meta = pack[:, : b * w], pack[:, b * w :]
        depth, act, cl = meta[0], meta[1], meta[2]
        active = act.astype(bool)
        logits, cache = self.model.packed_step(
            params, cache, desc[0], desc[1], desc[2]
        )
        drafts = desc[0][: b * w].reshape(b, w)[:, 1:]
        targets, n_acc, commit = spec_verify(
            logits[: b * w], drafts, depth, act, spf[0], spi[0], spf[1],
            spi[1], cl, btok, bval, smode=smode,
        )
        last_tok = jnp.where(active, targets[jnp.arange(b), n_acc], last_tok)
        cur_len = jnp.where(active, cl + commit, cur_len)
        return targets, commit, last_tok, cur_len, cache

    def _spec_paged_fn(self, params, cache, btab, last_tok, cur_len, pack,
                       spf, spi, btok, bval, depth_k: int = 1,
                       smode: int = 0):
        """The verify program over the block-paged pool: identical to
        :meth:`_spec_fn` with the block table threaded through.  Paged
        speculation releases NOTHING on rejection — admission reserved the
        slot's whole worst-case table, the verify rows only write
        positions inside it (and past any shared prefix, so COW blocks are
        never touched)."""
        b, w = self.B, depth_k + 1
        desc, meta = pack[:, : b * w], pack[:, b * w :]
        depth, act, cl = meta[0], meta[1], meta[2]
        active = act.astype(bool)
        logits, cache = self.model.packed_step(
            params, cache, desc[0], desc[1], desc[2], block_tables=btab
        )
        drafts = desc[0][: b * w].reshape(b, w)[:, 1:]
        targets, n_acc, commit = spec_verify(
            logits[: b * w], drafts, depth, act, spf[0], spi[0], spf[1],
            spi[1], cl, btok, bval, smode=smode,
        )
        last_tok = jnp.where(active, targets[jnp.arange(b), n_acc], last_tok)
        cur_len = jnp.where(active, cl + commit, cur_len)
        return targets, commit, last_tok, cur_len, cache

    def _admit_fn(self, params, cache, toks, slot, last_pos, last_tok,
                  cur_len, sampf, sampi, btok, bval, smode: int = 0):
        """One fused async admission (unified mode, prompt ≤ budget): dense
        prefill + cache insert + the first token sampled on device from the
        last REAL prompt position + tick-state update, all in ONE dispatch
        that nothing waits on. The legacy path's blocking logits transfer
        + host-side sample per admission — the pipeline bubble that stalls
        every decode slot — does not exist here; the newly admitted slot
        starts decoding in the same loop iteration."""
        logits, one_cache = self.model.prefill(
            params, {"tokens": toks}, self.max_len
        )
        cache = self._insert_fn(cache, one_cache, slot)
        row = logits[0, last_pos]  # [V]
        tok = fused_sample(
            row[None], sampf[:1], sampi[:1], sampf[1:], sampi[1:],
            last_pos[None], btok, bval, smode=smode,
        )[0]
        last_tok = last_tok.at[slot].set(tok)
        cur_len = cur_len.at[slot].set(last_pos + 1)
        return tok, last_tok, cur_len, cache

    def _prefill_one(self, req: Request, slot: int, stats: Optional[ServeStats]):
        s = len(req.prompt)
        sb = _bucket_len(s, self.max_len) if self._bucket_prefill else s
        sb = max(sb, s)
        if sb not in self._prefill_cache:
            self._prefill_cache[sb] = self.backend.jit(
                lambda p, b: self.model.prefill(p, b, self.max_len)
            )
            if stats is not None:
                stats.prefill_compiles += 1
        toks = np.zeros((1, sb), np.int32)
        toks[0, :s] = req.prompt
        logits, one_cache = self._prefill_cache[sb](
            self.params, {"tokens": self.backend.put_host(toks)}
        )
        self.cache = self._insert(self.cache, one_cache, jnp.int32(slot))
        return logits[0, s - 1]  # last REAL position's logits (device row)

    def _admit_samp(self, req: Request):
        """Per-request admission sampler operands ``(sampf, sampi, btok,
        bval)``. A greedy request reuses the cached device-resident zeros —
        its compiled program never reads them, so the all-greedy admission
        path uploads NOTHING beyond what the pre-SamplingParams engine did."""
        if req._smode == SMODE_GREEDY:
            return self._samp0f, self._samp0i, self._bias1_0t, self._bias1_0v
        p = req.params
        bt, bv = bias_row(p)
        return (
            self.backend.put_host(np.asarray([p.temperature, p.top_p], np.float32)),
            self.backend.put_host(np.asarray([p.top_k, req._seed], np.int32)),
            self.backend.put_host(bt[None]),
            self.backend.put_host(bv[None]),
        )

    def _sample_first(self, row, req: Request) -> int:
        """Legacy-path first-token sample: the SAME fused sampler as every
        device dispatch, jitted on a one-row batch (blocking — the legacy
        admission is synchronous by definition). The row is cast to f32
        BEFORE the jit boundary so the program prewarm() compiled (an f32
        dummy row) serves every model dtype — a bf16 arch must not pay a
        sampler compile at its first sampled admission."""
        sampf, sampi, bt, bv = self._admit_samp(req)
        return int(
            self._sample1(
                row.astype(jnp.float32), sampf, sampi,
                jnp.int32(len(req.prompt) - 1), bt, bv, smode=req._smode,
            )
        )

    # --------------------------------------------------------- token harvest

    def _credit(self, req: Request, tok: int, now: float,
                stats: Optional[ServeStats], first: bool = False) -> None:
        """Append one harvested token value to its request, detecting stop
        tokens at the host crossing that already exists. The stop token is
        itself emitted and counted into ``n_generated`` — exactly like the
        final token of a ``max_new`` window — and any in-flight values past
        it (or past a cancellation) are discarded here, so ``generated``
        is always the final visible prefix (a streaming iterator never
        sees a token that later disappears). A discarded decode value is
        also refunded from ``stats.total_tokens`` (it was counted at
        dispatch), so reported throughput only counts emitted tokens."""
        if req.finish_reason in ("stop", "cancelled") or (
            len(req.generated) >= req.n_generated
        ):
            # overrun values past a stop/cancel; first tokens (admit/packed
            # entries) were never in total_tokens, decode values were
            if stats is not None and not first:
                stats.total_tokens -= 1
            return
        req.generated.append(tok)
        if first and req.first_token_at is None:
            req.first_token_at = now
        if tok in req._stop:
            # stop wins over a simultaneous max_new boundary: the request
            # ended at this token either way, and the reason says why
            req.finish_reason = "stop"
            req.n_generated = len(req.generated)
            req.done_at = now

    @staticmethod
    def _stamp(req: Request, now: float) -> None:
        # done_at was stamped at dispatch-enqueue (counts-only
        # bookkeeping); pull it forward to when the values actually
        # reached the host so TPOT never goes negative and the final
        # chunk's device compute is not silently excluded
        if req.done_at is not None:
            req.done_at = max(req.done_at, now)

    def _harvest(self, entry) -> None:
        """Blockingly pull one dispatch's sampled tokens and credit the
        slots' requests. Called one dispatch behind, so this host transfer
        overlaps the next dispatch's device compute. Packed entries also
        stamp first-token availability (TTFT) — the value provably exists
        on the host at harvest time. The entry carries the stats object
        that counted its dispatch, so a discard refund always lands on the
        counter that was incremented — even when a chunk dispatched under
        step()-driven streaming is harvested inside a later run()."""
        kind, tok_dev, items, stats = entry
        toks = np.asarray(tok_dev)
        now = time.perf_counter()

        if kind == "admit":  # fused admission: one scalar first token
            slot, req = items
            self._credit(req, int(toks), now, stats, first=True)
            self._stamp(req, now)
        elif kind == "packed":  # [B] one sample per flagged slot
            for slot, req, is_first in items:
                self._credit(req, int(toks[slot]), now, stats, first=is_first)
                self._stamp(req, now)
        else:  # decode chunk: [n_steps, B]
            for slot, req in items:
                if not req._stop and len(req.generated) + len(toks) <= req.n_generated:
                    # no stop set and no overrun: bulk-extend (the all-greedy
                    # steady state takes this path for every chunk)
                    req.generated.extend(int(t) for t in toks[:, slot])
                else:
                    for t in toks[:, slot]:
                        self._credit(req, int(t), now, stats)
                self._stamp(req, now)

    def _drain_pending(self) -> None:
        while self._pending:
            self._harvest(self._pending.popleft())

    def _flush_events(self):
        """Upload pending slot changes; returns this tick's [4, B] lanes."""
        if not self._dirty:
            return self._lanes_idle
        lanes = np.zeros((4, self.B), np.int32)
        # one-shot override rows: fresh numpy every flush — CPU device_put
        # of a numpy array can be zero-copy/deferred, so handing jax a live
        # staging buffer the host later mutates races the in-flight
        # dispatch (observed as override lanes reading zeros mid-run)
        lanes[0] = self._ov_mask_h
        lanes[1] = self._ov_tok_h
        lanes[2] = self._ov_len_h
        # active == DECODING: a mid-prefill slot rides decode chunks inertly
        # (no cur_len advance, last_tok preserved) until its pack completes
        lanes[3] = [
            r is not None and self.slot_fed[i] >= len(r.prompt)
            for i, r in enumerate(self.slot_req)
        ]
        # the per-slot sampling rows are DEAD in every smode-0 program: an
        # all-greedy slot pool skips the rebuild entirely, and a flush
        # whose only change is a freed slot (rows still fresh) skips it too
        # — _packed_tick may also have rebuilt them earlier this iteration
        if not self._sp_fresh and any(
            r is not None and r._smode for r in self.slot_req
        ):
            self._put_sp(*self._sp_rows())
        # the overrides apply exactly once; later idle ticks reuse a cached
        # ov-zeroed copy with the same active row
        idle = lanes.copy()
        idle[:3] = 0
        self._lanes_idle = self.backend.put_host(idle)
        self._ov_mask_h[:] = False
        self._dirty = False
        return self.backend.put_host(lanes)

    def _flush_btab(self):
        """Upload the block table if any slot's mapping changed; returns
        the device-resident [B, max_blocks] table. Steady-state chunks with
        an unchanged slot set reuse the resident copy (no upload). The
        fresh ``copy()`` matters: releasing a slot NB's its host row, and
        the NEXT dispatch must see that before the freed blocks can be
        re-scattered by a new owner — handing jax a live staging buffer the
        host later mutates races the in-flight dispatch."""
        if self._btab_dirty:
            self._btab = self.backend.put_host(self._btab_h.copy())
            self._btab_dirty = False
        return self._btab

    def _release_slot_blocks(self, slot: int) -> None:
        """Drop the slot's references on its blocks (finish/cancel). Blocks
        the prefix tree retains keep their references and stay resident; the
        rest return to the free list and are re-admittable immediately —
        any in-flight dispatch that still reads them was enqueued before
        the next owner's scatter, so device ordering keeps it correct
        (the same argument as dense slot reuse)."""
        self.pool.release_all(self._slot_blocks[slot])
        self._slot_blocks[slot] = []
        self._btab_h[slot, :] = self.num_blocks
        self._btab_dirty = True

    # ------------------------------------------------------------------ API

    def prewarm(self, sampling: bool = False) -> None:
        """Compile every dispatch variant this engine can hit, before any
        request arrives (production serving compiles once, then serves):
        the decode-chunk scan depths up to ``max_chunk`` and — in unified
        mode — every packed T bucket up to ``prefill_budget`` plus the
        fused-admission prompt buckets. A compile landing inside a live
        arrival stream stalls every queued request's TTFT; this moves all
        of them off the serving path. ``sampling=True`` additionally
        compiles every sampler variant (gumbel temperature + masked
        top-k/top-p) of each dispatch — greedy-only deployments skip them,
        a mixed-sampling deployment should not let its first temperature
        or nucleus request pay the compile. Call on an IDLE engine (before
        serving): the dummy fused-admission dispatches overwrite slot 0's
        cache row."""
        smodes = (0, 1, 2) if sampling else (0,)
        k = 1
        while k <= self.max_chunk:
            for sm in smodes:
                if self.paged:
                    # all-sentinel block table: every scatter drops, every
                    # gather clamps — the pool is untouched by the warmup
                    toks, _lt, _cl, self.cache = self._tick_paged(
                        self.params, self.cache, self._btab,
                        self._last_tok, self._cur_len,
                        self._lanes_idle, self._spf, self._spi, self._btok,
                        self._bval, n_steps=k, smode=sm,
                    )
                else:
                    toks, _lt, _cl, self.cache = self._tick(
                        self.params, self.cache, self._last_tok, self._cur_len,
                        self._lanes_idle, self._spf, self._spi, self._btok,
                        self._bval, n_steps=k, smode=sm,
                    )
                jax.block_until_ready(toks)
            k *= 2
        if not self.unified:
            if sampling:  # the legacy first-token path's sampler variants
                row = self.backend.put_host(np.zeros(self.model.cfg.vocab_size, np.float32))
                for sm in smodes:
                    jax.block_until_ready(self._sample1(
                        row, self._samp0f, self._samp0i, jnp.int32(0),
                        self._bias1_0t, self._bias1_0v, smode=sm,
                    ))
            return
        # the EXACT T-bucket ladder _bucket_tokens can produce, including
        # the doubling tail beyond _T_BUCKETS for very large budgets
        top = _bucket_tokens(self.prefill_budget)
        tb_ladder = [b for b in _T_BUCKETS if b <= top]
        b = _T_BUCKETS[-1]
        while b < top:
            b *= 2
            tb_ladder.append(b)
        for tb in tb_ladder:
            if tb in self._packed_shapes:
                continue
            # an all-padding pack: scatters dropped (pos = max_len), no
            # slot sampled, cur_len passed through unchanged
            desc = np.zeros((3, tb), np.int32)
            desc[2] = self.max_len
            meta = np.concatenate(
                [
                    self.slot_len,
                    np.zeros(2 * self.B, np.int32),
                    np.zeros(self._pack_width, np.int32),
                ]
            )
            for sm in smodes:
                if self.paged:
                    toks, _lt, _cl, self.cache = self._packed_paged(
                        self.params, self.cache, self._btab, self._last_tok,
                        self.backend.put_host(desc), self.backend.put_host(meta),
                        self._spf, self._spi, self._btok, self._bval, smode=sm,
                    )
                else:
                    toks, _lt, _cl, self.cache = self._packed(
                        self.params, self.cache, self._last_tok,
                        self.backend.put_host(desc), self.backend.put_host(meta),
                        self._spf, self._spi, self._btok, self._bval, smode=sm,
                    )
                jax.block_until_ready(toks)
            self._packed_shapes.add(tb)
        if self.spec is not None:
            # the verify depth ladder {1, 2, 4, .., spec_k} — the only
            # widths _spec_tick can dispatch — plus the drafter's own
            # programs.  All-padding packs (pos = max_len, every slot
            # inactive) so the warmup commits nothing and touches no slot.
            self.drafter.prewarm()
            kk = 1
            while True:
                pack = np.zeros((3, self.B * (kk + 1) + self.B), np.int32)
                pack[2, : self.B * (kk + 1)] = self.max_len
                for sm in smodes:
                    if self.paged:
                        tg, _c, _lt, _cl, self.cache = self._spec_prog_paged(
                            self.params, self.cache, self._btab,
                            self._last_tok, self._cur_len,
                            self.backend.put_host(pack),
                            self._spf, self._spi, self._btok, self._bval,
                            depth_k=kk, smode=sm,
                        )
                    else:
                        tg, _c, _lt, _cl, self.cache = self._spec_prog(
                            self.params, self.cache, self._last_tok,
                            self._cur_len, self.backend.put_host(pack),
                            self._spf, self._spi, self._btok, self._bval,
                            depth_k=kk, smode=sm,
                        )
                    jax.block_until_ready(tg)
                    self._spec_shapes.add((kk, sm))
                if kk >= self.spec_k:
                    break
                kk *= 2
        if self.paged or self._chunk_only_admit:
            # paged, quantized-KV and SSM admission route every request
            # through the packed tier (one code path writes the cache /
            # pool / state) — no fused-admission shapes exist to warm
            return
        # the EXACT prompt buckets _admit_unified can produce: every power
        # of two up to the fused-tier limit, plus the max_len-capped bucket
        # a non-pow2 max_len introduces
        top_prompt = min(self.prefill_budget, self.max_len - 1)
        sizes = [top_prompt]
        b = 1
        while b <= top_prompt:
            sizes.append(b)
            b *= 2
        for sb in sorted({_bucket_len(s, self.max_len) for s in sizes}):
            if sb in self._admit_shapes:
                continue
            for sm in smodes:
                tok, _lt, _cl, self.cache = self._admit_prog(
                    self.params, self.cache,
                    self.backend.put_host(np.zeros((1, sb), np.int32)),
                    jnp.int32(0), jnp.int32(sb - 1), self._last_tok,
                    self._cur_len, self._samp0f, self._samp0i,
                    self._bias1_0t, self._bias1_0v, smode=sm,
                )
                jax.block_until_ready(tok)
            self._admit_shapes.add(sb)

    def reset(self) -> None:
        """Return an IDLE engine to its just-constructed serving state.

        Device tick state, override staging and slot bookkeeping are
        re-zeroed; compiled programs and the (garbage-tolerant) KV cache
        survive, so re-entering a previously-built cluster mode costs no
        recompiles and no cache realloc — the warm half of the paper's
        cheap CSR-write reconfiguration. Refuses to reset mid-flight."""
        assert all(r is None for r in self.slot_req), "reset() on a busy engine"
        self.slot_len[:] = 0
        self.slot_fed[:] = 0
        self.waiting.clear()
        self.finished = []
        self._prefilling.clear()
        self._done_now = []
        self._pending.clear()
        self._cancels.clear()
        self._stream_stats = ServeStats()
        self.rng = np.random.default_rng(self.seed)
        self._last_tok = self.backend.put_state(jnp.zeros(self.B, jnp.int32))
        self._cur_len = self.backend.put_state(jnp.zeros(self.B, jnp.int32))
        self._lanes_idle = self.backend.put_state(jnp.zeros((4, self.B), jnp.int32))
        self._put_sp(*param_rows([None] * self.B, np.zeros(self.B)))
        self._ov_mask_h[:] = False
        self._ov_tok_h[:] = 0
        self._ov_len_h[:] = 0
        self._dirty = False
        if self.spec is not None:
            self._spec_ewma[:] = 1.0
            for i in range(self.B):
                self.drafter.reset_slot(i)
        if self.paged:
            if self.prefix is not None:
                self.prefix.clear()
            self.pool.reset()
            self._slot_blocks = [[] for _ in range(self.B)]
            self._btab_h[:] = self.num_blocks
            self._btab = self.backend.put_host(self._btab_h.copy())
            self._btab_dirty = False

    def submit(self, req: Request) -> RequestHandle:
        assert len(req.prompt) < self.max_len, (len(req.prompt), self.max_len)
        if self.paged:
            need = blocks_for(
                len(req.prompt), req.params.max_new, self.max_len,
                self.kv_block_size,
            )
            if need > self.num_blocks:
                # an admission-time wait could never resolve — reject at
                # the submission boundary instead of spinning forever
                raise AdmissionRejected(
                    "infeasible",
                    f"request needs {need} KV blocks, pool holds "
                    f"{self.num_blocks}",
                )
        req.submitted_at = time.perf_counter()
        self.waiting.append(req)
        return RequestHandle(req, self)

    # ------------------------------------------------------------- lifecycle

    def cancel(self, req: Request) -> None:
        """Abort a request (thread-safe): enqueue the cancellation and — if
        no run loop owns the engine — apply it immediately. A controller
        thread mid-``run()`` applies queued cancels at its next scheduling
        iteration; the freed slot is re-admittable the same iteration, and
        no other slot's stream is perturbed (per-request sampling keys)."""
        with self._cancel_lock:
            # append AND the _running read happen under the lock: an
            # unlocked append could land on a list _apply_cancels already
            # swapped out (silently losing the cancel), and run() flips
            # _running under the same lock so inline application can't
            # overlap a starting serving loop
            self._cancels.append(req)
            running = self._running
        if not running:
            with self._drive_lock:
                self._apply_cancels(self._stream_stats)

    def _apply_cancels(self, stats: ServeStats) -> None:
        if not self._cancels:  # steady state: no lock, no list churn
            return
        with self._cancel_lock:
            cancels, self._cancels = self._cancels, []
            for req in cancels:
                if req.finish_reason is not None:
                    continue  # finished (or already cancelled) — nothing to free
                if req in self.waiting:
                    self.waiting.remove(req)
                for slot, r in enumerate(self.slot_req):
                    if r is req:  # free the slot mid-stream
                        self.slot_req[slot] = None
                        self.slot_len[slot] = 0
                        self.slot_fed[slot] = 0
                        if slot in self._prefilling:
                            self._prefilling.remove(slot)
                        if self.paged:  # cancel frees the blocks mid-stream
                            self._release_slot_blocks(slot)
                        self._ov_mask_h[slot] = False  # unflushed admission override
                        self._dirty = True
                req.finish_reason = "cancelled"
                req.n_generated = len(req.generated)  # in-flight values discarded
                req.done_at = time.perf_counter()
                self.finished.append(req)
                self._done_now.append(req)
                stats.cancelled += 1

    def _release_stopped(self, stats: ServeStats) -> None:
        """Free the slot of any request whose harvest found a stop token
        (value-dependent termination is detected one dispatch behind; the
        slot's overrun chunk, if any, was discarded at credit time)."""
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.finish_reason == "stop":
                self._finish(r, slot, stats)

    def _finish(self, req: Request, slot: int, stats: Optional[ServeStats],
                reason: str = "length") -> None:
        if req.finish_reason is None:
            req.finish_reason = reason
        if req.done_at is None:
            req.done_at = time.perf_counter()
        self.finished.append(req)
        self._done_now.append(req)
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        if self.paged:
            self._release_slot_blocks(slot)
        if stats is not None:
            stats.total_requests += 1
        self._dirty = True

    def _admit(self, stats: Optional[ServeStats] = None) -> None:
        """Legacy admission: synchronous B=1 prefill + cache insert. The
        first token runs through the SAME fused sampler as the device
        dispatches (one-row jit), so legacy and unified streams stay
        bit-identical under every SamplingParams."""
        for slot in range(self.B):
            while self.slot_req[slot] is None and self.waiting:
                req = self.waiting.popleft()
                self._bind(req)
                row = self._prefill_one(req, slot, stats)
                tok = self._sample_first(row, req)
                now = time.perf_counter()
                req.generated.append(tok)
                req.n_generated = 1
                req.first_token_at = now
                if tok in req._stop or req.n_generated >= req.params.max_new:
                    # nothing left to decode (stop token, or max_new=1):
                    # finish without ever occupying the slot. A first-token
                    # stop counts into n_generated exactly like a
                    # max_new=1 boundary — one emitted token either way.
                    req.finish_reason = "stop" if tok in req._stop else "length"
                    req.done_at = now
                    self.finished.append(req)
                    self._done_now.append(req)
                    if stats is not None:
                        stats.total_requests += 1
                    continue
                self.slot_req[slot] = req
                self._sp_fresh = False  # a new occupant's row must upload
                self.slot_len[slot] = len(req.prompt)
                self.slot_fed[slot] = len(req.prompt)
                self._ov_mask_h[slot] = True
                self._ov_tok_h[slot] = tok
                self._ov_len_h[slot] = len(req.prompt)
                self._dirty = True

    def _admit_unified(self, stats, pending: deque) -> None:
        """Unified admission — two tiers, neither of which ever blocks the
        host or stalls a decode slot:

        * prompt ≤ ``prefill_budget``: ONE fused async dispatch (dense
          prefill + insert + device-side first-token sample); the slot is
          decoding by the next dispatch in the same loop iteration.
        * longer prompts: bound to the slot and fed as ragged packed
          chunks of ≤ budget tokens per tick (Sarathi-style), so a long
          admission costs each tick only one bounded pack.
        """
        for slot in range(self.B):
            while self.slot_req[slot] is None and self.waiting:
                req = self.waiting.popleft()
                self._bind(req)
                s = len(req.prompt)
                self.slot_req[slot] = req
                self._sp_fresh = False  # a new occupant's row must upload
                self._dirty = True
                if self.spec is not None:
                    self._spec_ewma[slot] = 1.0  # optimistic: probe deep first
                    self.drafter.reset_slot(slot)
                if self._chunk_only_admit or s > self.prefill_budget:
                    # chunked ragged tier. Quantized-KV and SSM engines
                    # route EVERY admission here: the fused tier's
                    # model.prefill builds a scale-less B=1 cache that
                    # cannot insert into a scale-bearing one (quant), and
                    # an exact-length prefill would compile per prompt
                    # length (ssm — the chunk scan reuses the T buckets).
                    self.slot_len[slot] = 0
                    self.slot_fed[slot] = 0
                    self._prefilling.append(slot)
                    continue
                sb = _bucket_len(s, self.max_len) if self._bucket_prefill else s
                if sb not in self._admit_shapes:
                    self._admit_shapes.add(sb)
                    if stats is not None:
                        stats.prefill_compiles += 1
                toks = np.zeros((1, sb), np.int32)
                toks[0, :s] = req.prompt
                sampf, sampi, bt, bv = self._admit_samp(req)
                tok, self._last_tok, self._cur_len, self.cache = (
                    self._admit_prog(
                        self.params, self.cache, self.backend.put_host(toks),
                        jnp.int32(slot), jnp.int32(s - 1), self._last_tok,
                        self._cur_len, sampf, sampi, bt, bv,
                        smode=req._smode,
                    )
                )
                self.slot_len[slot] = s
                self.slot_fed[slot] = s
                req.n_generated += 1  # first token (in flight; counts-only
                pending.append(("admit", tok, (slot, req), stats))
                if req.n_generated >= req.params.max_new:  # bookkeeping)
                    self._finish(req, slot, stats)

    def _admit_paged(self, stats) -> None:
        """Paged admission: consult the prefix tree, reserve the request's
        ENTIRE worst-case block table, and bind the slot to the chunked
        ragged tier starting at the first unmatched position.

        * The radix tree (when enabled) yields the longest block-aligned
          shared prefix; those blocks enter the table read-only and
          ``slot_fed`` starts past them — matched tokens are never re-fed,
          so a repeated system prompt's prefill collapses to its tail
          (admission TTFT ∝ unmatched tokens).
        * Allocation is all-or-nothing and up front (``blocks_for``):
          decode can never run out of blocks mid-stream, and pool pressure
          surfaces exactly here — the request stays at the head of the
          queue and WAITS (after trying LRU eviction of tree-only blocks)
          until a finishing request frees capacity. Nothing crashes, no
          other slot is perturbed.
        * Every admission — even a one-token prompt — runs the packed
          tier: one code path writes the pool, so the COW invariant
          (shared blocks are never scattered into) has a single proof
          point.
        """
        for slot in range(self.B):
            while self.slot_req[slot] is None and self.waiting:
                req = self.waiting[0]
                self._bind(req)
                s = len(req.prompt)
                need_total = blocks_for(
                    s, req.params.max_new, self.max_len, self.kv_block_size
                )
                shared: list[int] = []
                matched = 0
                if self.prefix is not None:
                    shared, matched = self.prefix.match(req.prompt)
                need = need_total - len(shared)
                if not self.pool.can_alloc(need):
                    if self.prefix is not None:
                        self.prefix.evict(need - self.pool.free)
                    if not self.pool.can_alloc(need):
                        # pool exhausted: release the matched references
                        # and leave the request waiting, FCFS order intact
                        self.pool.alloc_failures += 1
                        self.pool.release_all(shared)
                        return
                self.waiting.popleft()
                blocks = shared + self.pool.alloc(need)
                self._slot_blocks[slot] = blocks
                row = self._btab_h[slot]
                row[:] = self.num_blocks
                row[: len(blocks)] = blocks
                self._btab_dirty = True
                self.slot_req[slot] = req
                self._sp_fresh = False  # a new occupant's row must upload
                self._dirty = True
                if self.spec is not None:
                    self._spec_ewma[slot] = 1.0  # optimistic: probe deep first
                    self.drafter.reset_slot(slot)
                self.slot_len[slot] = matched
                self.slot_fed[slot] = matched
                self._prefilling.append(slot)

    # ------------------------------------------------------------ tick paths

    def _packed_tick(self, stats: ServeStats, pending: deque) -> None:
        """Build and dispatch one ragged prefill pack: up to
        ``prefill_budget`` prompt tokens (FCFS across the admitting slots),
        padded to a T bucket. Decode slots are untouched here — the run
        loop rides a fused decode chunk alongside every pack, so admission
        work and decode progress share each loop iteration instead of
        queueing behind each other."""
        entries: list[tuple[int, int, int]] = []  # (token, LOCAL slot, pos)
        sample_idx = np.zeros(self.B, np.int32)
        sample_mask = np.zeros(self.B, bool)
        # the pack spans at most _pack_width admitting slots: attention
        # work (and the compile count — one variant) scales with the pack,
        # not the slot pool; later admissions simply join the next tick's
        # pack. SSM packs are width 1 (one contiguous stream per chunk).
        pack_slots = np.zeros(self._pack_width, np.int32)
        budget = self.prefill_budget
        completed: list[int] = []
        for local, i in enumerate(self._prefilling[: self._pack_width]):
            if budget <= 0:
                break
            pack_slots[local] = i
            req = self.slot_req[i]
            fed = int(self.slot_fed[i])
            n = min(budget, len(req.prompt) - fed)
            budget -= n
            for j in range(n):
                entries.append((int(req.prompt[fed + j]), local, fed + j))
            self.slot_fed[i] = fed + n
            self.slot_len[i] = fed + n
            if fed + n == len(req.prompt):
                sample_idx[i] = len(entries) - 1  # the final prompt token
                sample_mask[i] = True
                completed.append(i)
                self._prefilling.remove(i)
                self._dirty = True  # becomes an active decoder
        tb = _bucket_tokens(len(entries))
        if tb not in self._packed_shapes:
            self._packed_shapes.add(tb)
            stats.prefill_compiles += 1
        # combined uploads, built fresh every tick (CPU device_put can
        # be zero-copy, so jax must never see a buffer the host mutates
        # later). Padding tokens scatter out of bounds (dropped) and attend
        # slot 0 with an all-valid mask; their output rows are never sampled
        desc = np.zeros((3, tb), np.int32)
        desc[2] = self.max_len
        for t, (tok, sl, pos) in enumerate(entries):
            desc[0, t] = tok
            desc[1, t] = sl
            desc[2, t] = pos
        meta = np.concatenate(
            [self.slot_len, sample_idx, sample_mask.astype(np.int32), pack_slots]
        )
        # only the slots SAMPLED by this pack pick the compiled variant —
        # mid-prefill neighbours don't widen the dispatch; an all-greedy
        # pack reuses the cached zero sampler rows (dead in smode 0)
        smode = max(
            (self.slot_req[i]._smode for i in completed), default=SMODE_GREEDY
        )
        if smode:
            # refresh the RESIDENT rows (once): the fused decode chunk in
            # this same iteration — and _flush_events — reuse them instead
            # of re-building and re-uploading identical arrays
            if not self._sp_fresh:
                self._put_sp(*self._sp_rows())
            spf, spi, btok, bval = self._spf, self._spi, self._btok, self._bval
        else:
            spf, spi, btok, bval = self._sp0

        if self.paged:
            toks, self._last_tok, self._cur_len, self.cache = (
                self._packed_paged(
                    self.params, self.cache, self._flush_btab(),
                    self._last_tok,
                    self.backend.put_host(desc), self.backend.put_host(meta),
                    spf, spi, btok, bval,
                    smode=smode,
                )
            )
        else:
            toks, self._last_tok, self._cur_len, self.cache = (
                self._packed(
                    self.params, self.cache, self._last_tok,
                    self.backend.put_host(desc), self.backend.put_host(meta),
                    spf, spi, btok, bval,
                    smode=smode,
                )
            )
        stats.ticks += 1

        if completed:
            if self.paged and self.prefix is not None:
                # the prompt's K/V now exists in this slot's blocks (the
                # dispatch above is ordered before any future reader) —
                # register its full prompt blocks so later requests skip
                # them. Insert BEFORE any instant finish below: the tree
                # takes its own references, so the blocks outlive the
                # request until evicted.
                for i in completed:
                    self.prefix.insert(
                        self.slot_req[i].prompt, self._slot_blocks[i]
                    )
            items = []
            for i in completed:
                req = self.slot_req[i]
                req.n_generated += 1  # the request's first token (not counted
                items.append((i, req, True))  # in total_tokens, like legacy)
            pending.append(("packed", toks, items, stats))
            for i in completed:
                req = self.slot_req[i]
                # no capacity check: admission guarantees prompt < max_len,
                # so one decode write at position len(prompt) always fits
                if req.n_generated >= req.params.max_new:
                    self._finish(req, i, stats)

    def _chunk_tick(self, stats: ServeStats, pending: deque, active: list[int]) -> None:
        """One fused multi-step decode chunk: as long as no active slot can
        count-finish inside the chunk, k decode steps are one dispatch
        (bucketed to powers of two ≤ ``max_chunk`` so few tick variants
        compile). Stop tokens cannot participate here — the host never
        waits on values — so a stop-terminated slot overruns by at most
        one chunk, discarded at credit time."""
        rem = min(
            min(
                self.slot_req[i].params.max_new - self.slot_req[i].n_generated,
                self.max_len - 1 - int(self.slot_len[i]),
            )
            for i in active
        )
        cap = max(1, min(rem, self.max_chunk))
        k = 1
        while k * 2 <= cap:
            k *= 2
        smode = max(self.slot_req[i]._smode for i in active)
        lanes = self._flush_events()
        if self.paged:
            toks, self._last_tok, self._cur_len, self.cache = (
                self._tick_paged(
                    self.params, self.cache, self._flush_btab(),
                    self._last_tok, self._cur_len,
                    lanes, self._spf, self._spi, self._btok, self._bval,
                    n_steps=k, smode=smode,
                )
            )
        else:
            toks, self._last_tok, self._cur_len, self.cache = (
                self._tick(
                    self.params, self.cache, self._last_tok, self._cur_len,
                    lanes, self._spf, self._spi, self._btok, self._bval,
                    n_steps=k, smode=smode,
                )
            )
        stats.ticks += k
        pending.append(("chunk", toks, [(i, self.slot_req[i]) for i in active], stats))
        # bookkeeping needs only COUNTS — token values are harvested a
        # chunk later, overlapping this chunk's device compute
        for i in active:
            req = self.slot_req[i]
            self.slot_len[i] += k
            req.n_generated += k
            stats.total_tokens += k
            if req.n_generated >= req.params.max_new or self.slot_len[i] + 1 >= self.max_len:
                self._finish(req, i, stats)

    def _spec_depth(self, slot: int) -> int:
        """Adaptive proposal depth for one slot, from its acceptance EWMA.
        Host-side and bucketed to the compiled {1, 2, 4, .., spec_k} depth
        zoo, so adapting never compiles a new program.  With adaptation
        off every slot always proposes the full ``spec_k``."""
        if not self.spec.adaptive:
            return self.spec_k
        e = self._spec_ewma[slot]
        for thresh, d in ((0.7, 8), (0.45, 4), (0.2, 2)):
            if e >= thresh:
                return min(d, self.spec_k)
        return 1

    def _spec_tick(self, stats: ServeStats) -> None:
        """One draft-and-verify iteration over every decoding slot: drain
        the harvest (the drafter reads committed VALUES, and commit counts
        are value-dependent — speculation deliberately trades the
        one-behind pipeline for multi-token commits per dispatch), draft
        per-slot proposals, run ONE packed verify dispatch, then commit
        the accepted prefixes through the standard credit path.

        Depth is capped at ``rem - 1`` (rem = the slot's remaining token
        budget, the same bound :meth:`_chunk_tick` uses) so a commit can
        never overshoot ``max_new``/``max_len`` — count-based finish
        detection stays exact, and every verify-row position stays inside
        the dense row / reserved paged table.  Stop tokens are detected in
        the credit path as always; values past the stop are refunded and
        the slot is released at the next iteration — with the bonus
        sampled token and the exact-match rule, a speculated stream stops
        at exactly the token the sequential engine would have stopped
        at."""
        self._drain_pending()
        self._release_stopped(stats)
        decoding = [
            i for i, r in enumerate(self.slot_req)
            if r is not None and self.slot_fed[i] >= len(r.prompt)
        ]
        if not decoding:
            return
        b = self.B
        depths = np.zeros(b, np.int32)
        ctxs: list[Optional[np.ndarray]] = [None] * b
        for i in decoding:
            r = self.slot_req[i]
            rem = min(
                r.params.max_new - r.n_generated,
                self.max_len - 1 - int(self.slot_len[i]),
            )
            d = min(self.spec_k, rem - 1, self._spec_depth(i)) if r._spec else 0
            depths[i] = max(d, 0)
            if depths[i] > 0:
                ctxs[i] = np.concatenate(
                    [
                        np.asarray(r.prompt, np.int64),
                        np.asarray(r.generated, np.int64),
                    ]
                )
        if depths.any():
            props = self.drafter.propose(ctxs, depths)
            for i in decoding:
                depths[i] = min(int(depths[i]), len(props[i]))
        else:
            props = [[] for _ in range(b)]
        kmax = max(1, int(depths.max()))
        depth_k = 1
        while depth_k < kmax:
            depth_k *= 2
        w = depth_k + 1
        # slot-major verify rows [last_token, draft_1 .. draft_d]; rows
        # past a slot's depth (and whole inactive slots) carry the
        # position sentinel — scatter dropped, acceptance depth-masked.
        # desc and meta share ONE upload (see _spec_fn): pack[:, :b*w] is
        # the descriptor, pack[:, b*w:] the per-slot (depth, active, cl)
        pack = np.zeros((3, b * w + b), np.int32)
        desc = pack[:, : b * w]
        meta = pack[:, b * w :]
        desc[2] = self.max_len
        for i in decoding:
            r = self.slot_req[i]
            d = int(depths[i])
            cl = int(self.slot_len[i])
            r0 = i * w
            desc[0, r0] = r.generated[-1]
            if d:
                desc[0, r0 + 1 : r0 + 1 + d] = props[i][:d]
            desc[1, r0 : r0 + 1 + d] = i
            desc[2, r0 : r0 + 1 + d] = cl + np.arange(d + 1)
            meta[0, i] = d
            meta[1, i] = 1
            meta[2, i] = cl
        smode = max(self.slot_req[i]._smode for i in decoding)
        if smode:
            if not self._sp_fresh:
                self._put_sp(*self._sp_rows())
            spf, spi, btok, bval = self._spf, self._spi, self._btok, self._bval
        else:
            spf, spi, btok, bval = self._sp0
        if (depth_k, smode) not in self._spec_shapes:
            self._spec_shapes.add((depth_k, smode))
            stats.prefill_compiles += 1
        if self.paged:
            targets, commit, self._last_tok, self._cur_len, self.cache = (
                self._spec_prog_paged(
                    self.params, self.cache, self._flush_btab(),
                    self._last_tok, self._cur_len,
                    self.backend.put_host(pack),
                    spf, spi, btok, bval, depth_k=depth_k, smode=smode,
                )
            )
        else:
            targets, commit, self._last_tok, self._cur_len, self.cache = (
                self._spec_prog(
                    self.params, self.cache, self._last_tok, self._cur_len,
                    self.backend.put_host(pack),
                    spf, spi, btok, bval, depth_k=depth_k, smode=smode,
                )
            )
        stats.ticks += 1
        stats.spec_ticks += 1
        # value-blocking by design (see above); ONE transfer for both
        t_h, c_h = jax.device_get((targets, commit))
        now = time.perf_counter()
        for i in decoding:
            r = self.slot_req[i]
            c = int(c_h[i])  # accepted run + the bonus token, >= 1
            d = int(depths[i])
            stats.spec_proposed += d
            stats.spec_accepted += c - 1
            if self.spec.adaptive and d > 0:
                self._spec_ewma[i] = 0.5 * self._spec_ewma[i] + 0.5 * (
                    (c - 1) / d
                )
            r.n_generated += c
            self.slot_len[i] += c
            stats.total_tokens += c
            for j in range(c):
                self._credit(r, int(t_h[i, j]), now, stats)
            self._stamp(r, now)
            if r.finish_reason is None and (
                r.n_generated >= r.params.max_new
                or self.slot_len[i] + 1 >= self.max_len
            ):
                self._finish(r, i, stats)

    # ------------------------------------------------------------------- run

    def _service_once(self, stats: ServeStats, admit: bool = True) -> bool:
        """ONE scheduling iteration — the unit both ``run()`` and the
        streaming ``step()`` are built from: apply cancellations, release
        stop-finished slots, admit, dispatch this iteration's fused
        tick(s), then harvest everything older than the newest in-flight
        dispatch. Returns whether any work remains.

        ``admit=False`` drains in-flight slots without pulling from the
        waiting queue — the controlled-run slice boundary (a cluster about
        to reconfigure wants idle slots, not an empty queue)."""
        self._apply_cancels(stats)
        self._release_stopped(stats)
        if not admit:
            pass
        elif self.paged:
            self._admit_paged(stats)
        elif self.unified:
            self._admit_unified(stats, self._pending)
        else:
            self._admit(stats)
        stats.kv_bytes_resident = max(
            stats.kv_bytes_resident, self.kv_bytes_resident()
        )
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            self._drain_pending()
            self._release_stopped(stats)
            return bool(self.waiting) or any(
                r is not None for r in self.slot_req
            )
        if self.unified and self._prefilling:
            # merge mode: one ragged prefill pack, and — in the same
            # loop iteration — a fused decode chunk for every decoding
            # slot (including one whose prompt just completed in this
            # very pack). Admission never stalls decode.
            self._packed_tick(stats, self._pending)
            if self.spec is not None:
                self._spec_tick(stats)
            else:
                decoding = [
                    i for i, r in enumerate(self.slot_req)
                    if r is not None and self.slot_fed[i] >= len(r.prompt)
                ]
                if decoding:
                    self._chunk_tick(stats, self._pending, decoding)
        elif self.spec is not None:
            self._spec_tick(stats)
        else:
            self._chunk_tick(stats, self._pending, active)
        while len(self._pending) > 1:
            self._harvest(self._pending.popleft())
        return True

    def kv_bytes_resident(self) -> int:
        """Actual HBM bytes of KV state resident right now, dtype-aware.
        Dense engines report the constant cache allocation (every slot's
        worst case is always resident); paged engines report used blocks
        times the measured per-block weight — which is how an int8 pool
        shows ~4x the requests in the same byte budget."""
        if self.paged:
            return self.pool.used * self.pool.bytes_per_block
        return self._dense_kv_bytes

    @property
    def stream_stats(self) -> ServeStats:
        """Stats accumulated by step()-driven serving (handle iterators,
        inline cancellations) — work served OUTSIDE any ``run()`` window.
        A complete picture of a mixed streamed+drained session is this
        plus the ServeStats each ``run()`` returned."""
        return self._stream_stats

    def step(self) -> bool:
        """Advance the engine by one scheduling iteration (the streaming
        driver: a ``RequestHandle`` iterator calls this when no run loop
        owns the engine). Returns whether any work remains."""
        with self._drive_lock:
            busy = self._service_once(self._stream_stats)
            if not busy:
                self._drain_pending()
                self._release_stopped(self._stream_stats)
            return busy

    def _handle_pump(self, req: Request) -> None:
        """Make progress on behalf of a blocked handle iterator: drive the
        engine when this thread owns it, politely poll when a controller
        thread (cluster split mode) does. A poisoned (declared-dead)
        engine is never driven: the handle polls until the cluster has
        re-homed its request onto a survivor."""
        if self._running or self._poisoned:
            time.sleep(1e-4)
            return
        if self.step():
            return
        self._apply_cancels(self._stream_stats)
        if not req.complete:
            raise RuntimeError(
                f"engine idle but request {req.rid} incomplete — "
                "was it submitted to this engine?"
            )

    def run(
        self, arrivals=None, *, deadline_s=None, on_tick=None, gate=None
    ) -> ServeStats:
        """Drain all submitted requests; returns throughput + latency stats.

        ``arrivals`` optionally simulates an open-loop request stream: an
        iterable of ``(t_offset_seconds, Request)`` submitted once the run
        clock passes each offset (mixed-arrival benchmarking).

        ``gate`` is the admission hook for arrival-stream requests: called
        with each due request BEFORE it joins the queue, it may raise
        :class:`AdmissionRejected` — the request then finishes immediately
        as ``"rejected"`` (an open-loop stream has no caller to raise
        into). Gating happens at the scheduled arrival time against the
        live queue, which is what makes deadline-based shedding honest: a
        burst is rejected as the queue grows, not waved through because
        the queue was empty when the batch was handed over.

        ``deadline_s`` bounds the run to a control interval: once the run
        clock passes it, admission stops and the loop exits as soon as the
        in-flight slots drain — requests still waiting stay queued for the
        next run (the cluster's controlled-serving slice boundary, which
        leaves the engine reconfigure()-safe: idle slots, non-empty queue).

        ``on_tick`` is called once per scheduling iteration OUTSIDE the
        drive lock — the cluster's watchdog heartbeat and the test-only
        fault-injection point. After each call the poison pill is checked:
        a replica declared dead aborts here, at an iteration boundary,
        without touching re-homed state."""
        stats = ServeStats()
        self._done_now = []
        alloc_fail0 = self.pool.alloc_failures if self.paged else 0
        t0 = time.perf_counter()
        arr: deque = deque(
            sorted(arrivals, key=lambda a: a[0]) if arrivals else ()
        )
        with self._cancel_lock:  # see cancel(): no inline apply may overlap
            self._running = True
        try:
            while True:
                if on_tick is not None:
                    on_tick()
                if self._poisoned:
                    break
                now = time.perf_counter() - t0
                while arr and arr[0][0] <= now:
                    t_off, req = arr.popleft()
                    if gate is not None:
                        try:
                            gate(req)
                        except AdmissionRejected as rej:
                            req.finish_reason = "rejected"
                            req.reject_reason = rej.reason
                            req.submitted_at = t0 + t_off
                            req.done_at = time.perf_counter()
                            self.finished.append(req)
                            continue
                    self.submit(req)
                    # the TTFT clock starts at the SCHEDULED arrival, not at
                    # whenever the loop got around to polling the deque —
                    # otherwise time spent inside a blocking dispatch hides
                    # queueing delay from the latency stats
                    req.submitted_at = t0 + t_off
                stats.queue_peak = max(stats.queue_peak, len(self.waiting))
                expired = deadline_s is not None and now >= deadline_s
                if not (
                    any(r is not None for r in self.slot_req)
                    or (self.waiting and not expired)
                    or arr
                    or self._cancels
                ):
                    break
                with self._drive_lock:  # serialize vs inline cancel/step()
                    busy = self._service_once(stats, admit=not expired)
                if not busy and arr:
                    # idle until the next scheduled arrival
                    wait = arr[0][0] - (time.perf_counter() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.001))
            if not self._poisoned:
                with self._drive_lock:
                    self._drain_pending()
                    self._release_stopped(stats)
        finally:
            with self._cancel_lock:
                self._running = False
        stats.wall_seconds = time.perf_counter() - t0
        stats.kv_bytes_resident = max(
            stats.kv_bytes_resident, self.kv_bytes_resident()
        )
        if self.paged:
            stats.alloc_failures = self.pool.alloc_failures - alloc_fail0
        for req in self._done_now:
            if req.first_token_at is not None:
                stats.ttfts.append(req.first_token_at - req.submitted_at)
                if req.done_at is not None and req.n_generated >= 2:
                    stats.tpots.append(
                        max(req.done_at - req.first_token_at, 0.0)
                        / (req.n_generated - 1)
                    )
        return stats
