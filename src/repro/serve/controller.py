"""Closed-loop serving supervisor: reconfiguration, admission, failure.

This module is the scalar core of the paper's story, ported to serving:
Spatzformer's latency-tolerant scalar controller watches the workload and
re-homes the vector fabric (split for many independent small tasks, merge
for large uniform ones) because matching mode to workload — not the
datapath — is where mixed-workload utilization is won. Here the same
supervisor role is played by three cooperating pieces, all consumed by
:class:`repro.serve.cluster.ServeCluster`:

* :class:`ReconfigController` — watches a sliding window of live serving
  signals (queue depth, arrival mix, TTFT samples) and triggers
  split↔merge :meth:`ServeCluster.reconfigure` when the perfmodel's
  predicted win (:func:`repro.core.perfmodel.model_serving_mode`)
  exceeds the *measured* switch cost, with hysteresis, a confirmation
  streak, and a cooldown so it never flaps.
* :class:`AdmissionController` — the overload-survival layer at the
  submission boundary: per-tenant token buckets with priorities, a
  bounded queue with priority headroom, and deadline-based shedding
  (reject a request whose *predicted* TTFT exceeds its deadline instead
  of letting every queued request miss). All rejections are typed
  :class:`repro.serve.engine.AdmissionRejected`.
* :class:`FailurePolicy` — watchdog thresholds for split-mode controller
  threads; a replica whose heartbeat goes stale past ``dead_after`` is
  declared dead and its live requests are re-homed onto survivors via
  :func:`build_continuation`, bit-identically for seeded streams because
  ``fold_in(seed, position)`` keying makes every draw a function of the
  request's seed and absolute position, not of which engine draws it.

Everything here is host-side pure Python (no jax imports beyond what
``engine`` pulls in transitively) and unit-testable with a fake clock.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Mapping, Optional

import numpy as np

from repro.core.modes import Mode
from repro.core.perfmodel import (
    V5E,
    HardwareModel,
    ServingMix,
    serving_mode_advice,
)
from repro.serve.engine import AdmissionRejected, Request

__all__ = [
    "AdmissionController",
    "AdmissionPolicy",
    "ControllerConfig",
    "FailurePolicy",
    "ReconfigController",
    "SwitchDecision",
    "TenantPolicy",
    "WindowSample",
    "build_continuation",
    "model_token_cost",
    "plan_hetero_placement",
]


# ---------------------------------------------------------------------------
# reconfiguration controller
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WindowSample:
    """One control-interval observation of the live cluster."""

    t: float  # cluster-run clock (seconds since run start)
    mode: str  # "split" | "merge" at observation time
    queue_depth: int  # Σ len(waiting) over live engines
    n_requests: int  # arrivals admitted in the interval
    prompt_tokens: int  # Σ prompt length of those arrivals
    decode_tokens: int  # Σ max_new of those arrivals
    longest_tokens: int  # max max_new of any arrival
    n_tenants: int = 0  # distinct tenants in the interval
    ttft_p99: float = 0.0  # over requests finished in the interval
    tpot_p99: float = 0.0


@dataclass(frozen=True)
class SwitchDecision:
    """A committed controller decision: switch to ``mode`` because the
    windowed workload is predicted to run ``predicted_win_s`` faster
    there, which clears the (hysteresis-scaled) ``switch_cost_s``."""

    mode: Mode
    predicted_win_s: float
    switch_cost_s: float


@dataclass
class ControllerConfig:
    """Tuning knobs for :class:`ReconfigController`.

    ``cold_switch_s`` / ``warm_switch_s`` seed the switch-cost estimate
    with the repo's measured reconfigure costs (~60ms cold / ~6ms warm,
    see ``serving_bench --cluster``); every observed
    :class:`~repro.serve.cluster.ReconfigureReport` refines them by EWMA.
    """

    interval_s: float = 0.25  # control-loop slice length
    window_s: float = 1.0  # sliding window the mix is folded over
    cooldown_s: float = 1.0  # min seconds between committed switches
    hysteresis: float = 1.5  # required win = hysteresis × switch cost
    confirm: int = 2  # consecutive intervals agreeing before a switch
    cold_switch_s: float = 0.060
    warm_switch_s: float = 0.006
    cost_ewma: float = 0.5  # weight of a new measured switch cost
    # per-token model costs (for_cluster() derives them from the params)
    flops_per_token: float = 2e9
    hbm_bytes_per_token: float = 1e9
    coll_bytes_per_token: float = 1e5
    prefill_budget: int = 64
    max_chunk: int = 8
    batch_slots: int = 4
    hw: HardwareModel = field(default_factory=lambda: V5E)


class ReconfigController:
    """Sliding-window split↔merge decision loop (host-side, pure).

    Call :meth:`observe` once per control interval with a
    :class:`WindowSample`; it returns a :class:`SwitchDecision` when — and
    only when — all four gates pass:

    1. the perfmodel prefers the *other* mode for the windowed mix,
    2. the predicted win exceeds ``hysteresis ×`` the (measured) switch
       cost — marginal wins never pay for a move,
    3. the preference held for ``confirm`` consecutive intervals — one
       noisy window never triggers,
    4. ``cooldown_s`` has elapsed since the last committed switch — the
       controller cannot flap even under an adversarial oscillating load.

    After actually reconfiguring, report back via :meth:`note_switched`
    so the cooldown clock and the measured-cost EWMA advance.
    """

    def __init__(
        self, n_devices: int, config: Optional[ControllerConfig] = None
    ) -> None:
        self.cfg = config if config is not None else ControllerConfig()
        self.n_devices = max(int(n_devices), 1)
        self.samples: deque[WindowSample] = deque()
        self.switch_times: list[float] = []  # observation clocks of commits
        self.decisions: list[SwitchDecision] = []
        self._last_switch_t = -math.inf
        self._streak_mode: Optional[str] = None
        self._streak = 0
        self._cost = {
            "cold": self.cfg.cold_switch_s,
            "warm": self.cfg.warm_switch_s,
        }

    @property
    def interval_s(self) -> float:
        return self.cfg.interval_s

    @classmethod
    def for_cluster(cls, cluster, **overrides) -> "ReconfigController":
        """Build a controller whose per-token model costs come from the
        cluster's own parameters (weights bytes ≈ HBM stream per step;
        ~2 FLOPs per weight per token) and whose scheduling constants
        mirror the cluster's engine kwargs."""
        import jax
        import numpy as np

        from repro.common.utils import pytree_bytes

        # measure the RESIDENT tree (a live engine's, if one exists): with
        # int8 weight serving the engines hold ~4x fewer bytes than the f32
        # tree the cluster was constructed with, and the per-step HBM
        # stream follows the resident bytes while the FLOPs follow the
        # weight COUNT — the two must be derived independently, never as
        # bytes/4 (that assumption only held when every param was f32)
        engines = cluster._fabrics.get(cluster.mode) or []
        tree = engines[0].params if engines else cluster.params
        pb = float(pytree_bytes(tree))
        n_weights = float(
            sum(int(np.prod(leaf.shape)) for leaf in jax.tree.leaves(tree))
        )
        kw = cluster._engine_kw
        cfg_kw = dict(
            flops_per_token=2.0 * n_weights,  # ~2 FLOPs per weight per token
            hbm_bytes_per_token=pb,
            prefill_budget=kw.get("prefill_budget", 64),
            max_chunk=kw.get("max_chunk", 8),
            batch_slots=kw.get("batch_slots", 4),
        )
        cfg_kw.update(overrides)
        return cls(len(cluster.devices), ControllerConfig(**cfg_kw))

    def switch_cost(self, warm: bool) -> float:
        return self._cost["warm" if warm else "cold"]

    def _window_mix(self) -> Optional[ServingMix]:
        cfg = self.cfg
        n_req = sum(s.n_requests for s in self.samples)
        if n_req <= 0:
            return None
        return ServingMix(
            n_requests=n_req,
            prompt_tokens=float(sum(s.prompt_tokens for s in self.samples)),
            decode_tokens=float(sum(s.decode_tokens for s in self.samples)),
            longest_tokens=float(
                max(s.longest_tokens for s in self.samples)
            ),
            flops_per_token=cfg.flops_per_token,
            hbm_bytes_per_token=cfg.hbm_bytes_per_token,
            coll_bytes_per_token=cfg.coll_bytes_per_token,
            prefill_budget=cfg.prefill_budget,
            max_chunk=cfg.max_chunk,
            batch_slots=cfg.batch_slots,
        )

    def observe(
        self, sample: WindowSample, *, warm_target: bool = False
    ) -> Optional[SwitchDecision]:
        cfg = self.cfg
        self.samples.append(sample)
        while self.samples and sample.t - self.samples[0].t > cfg.window_s:
            self.samples.popleft()
        mix = self._window_mix()
        if mix is None:  # idle window: hold mode, decay nothing
            self._streak_mode, self._streak = None, 0
            return None
        best, seconds = serving_mode_advice(mix, self.n_devices, cfg.hw)
        if best == sample.mode:
            self._streak_mode, self._streak = None, 0
            return None
        win = seconds[sample.mode] - seconds[best]
        cost = self.switch_cost(warm_target)
        if win <= cfg.hysteresis * cost:
            self._streak_mode, self._streak = None, 0
            return None
        if self._streak_mode == best:
            self._streak += 1
        else:
            self._streak_mode, self._streak = best, 1
        if self._streak < cfg.confirm:
            return None
        if sample.t - self._last_switch_t < cfg.cooldown_s:
            return None
        return SwitchDecision(
            mode=Mode.parse(best), predicted_win_s=win, switch_cost_s=cost
        )

    def note_switched(self, t: float, report=None) -> None:
        """Commit a decision: start the cooldown clock at observation
        time ``t`` and fold the measured switch cost (a
        ``ReconfigureReport``) into the warm/cold EWMA estimates."""
        self._last_switch_t = t
        self.switch_times.append(t)
        self._streak_mode, self._streak = None, 0
        if report is not None:
            kind = "warm" if getattr(report, "cached", False) else "cold"
            a = self.cfg.cost_ewma
            self._cost[kind] = (
                (1 - a) * self._cost[kind] + a * float(report.seconds)
            )


# ---------------------------------------------------------------------------
# admission control / load shedding
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant admission terms. ``rate`` refills a token bucket in
    *cost tokens* per second (cost of a request = prompt + max_new);
    ``burst`` caps the bucket. ``priority > 0`` rides the deeper queue
    bound (``max_queue × priority_headroom``) before hitting
    ``queue_full`` — priority buys headroom, not starvation of others."""

    rate: float = math.inf
    burst: float = math.inf
    priority: int = 0


@dataclass
class AdmissionPolicy:
    """Cluster-wide admission configuration (see AdmissionController)."""

    max_queue: Optional[int] = None  # per-target-replica waiting bound
    tenants: Mapping[str, TenantPolicy] = field(default_factory=dict)
    default: TenantPolicy = field(default_factory=TenantPolicy)
    priority_headroom: float = 2.0
    # seeds the TTFT predictor before any service-rate feedback arrives;
    # None disables deadline shedding until the first measured rate
    initial_tok_per_s: Optional[float] = None
    rate_ewma: float = 0.5


class _Bucket:
    def __init__(self, pol: TenantPolicy, now: float) -> None:
        self.pol = pol
        self.level = pol.burst
        self.last = now

    def refill(self, now: float) -> None:
        if math.isfinite(self.pol.rate):
            self.level = min(
                self.pol.burst, self.level + (now - self.last) * self.pol.rate
            )
        self.last = now

    def peek(self, cost: float) -> bool:
        return self.level >= cost or not math.isfinite(self.pol.burst)

    def take(self, cost: float) -> None:
        if math.isfinite(self.pol.burst):
            self.level -= cost


class AdmissionController:
    """Submit-time gate: every request passes (in order) the tenant rate
    bucket, the bounded queue, and the deadline predictor before it may
    join a replica's waiting queue. Rejections raise
    :class:`AdmissionRejected` and are counted by reason; the bucket is
    only debited for requests that actually pass every gate.

    TTFT prediction is deliberately crude and cheap: predicted TTFT =
    (cost tokens already queued ahead) / (EWMA of the measured per-replica
    service rate). Crude is enough — under overload the queue cost grows
    without bound, so *any* consistent rate estimate separates requests
    that will meet their deadline from those that cannot.
    """

    def __init__(
        self,
        policy: Optional[AdmissionPolicy] = None,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.policy = policy if policy is not None else AdmissionPolicy()
        self.clock = clock
        self._buckets: dict[Optional[str], _Bucket] = {}
        self._rate = self.policy.initial_tok_per_s
        # split-mode replica threads gate concurrently (engine.run's
        # arrival hook) — buckets and counters share one lock
        self._lock = threading.Lock()
        self.admitted = 0
        self.shed = 0  # shed_deadline
        self.rate_limited = 0
        self.queue_full = 0

    @property
    def rejected(self) -> int:
        """Non-deadline rejections (rate_limited + queue_full)."""
        return self.rate_limited + self.queue_full

    def note_service_rate(self, tok_per_s: float) -> None:
        """Feed back a measured per-replica service rate (tokens/sec)."""
        if tok_per_s <= 0:
            return
        with self._lock:
            if self._rate is None:
                self._rate = tok_per_s
            else:
                a = self.policy.rate_ewma
                self._rate = (1 - a) * self._rate + a * tok_per_s

    def predict_ttft(self, queue_cost: float) -> float:
        """Seconds until a request behind ``queue_cost`` tokens starts."""
        if self._rate is None:
            return 0.0
        return queue_cost / max(self._rate, 1e-9)

    @staticmethod
    def request_cost(req: Request) -> float:
        return float(len(req.prompt) + req.params.max_new)

    def admit(
        self, req: Request, *, queue_depth: int, queue_cost: float
    ) -> None:
        """Gate one request against the target replica's queue state.
        Raises :class:`AdmissionRejected`; returns None on admission."""
        pol = self.policy.tenants.get(req.tenant, self.policy.default)
        now = self.clock()
        cost = self.request_cost(req)
        with self._lock:
            bucket = self._buckets.get(req.tenant)
            if bucket is None:
                bucket = self._buckets[req.tenant] = _Bucket(pol, now)
            bucket.refill(now)
            if not bucket.peek(cost):
                self.rate_limited += 1
                raise AdmissionRejected(
                    "rate_limited",
                    f"tenant {req.tenant!r} over rate "
                    f"({bucket.level:.0f} of {cost:.0f} cost tokens "
                    "available)",
                )
            if self.policy.max_queue is not None:
                bound = self.policy.max_queue * (
                    self.policy.priority_headroom if pol.priority > 0 else 1.0
                )
                if queue_depth >= bound:
                    self.queue_full += 1
                    raise AdmissionRejected(
                        "queue_full",
                        f"{queue_depth} waiting >= bound {bound:.0f} "
                        f"(tenant {req.tenant!r} priority {pol.priority})",
                    )
            if req.deadline_s is not None and self._rate is not None:
                eta = queue_cost / max(self._rate, 1e-9)
                if eta > req.deadline_s:
                    self.shed += 1
                    raise AdmissionRejected(
                        "shed_deadline",
                        f"predicted TTFT {eta:.3f}s > deadline "
                        f"{req.deadline_s:.3f}s",
                    )
            bucket.take(cost)
            self.admitted += 1


# ---------------------------------------------------------------------------
# replica-failure policy + re-homing continuation
# ---------------------------------------------------------------------------


@dataclass
class FailurePolicy:
    """Watchdog thresholds for split-mode controller threads.

    Each replica's serving loop beats a heartbeat lane once per
    scheduling iteration; a lane stale past ``straggler_after`` is
    flagged, past ``dead_after`` the replica is declared dead and its
    live requests re-home onto survivors. ``tick_hook(replica_idx)`` is
    an instrumentation point called on the replica's own thread every
    iteration (after the beat) — tests inject stalls through it.

    Heartbeats fire at scheduling-iteration boundaries, so ``dead_after``
    must exceed the worst-case single iteration — including cold prefill
    compiles, which can take seconds. Prewarm the cluster (compiles off
    the serving path) or set ``dead_after`` accordingly; otherwise a
    replica mid-compile reads as dead and gets needlessly retired."""

    straggler_after: float = 0.5
    dead_after: float = 2.0
    poll: float = 0.02
    tick_hook: Optional[Callable[[int], None]] = None


def model_token_cost(cfg) -> float:
    """Relative per-decode-token serving cost of one architecture.

    The heterogeneous placement planner only needs *ratios* between the
    models sharing a cluster, so this is a deliberately small perfmodel
    keyed on what each family's decode step actually streams per token:

    * attention families are HBM-bound on the KV read — cost ∝ layers ×
      bytes-per-position row. MLA reads the compressed latent row
      (``kv_lora_rank + rope_head_dim``); GQA reads ``2 × n_kv_heads ×
      head_dim``.
    * SSM families never touch a growing cache — the recurrence is
      flops-bound on the state update: cost ∝ layers × inner width
      (``d_model × expand``) × state size, scaled down by the hardware's
      flops:HBM byte ratio stand-in (the constant only shifts SSM vs
      attention weighting, not SSM vs SSM).

    Hybrids take the max of their two lanes (the decode step runs both).
    """
    L = cfg.n_layers
    attn_row = 0.0
    if cfg.mla is not None:
        attn_row = float(cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim)
    elif cfg.family in ("dense", "moe", "hybrid"):
        attn_row = float(2 * cfg.n_kv_heads * cfg.head_dim)
    ssm_cost = 0.0
    if cfg.ssm is not None:
        inner = cfg.d_model * cfg.ssm.expand
        # ~flops:bytes ratio stand-in; keeps SSM state math comparable
        # to an HBM row read rather than dominating it.
        ssm_cost = inner * cfg.ssm.state / 256.0
    return float(L) * max(attn_row * 4.0, ssm_cost, 1.0)  # f32 bytes/row


def plan_hetero_placement(
    model_cfgs: Mapping[str, Any], n_devices: int
) -> dict[str, int]:
    """Replica counts per model for a split cluster of ``n_devices``.

    Every model gets at least one replica (a model with zero replicas
    cannot serve at all — availability beats proportionality); the
    remaining devices go to models by largest remainder on their
    :func:`model_token_cost` weights, so the expensive-per-token model
    gets the capacity. Deterministic: ties break on insertion order of
    ``model_cfgs``.
    """
    names = list(model_cfgs)
    if not names:
        raise ValueError("plan_hetero_placement: no models")
    if n_devices < len(names):
        raise ValueError(
            f"{len(names)} models need at least {len(names)} devices; "
            f"have {n_devices}"
        )
    costs = {n: model_token_cost(model_cfgs[n]) for n in names}
    total = sum(costs.values())
    counts = {n: 1 for n in names}
    spare = n_devices - len(names)
    if spare:
        quotas = {n: spare * costs[n] / total for n in names}
        floors = {n: int(math.floor(quotas[n])) for n in names}
        for n in names:
            counts[n] += floors[n]
        left = spare - sum(floors.values())
        by_rem = sorted(
            names, key=lambda n: (-(quotas[n] - floors[n]), names.index(n))
        )
        for n in by_rem[:left]:
            counts[n] += 1
    return counts


def build_continuation(req: Request) -> tuple[Request, int]:
    """(continuation, committed) for re-homing a partially-served request.

    The continuation's prompt is the original prompt plus the
    ``committed`` tokens already harvested to the host; its budget is the
    remainder. Because the engine feeds the whole prompt before sampling
    and keys every draw by ``fold_in(seed, absolute_position)``, the
    continuation's first draw lands at exactly the position the original
    stream would have sampled next — a seeded re-homed stream is
    bit-identical to the uninterrupted one. Unharvested in-flight draws
    on the dead replica are simply re-derived (same key, same value).

    A request the engine never bound keeps ``params.seed`` as given (the
    survivor assigns its own key only if seed is None *and* the stream
    never started — in which case no draw was committed either, so any
    seed is consistent). A bound request pins the engine-assigned seed so
    the survivor continues the *same* stream.
    """
    committed = len(req.generated)
    seed = req.params.seed
    if seed is None and getattr(req, "_bound", False):
        seed = req._seed
    cont = Request(
        rid=req.rid,
        prompt=np.concatenate(
            [
                np.asarray(req.prompt, np.int32),
                np.asarray(req.generated, np.int32),
            ]
        ),
        params=replace(
            req.params, max_new=req.params.max_new - committed, seed=seed
        ),
        tenant=req.tenant,
        model=req.model,
    )
    return cont, committed
