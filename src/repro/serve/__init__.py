from repro.serve.engine import Request, ServeEngine, ServeStats

__all__ = ["ServeEngine", "Request", "ServeStats"]
