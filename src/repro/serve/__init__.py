"""Public serving surface.

The request API is :class:`Request` + :class:`SamplingParams` (frozen
per-request sampling/termination config) → :meth:`ServeEngine.submit`
returns a :class:`RequestHandle` (incremental token iterator +
``cancel()``); :class:`ServeCluster` serves the same surface over a
split/merge multi-device fabric with per-tenant default params.

Deprecation shims: the pre-SamplingParams kwargs
``Request(max_new=..., temperature=...)`` still work (they build the
equivalent ``params`` and warn ``DeprecationWarning``); migrate to
``Request(..., params=SamplingParams(...))``.
"""

from repro.serve.backend import (
    DefaultBackend,
    DeviceBackend,
    PlacementBackend,
    ShardedBackend,
)
from repro.serve.cluster import (
    ClusterStats,
    NoModelReplica,
    ReconfigureReport,
    Router,
    ServeCluster,
)
from repro.serve.controller import (
    AdmissionController,
    AdmissionPolicy,
    ControllerConfig,
    FailurePolicy,
    ReconfigController,
    SwitchDecision,
    TenantPolicy,
    WindowSample,
    model_token_cost,
    plan_hetero_placement,
)
from repro.serve.engine import (
    AdmissionRejected,
    Request,
    RequestHandle,
    ServeEngine,
    ServeStats,
)
from repro.serve.kv_pool import BlockPool, PoolStats, blocks_for
from repro.serve.prefix_cache import PrefixStats, RadixPrefixCache
from repro.serve.sampling import (
    MAX_LOGIT_BIAS,
    SamplingParams,
    fused_sample,
    spec_verify,
)
from repro.serve.speculate import (
    ModelDrafter,
    NGramDrafter,
    SpeculateConfig,
    build_drafter,
)

__all__ = [
    # request lifecycle
    "Request",
    "SamplingParams",
    "RequestHandle",
    "MAX_LOGIT_BIAS",
    # engines
    "ServeEngine",
    "ServeStats",
    "fused_sample",
    # cluster
    "ServeCluster",
    "ClusterStats",
    "ReconfigureReport",
    "Router",
    # heterogeneous serving (multi-model split clusters)
    "NoModelReplica",
    "model_token_cost",
    "plan_hetero_placement",
    # supervision: reconfiguration control, admission, failure recovery
    "ReconfigController",
    "ControllerConfig",
    "SwitchDecision",
    "WindowSample",
    "AdmissionController",
    "AdmissionPolicy",
    "AdmissionRejected",
    "TenantPolicy",
    "FailurePolicy",
    # speculative decoding
    "SpeculateConfig",
    "NGramDrafter",
    "ModelDrafter",
    "build_drafter",
    "spec_verify",
    # paged KV
    "BlockPool",
    "PoolStats",
    "blocks_for",
    "RadixPrefixCache",
    "PrefixStats",
    # placement
    "PlacementBackend",
    "DefaultBackend",
    "DeviceBackend",
    "ShardedBackend",
]
