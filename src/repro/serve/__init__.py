from repro.serve.backend import (
    DefaultBackend,
    DeviceBackend,
    PlacementBackend,
    ShardedBackend,
)
from repro.serve.cluster import (
    ClusterStats,
    ReconfigureReport,
    Router,
    ServeCluster,
)
from repro.serve.engine import Request, ServeEngine, ServeStats

__all__ = [
    "ServeEngine",
    "Request",
    "ServeStats",
    "ServeCluster",
    "ClusterStats",
    "ReconfigureReport",
    "Router",
    "PlacementBackend",
    "DefaultBackend",
    "DeviceBackend",
    "ShardedBackend",
]
