"""Radix tree over prompt token blocks: prefix reuse for the paged engine.

Identical system prompts are the common case at serving scale — every
request of a tenant opens with the same instruction block. The dense
engine prefills (and stores) that prefix once PER REQUEST; with the paged
pool (:mod:`repro.serve.kv_pool`) the K/V of a prompt prefix lives in
pool blocks that any later request can reference through its own block
table, so the tree below lets admission skip both the prefill compute and
the storage for every full block it has seen before.

Structure: a radix tree where each edge consumes exactly ``block_size``
prompt tokens (one KV block). A node owns one pool block — the block
holding the K/V for those positions, computed by whichever request first
ran that prefix — plus one refcount on it, so the block outlives the
request that filled it. ``match`` walks full blocks of a new prompt and
returns the shared block ids; ``insert`` is called once a prompt finishes
prefilling and registers its full prompt blocks.

Sharing is block-aligned copy-on-write: a matched request's table starts
with shared (read-only) block ids and continues with freshly allocated
private ones, and the engine feeds the prompt from the first unmatched
position — divergence inside a block is simply never matched, so the
diverging block is recomputed privately and no mid-block copy ever
happens. Matching is additionally capped at ``len(prompt) - 1`` tokens:
the engine always recomputes at least the final prompt token, whose
logits seed the first sampled token.

Eviction is LRU over leaf nodes whose block has no live referent besides
the tree itself (refcount 1): admission under pool pressure calls
``evict(n)`` before making a request wait. Interior nodes become
evictable as their children go.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.serve.kv_pool import BlockPool


@dataclass
class PrefixStats:
    lookups: int
    hits: int  # lookups that matched >= 1 block
    hit_tokens: int  # prompt tokens skipped via the tree, cumulative
    inserts: int
    nodes: int
    evictions: int


class _Node:
    __slots__ = ("children", "block", "parent", "key", "stamp")

    def __init__(self, parent: Optional["_Node"], key, block: int, stamp: int):
        self.children: dict[tuple, _Node] = {}
        self.parent = parent
        self.key = key  # edge label: tuple of block_size token ids
        self.block = block  # pool block id (-1 on the root)
        self.stamp = stamp  # LRU clock at last touch


class RadixPrefixCache:
    """Block-granular radix tree sharing prompt-prefix KV blocks.

    The tree holds ONE pool reference per node; requests that match a node
    acquire their own reference, so a block is freed only when the tree
    evicts it AND no matched request is still reading it.
    """

    def __init__(self, pool: BlockPool, block_size: int) -> None:
        self.pool = pool
        self.block_size = int(block_size)
        self._root = _Node(None, None, -1, 0)
        self._clock = 0
        self._nodes = 0
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserts = 0
        self.evictions = 0

    # ------------------------------------------------------------------ keys

    def _keys(self, prompt: np.ndarray, n_blocks: int):
        bs = self.block_size
        p = np.asarray(prompt)
        for i in range(n_blocks):
            yield tuple(int(t) for t in p[i * bs : (i + 1) * bs])

    # ----------------------------------------------------------------- match

    def match(self, prompt: np.ndarray) -> tuple[list[int], int]:
        """Longest shared prefix of ``prompt`` present in the tree.

        Returns ``(blocks, matched_tokens)``: the shared pool block ids (a
        reference is ACQUIRED on each — the caller owns them exactly like
        freshly allocated blocks and must release them on finish/cancel or
        on an aborted admission) and the token count they cover. Matching
        stops at full blocks and never consumes the final prompt token,
        so the caller always has at least one position to prefill."""
        self.lookups += 1
        bs = self.block_size
        usable = (len(prompt) - 1) // bs  # full blocks, last token excluded
        node = self._root
        blocks: list[int] = []
        self._clock += 1
        for key in self._keys(prompt, usable):
            child = node.children.get(key)
            if child is None:
                break
            child.stamp = self._clock
            self.pool.acquire(child.block)
            blocks.append(child.block)
            node = child
        if blocks:
            self.hits += 1
            self.hit_tokens += len(blocks) * bs
        return blocks, len(blocks) * bs

    # ---------------------------------------------------------------- insert

    def insert(self, prompt: np.ndarray, table: list[int]) -> int:
        """Register a fully prefilled prompt's full blocks.

        ``table`` is the request's block table (shared prefix + private
        blocks, in position order). Each NEW node acquires a tree-owned
        reference on its block; an already-present prefix keeps the
        existing node's block (two requests that raced the same cold
        prefix simply never share — the loser's private copy frees with
        it). Returns the number of nodes added."""
        self.inserts += 1
        bs = self.block_size
        n_full = len(prompt) // bs
        node = self._root
        added = 0
        self._clock += 1
        for i, key in enumerate(self._keys(prompt, n_full)):
            child = node.children.get(key)
            if child is None:
                child = _Node(node, key, table[i], self._clock)
                node.children[key] = child
                self.pool.acquire(table[i])
                self._nodes += 1
                added += 1
            child.stamp = self._clock
            node = child
        return added

    # -------------------------------------------------------------- eviction

    def _evictable(self) -> list[_Node]:
        """Leaf nodes whose block only the tree still references."""
        out: list[_Node] = []
        stack = [self._root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self._root and not n.children:
                if self.pool.refcount[n.block] == 1:
                    out.append(n)
        return out

    def evict(self, n_blocks: int) -> int:
        """Free up to ``n_blocks`` pool blocks, LRU leaves first. Evicting
        a leaf may expose its parent; the scan repeats until satisfied or
        nothing else is evictable. Returns blocks actually freed."""
        freed = 0
        while freed < n_blocks:
            leaves = self._evictable()
            if not leaves:
                break
            leaves.sort(key=lambda n: n.stamp)
            for leaf in leaves:
                leaf.parent.children.pop(leaf.key)
                self.pool.release(leaf.block)
                self._nodes -= 1
                self.evictions += 1
                freed += 1
                if freed >= n_blocks:
                    break
        return freed

    # ----------------------------------------------------------------- misc

    def clear(self) -> None:
        """Drop the whole tree, releasing every tree-owned reference (so a
        standalone clear returns blocks nobody else holds to the free
        list; engine ``reset()`` additionally resets the pool after us)."""
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self.pool.release(n.block)
        self._root = _Node(None, None, -1, 0)
        self._nodes = 0
        self._clock = 0

    def stats(self) -> PrefixStats:
        return PrefixStats(
            lookups=self.lookups,
            hits=self.hits,
            hit_tokens=self.hit_tokens,
            inserts=self.inserts,
            nodes=self._nodes,
            evictions=self.evictions,
        )

    def __repr__(self) -> str:
        return f"RadixPrefixCache(bs={self.block_size}, nodes={self._nodes})"
