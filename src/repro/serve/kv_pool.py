"""Block-paged KV pool: the host-side allocator behind paged serving.

Dense serving reserves a worst-case ``[S_max, KV, hd]`` cache row per slot;
a slot serving a 40-token request pays for ``S_max`` positions. The paged
engine instead owns ONE device pool shaped ``[L, num_blocks, block_size,
KV, hd]`` and maps each request onto it through a per-request *block
table*: row ``i`` of a request's table names the pool block holding its
positions ``[i*block_size, (i+1)*block_size)``. A request then costs
``ceil(total_positions / block_size)`` blocks — its actual length, rounded
up to one block — and the freed worst-case headroom becomes extra resident
requests (see the ``_paged_capacity`` bench scenario).

This module is the HOST side only: a free list plus per-block reference
counts. Nothing here touches jax — the engine uploads the tables it builds
from these allocations, and the device indirection lives in the
``(block, offset)`` generalization of the ragged-attention descriptors
(``repro.kernels.ragged_attention.paged_ragged_attention``).

Refcounts make prefix sharing safe: a block referenced by a live request
AND retained by the radix prefix tree (:mod:`repro.serve.prefix_cache`)
holds one count per referent, and only drops back onto the free list when
the last referent releases it — a cancel mid-stream frees the cancelled
request's counts and nothing else.

The engine allocates a request's WHOLE worst-case table at admission
(``blocks_for(prompt, max_new, max_len)`` blocks, minus any prefix-shared
ones), so decode growth can never fail mid-stream: pool pressure surfaces
exactly once, at admission, where the engine can make the request wait.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def blocks_for(prompt_len: int, max_new: int, max_len: int, block_size: int) -> int:
    """Worst-case block count for one request: positions ``0 ..
    min(prompt_len + max_new, max_len) - 1``, rounded up to whole blocks.
    Admission reserves all of them up front — decode never allocates.

    Block counts are position counts, NOT bytes: what a block weighs in HBM
    depends on the pool leaves' dtypes (an int8 K/V row plus its f32 scale
    is ``head_dim + 4`` bytes per head vs f32's ``4 * head_dim``), so byte
    math lives in dtype-aware accounting (``PoolStats.kv_bytes_resident``,
    fed by the engine's measured per-block bytes) — never in a
    ``slots × f32`` assumption here."""
    total = min(prompt_len + max_new, max_len)
    return -(-total // block_size)


@dataclass
class PoolStats:
    num_blocks: int
    block_size: int
    free_blocks: int
    used_blocks: int
    allocs: int
    alloc_failures: int
    # actual HBM bytes of ONE pool block across every cache leaf (all L
    # layers, K + V payloads + any scale planes), measured from the live
    # pool's dtypes by the engine — 0 when the owner didn't wire it up
    bytes_per_block: int = 0

    @property
    def utilization(self) -> float:
        return self.used_blocks / max(self.num_blocks, 1)

    @property
    def kv_bytes_resident(self) -> int:
        """Dtype-aware resident KV bytes: used blocks × measured block
        weight. An int8 pool reports ~4x fewer bytes for the same block
        count — the number capacity planning should use."""
        return self.used_blocks * self.bytes_per_block


class BlockPool:
    """Free-list allocator with per-block refcounts over ``num_blocks``
    KV blocks of ``block_size`` positions each.

    ``alloc(n)`` hands out ``n`` blocks with refcount 1 (the requesting
    request's reference); ``acquire``/``release`` adjust the count for
    additional referents (the prefix tree, a prefix-matched request). A
    block returns to the free list when its count reaches zero. The
    allocator is deliberately LIFO (``alloc`` pops the most recently freed
    blocks): reusing warm block ids keeps the device-side pool accesses as
    temporally local as the dense engine's slot reuse.
    """

    def __init__(self, num_blocks: int, block_size: int) -> None:
        assert num_blocks > 0 and block_size > 0, (num_blocks, block_size)
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.refcount = np.zeros(self.num_blocks, np.int32)
        self._free: list[int] = list(range(self.num_blocks - 1, -1, -1))
        self.allocs = 0
        self.alloc_failures = 0
        # set by the engine from the live device pool's leaf dtypes (this
        # module never touches jax); 0 until wired
        self.bytes_per_block = 0

    # ------------------------------------------------------------ allocation

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.num_blocks - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Pop ``n`` blocks (refcount 1 each). Raises when the pool cannot
        satisfy the request — callers gate on :meth:`can_alloc` (the engine
        makes the request WAIT instead of crashing)."""
        if n > len(self._free):
            self.alloc_failures += 1
            raise RuntimeError(
                f"KV pool exhausted: need {n} blocks, {len(self._free)} free"
            )
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            assert self.refcount[b] == 0, (b, int(self.refcount[b]))
            self.refcount[b] = 1
        self.allocs += n
        return out

    # ------------------------------------------------------------ refcounts

    def acquire(self, block: int) -> None:
        """Add a reference to an already-live block (prefix share)."""
        assert self.refcount[block] > 0, block
        self.refcount[block] += 1

    def release(self, block: int) -> None:
        """Drop one reference; the block frees when the last holder lets go."""
        assert self.refcount[block] > 0, block
        self.refcount[block] -= 1
        if self.refcount[block] == 0:
            self._free.append(block)

    def release_all(self, blocks: list[int]) -> None:
        for b in blocks:
            self.release(b)

    # ----------------------------------------------------------------- misc

    def reset(self) -> None:
        """Drop every reference (engine ``reset()``: slots are empty and the
        prefix tree is being cleared with us)."""
        self.refcount[:] = 0
        self._free = list(range(self.num_blocks - 1, -1, -1))

    def stats(self) -> PoolStats:
        return PoolStats(
            num_blocks=self.num_blocks,
            block_size=self.block_size,
            free_blocks=self.free,
            used_blocks=self.used,
            allocs=self.allocs,
            alloc_failures=self.alloc_failures,
            bytes_per_block=self.bytes_per_block,
        )

    def __repr__(self) -> str:  # debugging aid
        return (
            f"BlockPool(blocks={self.num_blocks}, bs={self.block_size}, "
            f"free={self.free})"
        )
