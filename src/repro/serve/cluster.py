"""ServeCluster: the split/merge reconfigurable multi-device serving fabric.

Spatzformer's cluster-level thesis, lifted to serving (DESIGN.md maps the
temporal, single-device version; this module adds the spatial one):

* **SPLIT** — one independent :class:`~repro.serve.engine.ServeEngine`
  replica per mesh device, each pinned via a
  :class:`~repro.serve.backend.DeviceBackend` and driven by its own
  controller thread, behind a :class:`Router` doing join-shortest-queue
  with per-tenant affinity. Two latency-sensitive tenants proceed
  concurrently — the paper's two independent cores, the router playing the
  scalar control core.
* **MERGE** — ONE engine whose params and ``[L, B, S_max, KV, hd]`` KV
  cache are tensor-parallel over the ``model`` axis
  (``dist.sharding.spec_for_param`` / ``serve_cache_shardings``, attention
  heads partitioned — see ``models/attention._head_constraint``), its
  tick/admit/packed programs GSPMD-partitioned across every device: the
  fused fabric under one controller for large uniform work.
* **reconfigure(mode)** — drain in-flight chunks, re-place params/cache on
  the target fabric, resume; the wall-clock cost is measured and reported
  (:class:`ReconfigureReport`) like the paper's CSR-write cost. A
  previously-built fabric is kept warm, so switching BACK is just an
  engine reset — the second half of "reconfiguration is cheap and off the
  hot path".

Both modes serve any greedy request stream with bit-identical outputs to a
plain single-device engine (pinned by ``tests/test_multidev.py``): the
cluster changes WHERE work runs, never what is computed.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence

import jax

from repro.common.utils import pytree_bytes
from repro.core.modes import Mode
from repro.dist.sharding import serving_mesh_info
from repro.models.model import LM
from repro.serve.backend import DeviceBackend, ShardedBackend
from repro.serve.engine import (
    Request,
    RequestHandle,
    ServeEngine,
    ServeStats,
    percentile,
)
from repro.serve.sampling import SamplingParams


# =============================================================================
# router (split mode's scalar control core)
# =============================================================================


class Router:
    """Join-shortest-queue request routing with per-tenant affinity.

    Queue length is the cumulative admitted cost (prompt + decode tokens)
    per replica — routing happens at submit time, so balance is over
    assigned work, not instantaneous occupancy. A request carrying a
    ``tenant`` sticks to the replica its tenant first landed on (KV/prefix
    locality and per-tenant isolation beat perfect balance); tenant-less
    requests always take the shortest queue, ties to the lowest index.
    """

    def __init__(self, n_replicas: int) -> None:
        self.n = n_replicas
        self.load = [0.0] * n_replicas
        self.assigned = [0] * n_replicas
        self.tenant_home: dict[str, int] = {}

    @staticmethod
    def cost(req: Request) -> float:
        return float(len(req.prompt) + req.max_new)

    def route(self, req: Request) -> int:
        if req.tenant is not None and req.tenant in self.tenant_home:
            i = self.tenant_home[req.tenant]
        else:
            i = min(range(self.n), key=lambda j: (self.load[j], j))
            if req.tenant is not None:
                self.tenant_home[req.tenant] = i
        self.load[i] += self.cost(req)
        self.assigned[i] += 1
        return i

    def unassign(self, replica: int, req: Request) -> None:
        """Credit back a routed-but-unserved request (it is about to be
        carried across a reconfigure and re-routed): without this, carried
        requests would double-count in the JSQ load and the per-replica
        ``assigned`` telemetry."""
        self.load[replica] -= self.cost(req)
        self.assigned[replica] -= 1


# =============================================================================
# stats
# =============================================================================


@dataclass
class ReconfigureReport:
    """Cost of one mode switch — the paper's CSR-write number.

    ``drain_seconds`` is the time spent finishing in-flight chunks after
    the switch was requested; ``place_seconds`` the re-placement of
    params/cache onto the target fabric (``bytes_moved`` counts what was
    placed; 0 and ``cached=True`` for a warm switch back to an
    already-built fabric, where only the engine state resets)."""

    from_mode: str
    to_mode: str
    drain_seconds: float
    place_seconds: float
    bytes_moved: int
    cached: bool

    @property
    def seconds(self) -> float:
        return self.drain_seconds + self.place_seconds

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        kind = "warm" if self.cached else "cold"
        return (
            f"reconfigure {self.from_mode}->{self.to_mode} ({kind}): "
            f"{self.seconds*1e3:.1f}ms (drain {self.drain_seconds*1e3:.1f} + "
            f"place {self.place_seconds*1e3:.1f}), "
            f"{self.bytes_moved/1e6:.2f} MB placed"
        )


@dataclass
class SegmentStats:
    """One constant-mode stretch of a cluster run."""

    mode: str
    replicas: list[ServeStats]

    @property
    def wall_seconds(self) -> float:
        return max((r.wall_seconds for r in self.replicas), default=0.0)


@dataclass
class ClusterStats:
    """Aggregate over every segment/replica of one ``ServeCluster.run``."""

    mode: str  # e.g. "split" or "split->merge"
    segments: list[SegmentStats]
    reconfigures: list[ReconfigureReport] = field(default_factory=list)

    def _each(self, attr: str) -> list:
        return [getattr(r, attr) for s in self.segments for r in s.replicas]

    @property
    def total_tokens(self) -> int:
        return sum(self._each("total_tokens"))

    @property
    def total_requests(self) -> int:
        return sum(self._each("total_requests"))

    @property
    def ticks(self) -> int:
        return sum(self._each("ticks"))

    @property
    def prefill_compiles(self) -> int:
        return sum(self._each("prefill_compiles"))

    @property
    def cancelled(self) -> int:
        return sum(self._each("cancelled"))

    @property
    def spec_proposed(self) -> int:
        return sum(self._each("spec_proposed"))

    @property
    def spec_accepted(self) -> int:
        return sum(self._each("spec_accepted"))

    @property
    def spec_ticks(self) -> int:
        return sum(self._each("spec_ticks"))

    @property
    def spec_acceptance(self) -> float:
        return self.spec_accepted / max(self.spec_proposed, 1)

    @property
    def wall_seconds(self) -> float:
        # replicas within a segment run concurrently (max); segments and
        # reconfigurations are sequential (sum). A reconfigure's DRAIN
        # already lives inside the preceding segment's wall — only the
        # re-placement extends the clock.
        return sum(s.wall_seconds for s in self.segments) + sum(
            r.place_seconds for r in self.reconfigures
        )

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_seconds, 1e-9)

    @property
    def ttfts(self) -> list[float]:
        return [t for xs in self._each("ttfts") for t in xs]

    @property
    def tpots(self) -> list[float]:
        return [t for xs in self._each("tpots") for t in xs]

    @property
    def ttft_p50(self) -> float:
        return percentile(self.ttfts, 50)

    @property
    def ttft_p99(self) -> float:
        return percentile(self.ttfts, 99)

    @property
    def tpot_p50(self) -> float:
        return percentile(self.tpots, 50)

    @property
    def tpot_p99(self) -> float:
        return percentile(self.tpots, 99)


# =============================================================================
# cluster
# =============================================================================


class ServeCluster:
    """Reconfigurable multi-device serving: split replicas or one merged
    tensor-parallel engine over the same devices, switchable at runtime.

    Construction places the initial mode's fabric; ``submit``/``run``
    mirror :class:`ServeEngine` (``run`` returns :class:`ClusterStats`).
    ``reconfigure(mode)`` switches fabrics between runs;
    ``run(reconfigure_schedule=[(t, mode), ...])`` switches mid-stream —
    the cluster drains in-flight work at each switch point, re-homes, and
    resumes with the remaining arrivals.
    """

    def __init__(
        self,
        model: LM,
        params,
        *,
        mode: Mode | str = Mode.SPLIT,
        devices: Optional[Sequence] = None,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
        unified: Optional[bool] = None,
        prefill_budget: int = 64,
        max_chunk: int = 8,
        kv_block_size: Optional[int] = None,
        num_blocks: Optional[int] = None,
        prefix_cache: bool = False,
        speculate=None,
        tenant_defaults: Optional[Mapping[str, SamplingParams]] = None,
    ) -> None:
        self.model = model
        self.params = params
        self.devices = list(devices) if devices is not None else list(jax.devices())
        assert self.devices, "ServeCluster needs at least one device"
        self.seed = seed
        # paged kwargs pass straight through: split mode gets one
        # independent pool + prefix tree PER replica (tenant-affinity
        # routing then doubles as prefix locality — a tenant's repeated
        # system prompt stays hot on its home replica's tree)
        self._engine_kw = dict(
            batch_slots=batch_slots,
            max_len=max_len,
            unified=unified,
            prefill_budget=prefill_budget,
            max_chunk=max_chunk,
            kv_block_size=kv_block_size,
            num_blocks=num_blocks,
            prefix_cache=prefix_cache,
            # each engine builds its own drafter from the config string —
            # a split replica drafts against its local slots, the merged
            # engine against the whole batch; seeded streams stay
            # bit-identical across modes because acceptance is exact-match
            # against the same fold_in(seed, position) draws
            speculate=speculate,
        )
        self.router = Router(len(self.devices))
        self.finished: list[Request] = []
        self.reconfigures: list[ReconfigureReport] = []
        # per-tenant default SamplingParams: a request submitted WITHOUT
        # explicit sampling config inherits its tenant's default at routing
        # time, before any engine sees it — so the defaults survive
        # split/merge switches and mid-stream reconfigure re-routing
        # unchanged (params are resolved once, at first submit)
        self.tenant_defaults: dict[str, SamplingParams] = dict(tenant_defaults or {})
        # which engine currently owns each live request (handles route
        # cancellation through this; reconfigure() re-homes the entries)
        self._where: dict[Request, ServeEngine] = {}
        self._fabrics: dict[Mode, list[ServeEngine]] = {}
        self.mode = Mode.parse(mode)
        self._ensure_fabric(self.mode)

    # ----------------------------------------------------------------- fabric

    @property
    def engines(self) -> list[ServeEngine]:
        return self._fabrics[self.mode]

    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    def _ensure_fabric(self, mode: Mode) -> tuple[bool, int]:
        """Build (or warm-reset) the engines for ``mode``.

        Returns ``(cached, bytes_placed)``: a cached fabric only resets its
        engines' tick state (compiled programs and placement survive)."""
        if mode in self._fabrics:
            for e in self._fabrics[mode]:
                e.reset()
            return True, 0
        if mode is Mode.MERGE:
            info = serving_mesh_info(self.devices)
            if info.model_size > 1:
                # a fresh LM view carrying the mesh: decode/packed attention
                # runs head-sharded (models/attention._head_constraint)
                model = LM(self.model.cfg, mesh_info=info)
                backend = ShardedBackend(info)
            else:  # one device: merge degenerates to a pinned plain engine
                model, backend = self.model, DeviceBackend(self.devices[0])
            engines = [
                ServeEngine(
                    model, self.params, seed=self.seed, backend=backend,
                    **self._engine_kw,
                )
            ]
        else:
            engines = [
                ServeEngine(
                    self.model, self.params, seed=self.seed + i,
                    backend=DeviceBackend(d), **self._engine_kw,
                )
                for i, d in enumerate(self.devices)
            ]
        jax.block_until_ready([e.params for e in engines])
        jax.block_until_ready([e.cache for e in engines])
        self._fabrics[mode] = engines
        placed = sum(pytree_bytes(e.params) + pytree_bytes(e.cache) for e in engines)
        return False, placed

    def prewarm(self, sampling: bool = False) -> None:
        """Compile every dispatch variant of the CURRENT mode's fabric off
        the serving path (replica prewarms run concurrently in split mode)."""
        engines = self.engines
        if len(engines) == 1:
            engines[0].prewarm(sampling)
            return
        with ThreadPoolExecutor(len(engines)) as ex:
            list(ex.map(lambda e: e.prewarm(sampling), engines))

    # ------------------------------------------------------------------ admit

    def submit(self, req: Request) -> RequestHandle:
        """Apply the tenant's default SamplingParams (if the request came
        without explicit config), route, and enqueue; returns a
        :class:`RequestHandle` owned by the cluster — its ``cancel()``
        follows the request to whichever engine currently holds it, across
        split/merge switches and mid-stream reconfiguration."""
        if req.tenant is not None and req.tenant in self.tenant_defaults:
            req.apply_default_params(self.tenant_defaults[req.tenant])
        engines = self.engines
        if self.mode is Mode.MERGE:  # one fused engine, no routing
            i = 0
        else:
            # split mode always routes — even a degenerate 1-replica fabric
            # keeps its JSQ/affinity telemetry truthful
            i = self.router.route(req)
        handle = engines[i].submit(req)
        handle._owner = self
        handle.replica = i
        self._where[req] = engines[i]
        return handle

    def cancel(self, req: Request) -> None:
        """Abort a request wherever it currently lives (handle plumbing).
        Cancelling a request that already finished is a no-op, matching
        the engine-level semantics (a client-side timeout racing normal
        completion must not crash)."""
        eng = self._where.get(req)
        if eng is None:
            if req.finish_reason is not None:
                return  # completed (and pruned from the ownership map)
            raise KeyError(f"request {req.rid} was never submitted to this cluster")
        eng.cancel(req)

    def _handle_pump(self, req: Request) -> None:
        """Progress hook for a blocked handle iterator: drive the owning
        engine when this thread can, politely poll when a controller
        thread owns it (split-mode replicas run under their own threads)."""
        eng = self._where.get(req)
        if eng is None or eng._running:
            time.sleep(2e-4)
            return
        eng._handle_pump(req)
        if req.complete:
            self._handle_done(req)

    def _handle_done(self, req: Request) -> None:
        """Drop a COMPLETE request from the ownership map — a purely
        handle-streamed request never passes through _run_segment's prune,
        and without this a run()-less cluster grows the map without bound.
        Only once complete (values harvested), never merely
        count-finished: the final chunk's tokens are still in flight when
        ``finish_reason`` lands, and the iterator needs the engine mapping
        to pump them home."""
        if req.complete:
            self._where.pop(req, None)

    # ------------------------------------------------------------ reconfigure

    def reconfigure(self, mode: Mode | str, drain_seconds: float = 0.0) -> ReconfigureReport:
        """Switch the serving fabric: collect undrained requests, re-place
        (or warm-reset) the target mode's engines, re-route the carried
        requests, and report the measured cost. Engines must be idle (no
        in-flight slots) — ``run()`` drains before returning, and the
        scheduled mid-stream path measures its drain into the report."""
        mode = Mode.parse(mode)
        carried: list[Request] = []
        routed = self.mode is not Mode.MERGE  # split queues went through JSQ
        for idx, e in enumerate(self.engines):
            assert all(r is None for r in e.slot_req), (
                "reconfigure() with in-flight slots; run() must drain first"
            )
            for r in e.waiting:
                if routed:  # re-routed below — give the JSQ load back
                    self.router.unassign(idx, r)
                carried.append(r)
            e.waiting.clear()
        carried.sort(key=lambda r: r.submitted_at)
        old = self.mode
        t0 = time.perf_counter()
        cached, placed = self._ensure_fabric(mode)
        place_s = time.perf_counter() - t0
        self.mode = mode
        for r in carried:
            t = r.submitted_at  # preserve the TTFT clock across the switch
            self.submit(r)  # re-homes _where, so live handles follow
            r.submitted_at = t
        rep = ReconfigureReport(
            str(old), str(mode), drain_seconds, place_s, placed, cached
        )
        self.reconfigures.append(rep)
        return rep

    # -------------------------------------------------------------------- run

    def _run_segment(self, seg_arrivals: list) -> SegmentStats:
        engines = self.engines
        # arrival-stream requests take the same intake path as submit():
        # tenant default params attach and the ownership map learns their
        # engine (so handle.cancel() reaches a request that arrived
        # mid-stream, and per-tenant policy is honoured either way)
        for _, req in seg_arrivals:
            if req.tenant is not None and req.tenant in self.tenant_defaults:
                req.apply_default_params(self.tenant_defaults[req.tenant])
        if self.mode is Mode.MERGE:
            for _, req in seg_arrivals:
                self._where[req] = engines[0]
            stats = [engines[0].run(arrivals=seg_arrivals or None)]
        else:
            per: list[list] = [[] for _ in engines]
            for t, req in seg_arrivals:
                i = self.router.route(req)
                per[i].append((t, req))
                self._where[req] = engines[i]
            if len(engines) == 1:  # degenerate split: no threads needed
                stats = [engines[0].run(arrivals=(per[0] or None))]
            else:
                # one controller thread per replica — the paper's "each core
                # driven by its own scalar core"; jax dispatch is thread-safe
                # across disjoint engines
                with ThreadPoolExecutor(len(engines)) as ex:
                    futs = [
                        ex.submit(e.run, arrivals=(pl or None))
                        for e, pl in zip(engines, per)
                    ]
                    stats = [f.result() for f in futs]
        for e, st in zip(engines, stats):
            # work served OUTSIDE run() — handle-driven streaming and idle
            # cancellations — landed in the engine's stream-stats; fold
            # every counter into this segment (and zero them) so
            # ClusterStats reports the whole session, not just the drains
            ss = e.stream_stats
            st.total_tokens += ss.total_tokens
            st.total_requests += ss.total_requests
            st.ticks += ss.ticks
            st.prefill_compiles += ss.prefill_compiles
            st.cancelled += ss.cancelled
            ss.total_tokens = ss.total_requests = ss.ticks = 0
            ss.prefill_compiles = ss.cancelled = 0
            self.finished.extend(e.finished)
            e.finished = []
        # drop completed requests from the ownership map (cancellation can
        # no longer reach them; keeps the map from growing unboundedly)
        self._where = {r: e for r, e in self._where.items() if r.finish_reason is None}
        return SegmentStats(str(self.mode), stats)

    def run(self, arrivals=None, reconfigure_schedule=None) -> ClusterStats:
        """Drain all submitted work (+ an optional open-loop ``arrivals``
        schedule), optionally switching modes mid-stream.

        ``reconfigure_schedule``: ``[(t_offset_seconds, mode), ...]`` —
        at each offset the cluster stops admitting, drains in-flight
        chunks, reconfigures, and resumes with the remaining arrivals.
        Arrival offsets stay anchored to the ORIGINAL stream clock: a
        segment's offsets are re-based by the wall time already consumed
        (serving + drain + re-placement), going negative when the switch
        overran an arrival — the engine then submits it immediately with
        its true scheduled ``submitted_at``, so reconfiguration latency
        SHOWS UP in TTFT instead of hiding behind a restarted clock (the
        same no-hiding rule as the engine's own arrival handling)."""
        schedule = sorted(reconfigure_schedule or [], key=lambda x: x[0])
        arr = sorted(arrivals or [], key=lambda a: a[0])
        segments: list[SegmentStats] = []
        reports: list[ReconfigureReport] = []
        elapsed = 0.0  # true wall time consumed before the current segment
        for idx in range(len(schedule) + 1):
            if idx < len(schedule):
                t_switch, nxt = schedule[idx]
                seg_arr = [(t - elapsed, r) for t, r in arr if t < t_switch]
                arr = [(t, r) for t, r in arr if t >= t_switch]
            else:
                t_switch, nxt = None, None
                seg_arr = [(t - elapsed, r) for t, r in arr]
            seg = self._run_segment(seg_arr)
            segments.append(seg)
            if t_switch is None:
                break
            drain = max(0.0, seg.wall_seconds - max(t_switch - elapsed, 0.0))
            rep = self.reconfigure(nxt, drain_seconds=drain)
            reports.append(rep)
            # drain already lives inside seg.wall_seconds; only the
            # re-placement extends the clock beyond the segment
            elapsed += seg.wall_seconds + rep.place_seconds
        modes = [s.mode for s in segments]
        # collapse only ADJACENT repeats: a split->merge->split round trip
        # must read as such, not dedupe to "split->merge"
        mode_label = "->".join(
            m for i, m in enumerate(modes) if i == 0 or modes[i - 1] != m
        )
        return ClusterStats(
            mode=mode_label,
            segments=segments,
            reconfigures=reports,
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"ServeCluster(mode={self.mode}, devices={len(self.devices)}, "
            f"replicas={self.n_replicas})"
        )
