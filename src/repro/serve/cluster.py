"""ServeCluster: the split/merge reconfigurable multi-device serving fabric.

Spatzformer's cluster-level thesis, lifted to serving (DESIGN.md maps the
temporal, single-device version; this module adds the spatial one):

* **SPLIT** — one independent :class:`~repro.serve.engine.ServeEngine`
  replica per mesh device, each pinned via a
  :class:`~repro.serve.backend.DeviceBackend` and driven by its own
  controller thread, behind a :class:`Router` doing join-shortest-queue
  with per-tenant affinity. Two latency-sensitive tenants proceed
  concurrently — the paper's two independent cores, the router playing the
  scalar control core.
* **MERGE** — ONE engine whose params and ``[L, B, S_max, KV, hd]`` KV
  cache are tensor-parallel over the ``model`` axis
  (``dist.sharding.spec_for_param`` / ``serve_cache_shardings``, attention
  heads partitioned — see ``models/attention._head_constraint``), its
  tick/admit/packed programs GSPMD-partitioned across every device: the
  fused fabric under one controller for large uniform work.
* **reconfigure(mode)** — drain in-flight chunks, re-place params/cache on
  the target fabric, resume; the wall-clock cost is measured and reported
  (:class:`ReconfigureReport`) like the paper's CSR-write cost. A
  previously-built fabric is kept warm, so switching BACK is just an
  engine reset — the second half of "reconfiguration is cheap and off the
  hot path".

Both modes serve any greedy request stream with bit-identical outputs to a
plain single-device engine (pinned by ``tests/test_multidev.py``): the
cluster changes WHERE work runs, never what is computed.

Robustness layers (all opt-in, see :mod:`repro.serve.controller`):

* ``admission=AdmissionPolicy(...)`` gates every request (submit() and the
  arrival stream alike) through per-tenant rate buckets, a bounded queue,
  and deadline-based shedding — typed ``AdmissionRejected`` either raises
  (submit) or marks the request ``finish_reason="rejected"`` (arrivals);
* ``failure=FailurePolicy(...)`` arms a watchdog over the split-mode
  controller threads: a replica whose heartbeat goes stale is declared
  dead and its live requests re-home onto survivors, bit-identically for
  seeded streams (``fold_in(seed, position)`` keying);
* ``run_controlled(...)`` closes the loop: serve in control intervals and
  let a :class:`~repro.serve.controller.ReconfigController` trigger
  split↔merge switches when the perfmodel-predicted win clears the
  measured switch cost.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence

import jax

from repro.common.utils import pytree_bytes
from repro.core.modes import Mode
from repro.dist.sharding import serving_mesh_info
from repro.ft.watchdog import Watchdog
from repro.models.model import LM
from repro.serve.backend import DeviceBackend, ShardedBackend
from repro.serve.controller import (
    AdmissionController,
    AdmissionPolicy,
    FailurePolicy,
    ReconfigController,
    WindowSample,
    build_continuation,
    plan_hetero_placement,
)
from repro.serve.engine import (
    AdmissionRejected,
    Request,
    RequestHandle,
    ServeEngine,
    ServeStats,
    percentile,
)
from repro.serve.sampling import SamplingParams


# =============================================================================
# router (split mode's scalar control core)
# =============================================================================


class NoModelReplica(AdmissionRejected):
    """No live replica serves the model a request is pinned to.

    A heterogeneous cluster pins one model per split replica; a request
    whose ``model`` names nothing in the placement — or whose model's
    replicas are all dead — cannot be served anywhere, and silently
    routing it to a *different* model would return the wrong
    distribution. Typed as an :class:`AdmissionRejected` (reason
    ``"infeasible"``) so the submit/arrival rejection plumbing treats it
    like any other capacity rejection."""

    def __init__(self, model: Optional[str], detail: str = "") -> None:
        self.model = model
        super().__init__(
            "infeasible",
            detail or f"no live replica serves model {model!r}",
        )


class Router:
    """Join-shortest-queue request routing with per-tenant affinity.

    Queue length is the cumulative admitted cost (prompt + decode tokens)
    per replica — routing happens at submit time, so balance is over
    assigned work, not instantaneous occupancy. A request carrying a
    ``tenant`` sticks to the replica its tenant first landed on (KV/prefix
    locality and per-tenant isolation beat perfect balance); tenant-less
    requests always take the shortest queue, ties to the lowest index.

    With ``replica_model`` set (heterogeneous cluster), each replica is
    pinned to one named model and a request carrying ``model=`` only
    routes among that model's replicas — JSQ and tenant affinity apply
    *within* the compatible set, and a tenant's home is honoured only
    when it serves the requested model (a tenant mixing models keeps its
    home for the home's model and JSQ-routes the rest). An empty
    compatible set raises :class:`NoModelReplica`.
    """

    def __init__(
        self,
        n_replicas: int,
        replica_model: Optional[Sequence[Optional[str]]] = None,
    ) -> None:
        self.n = n_replicas
        self.load = [0.0] * n_replicas
        self.assigned = [0] * n_replicas
        self.tenant_home: dict[str, int] = {}
        self.retired: set[int] = set()  # dead replicas: never routed to
        self.replica_model = (
            list(replica_model) if replica_model is not None else None
        )

    @staticmethod
    def cost(req: Request) -> float:
        return float(len(req.prompt) + req.max_new)

    def _candidates(self, req: Request) -> list[int]:
        live = [j for j in range(self.n) if j not in self.retired] or list(
            range(self.n)
        )
        if req.model is None or self.replica_model is None:
            return live
        cand = [j for j in live if self.replica_model[j] == req.model]
        if not cand:
            raise NoModelReplica(req.model)
        return cand

    def peek(self, req: Request) -> int:
        """The replica ``route()`` would pick, without committing load
        (admission control inspects the prospective target's queue)."""
        cand = self._candidates(req)
        if req.tenant is not None:
            home = self.tenant_home.get(req.tenant)
            if home is not None and home in cand:
                return home
        return min(cand, key=lambda j: (self.load[j], j))

    def route(self, req: Request) -> int:
        i = self.peek(req)
        if req.tenant is not None and req.tenant not in self.tenant_home:
            self.tenant_home[req.tenant] = i
        self.load[i] += self.cost(req)
        self.assigned[i] += 1
        return i

    def retire(self, replica: int) -> None:
        """Take a dead replica out of rotation: JSQ skips it and its
        tenants re-home to a survivor on their next request."""
        self.retired.add(replica)
        self.tenant_home = {
            t: i for t, i in self.tenant_home.items() if i != replica
        }

    def unassign(self, replica: int, req: Request) -> None:
        """Credit back a routed-but-unserved request (it is about to be
        carried across a reconfigure and re-routed): without this, carried
        requests would double-count in the JSQ load and the per-replica
        ``assigned`` telemetry."""
        self.load[replica] -= self.cost(req)
        self.assigned[replica] -= 1


# =============================================================================
# stats
# =============================================================================


@dataclass
class ReconfigureReport:
    """Cost of one mode switch — the paper's CSR-write number.

    ``drain_seconds`` is the time spent finishing in-flight chunks after
    the switch was requested; ``place_seconds`` the re-placement of
    params/cache onto the target fabric (``bytes_moved`` counts what was
    placed; 0 and ``cached=True`` for a warm switch back to an
    already-built fabric, where only the engine state resets)."""

    from_mode: str
    to_mode: str
    drain_seconds: float
    place_seconds: float
    bytes_moved: int
    cached: bool

    @property
    def seconds(self) -> float:
        return self.drain_seconds + self.place_seconds

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        kind = "warm" if self.cached else "cold"
        return (
            f"reconfigure {self.from_mode}->{self.to_mode} ({kind}): "
            f"{self.seconds*1e3:.1f}ms (drain {self.drain_seconds*1e3:.1f} + "
            f"place {self.place_seconds*1e3:.1f}), "
            f"{self.bytes_moved/1e6:.2f} MB placed"
        )


@dataclass
class SegmentStats:
    """One constant-mode stretch of a cluster run."""

    mode: str
    replicas: list[ServeStats]

    @property
    def wall_seconds(self) -> float:
        return max((r.wall_seconds for r in self.replicas), default=0.0)


@dataclass
class ClusterStats:
    """Aggregate over every segment/replica of one ``ServeCluster.run``."""

    mode: str  # e.g. "split" or "split->merge"
    segments: list[SegmentStats]
    reconfigures: list[ReconfigureReport] = field(default_factory=list)
    # robustness counters for THIS run (deltas, filled by the cluster):
    shed: int = 0  # deadline-shed arrivals (shed_deadline)
    rejected: int = 0  # rate_limited + queue_full arrivals
    rehomed: int = 0  # live requests moved off a dead replica
    stragglers: int = 0  # watchdog straggler flags (recovered or not)
    dead_replicas: int = 0  # replicas declared dead during the run

    def _each(self, attr: str) -> list:
        return [getattr(r, attr) for s in self.segments for r in s.replicas]

    @property
    def total_tokens(self) -> int:
        return sum(self._each("total_tokens"))

    @property
    def total_requests(self) -> int:
        return sum(self._each("total_requests"))

    @property
    def ticks(self) -> int:
        return sum(self._each("ticks"))

    @property
    def prefill_compiles(self) -> int:
        return sum(self._each("prefill_compiles"))

    @property
    def cancelled(self) -> int:
        return sum(self._each("cancelled"))

    @property
    def spec_proposed(self) -> int:
        return sum(self._each("spec_proposed"))

    @property
    def spec_accepted(self) -> int:
        return sum(self._each("spec_accepted"))

    @property
    def spec_ticks(self) -> int:
        return sum(self._each("spec_ticks"))

    @property
    def spec_acceptance(self) -> float:
        return self.spec_accepted / max(self.spec_proposed, 1)

    @property
    def queue_peak(self) -> int:
        """High-water mark of any single replica's waiting queue."""
        return max(self._each("queue_peak"), default=0)

    @property
    def alloc_failures(self) -> int:
        return sum(self._each("alloc_failures"))

    @property
    def kv_bytes_resident(self) -> int:
        """Peak dtype-aware resident KV bytes across any one segment
        (replicas within a segment are resident CONCURRENTLY, so they sum;
        segments are sequential, so the cluster peak is the max)."""
        return max(
            (
                sum(r.kv_bytes_resident for r in s.replicas)
                for s in self.segments
            ),
            default=0,
        )

    @property
    def wall_seconds(self) -> float:
        # replicas within a segment run concurrently (max); segments and
        # reconfigurations are sequential (sum). A reconfigure's DRAIN
        # already lives inside the preceding segment's wall — only the
        # re-placement extends the clock.
        return sum(s.wall_seconds for s in self.segments) + sum(
            r.place_seconds for r in self.reconfigures
        )

    @property
    def tokens_per_sec(self) -> float:
        return self.total_tokens / max(self.wall_seconds, 1e-9)

    @property
    def ttfts(self) -> list[float]:
        return [t for xs in self._each("ttfts") for t in xs]

    @property
    def tpots(self) -> list[float]:
        return [t for xs in self._each("tpots") for t in xs]

    @property
    def ttft_p50(self) -> float:
        return percentile(self.ttfts, 50)

    @property
    def ttft_p99(self) -> float:
        return percentile(self.ttfts, 99)

    @property
    def tpot_p50(self) -> float:
        return percentile(self.tpots, 50)

    @property
    def tpot_p99(self) -> float:
        return percentile(self.tpots, 99)


# =============================================================================
# cluster
# =============================================================================


class ServeCluster:
    """Reconfigurable multi-device serving: split replicas or one merged
    tensor-parallel engine over the same devices, switchable at runtime.

    Construction places the initial mode's fabric; ``submit``/``run``
    mirror :class:`ServeEngine` (``run`` returns :class:`ClusterStats`).
    ``reconfigure(mode)`` switches fabrics between runs;
    ``run(reconfigure_schedule=[(t, mode), ...])`` switches mid-stream —
    the cluster drains in-flight work at each switch point, re-homes, and
    resumes with the remaining arrivals.
    """

    def __init__(
        self,
        model: Optional[LM] = None,
        params=None,
        *,
        models: Optional[Mapping[str, tuple]] = None,
        placement: Optional[Mapping[str, int]] = None,
        tenant_models: Optional[Mapping[str, str]] = None,
        mode: Mode | str = Mode.SPLIT,
        devices: Optional[Sequence] = None,
        batch_slots: int = 4,
        max_len: int = 256,
        seed: int = 0,
        unified: Optional[bool] = None,
        prefill_budget: int = 64,
        max_chunk: int = 8,
        kv_block_size: Optional[int] = None,
        num_blocks: Optional[int] = None,
        prefix_cache: bool = False,
        speculate=None,
        kv_dtype=None,
        weight_dtype=None,
        tenant_defaults: Optional[Mapping[str, SamplingParams]] = None,
        admission: Optional[AdmissionPolicy] = None,
        failure: Optional[FailurePolicy] = None,
    ) -> None:
        self.devices = list(devices) if devices is not None else list(jax.devices())
        assert self.devices, "ServeCluster needs at least one device"
        # ---- heterogeneous serving: {name: (model-or-config, params)}
        if models is not None:
            if model is not None or params is not None:
                raise ValueError(
                    "pass either (model, params) or models={...}, not both"
                )
            if not models:
                raise ValueError("models={} names no model to serve")
            self.models = {
                name: self._norm_model_spec(name, spec)
                for name, spec in models.items()
            }
            # first entry is the cluster's primary model: requests with no
            # model pin default to it, and single-engine introspection
            # (ReconfigController.for_cluster reads .params) sees it
            self.model, self.params = next(iter(self.models.values()))
        else:
            if model is None or params is None:
                raise ValueError(
                    "ServeCluster needs (model, params) or models={...}"
                )
            self.models = None
            self.model = model
            self.params = params
        self.tenant_models: dict[str, str] = dict(tenant_models or {})
        if self.tenant_models and self.models is None:
            raise ValueError("tenant_models= needs models={...}")
        for t, name in self.tenant_models.items():
            if name not in (self.models or {}):
                raise ValueError(
                    f"tenant_models[{t!r}] names unknown model {name!r}"
                )
        self._replica_model = self._plan_replicas(placement)
        self.seed = seed
        # paged kwargs pass straight through: split mode gets one
        # independent pool + prefix tree PER replica (tenant-affinity
        # routing then doubles as prefix locality — a tenant's repeated
        # system prompt stays hot on its home replica's tree)
        self._engine_kw = dict(
            batch_slots=batch_slots,
            max_len=max_len,
            unified=unified,
            prefill_budget=prefill_budget,
            max_chunk=max_chunk,
            kv_block_size=kv_block_size,
            num_blocks=num_blocks,
            prefix_cache=prefix_cache,
            # each engine builds its own drafter from the config string —
            # a split replica drafts against its local slots, the merged
            # engine against the whole batch; seeded streams stay
            # bit-identical across modes because acceptance is exact-match
            # against the same fold_in(seed, position) draws
            speculate=speculate,
            # quantized serving passes through unchanged: every fabric
            # (split replicas AND the merged TP engine) stores the same
            # int8 rows + scales, so a mid-stream SPLIT<->MERGE switch
            # re-homes requests across identically-quantized caches
            kv_dtype=kv_dtype,
            weight_dtype=weight_dtype,
        )
        self.router = Router(len(self.devices), replica_model=self._replica_model)
        self.finished: list[Request] = []
        self.reconfigures: list[ReconfigureReport] = []
        # per-tenant default SamplingParams: a request submitted WITHOUT
        # explicit sampling config inherits its tenant's default at routing
        # time, before any engine sees it — so the defaults survive
        # split/merge switches and mid-stream reconfigure re-routing
        # unchanged (params are resolved once, at first submit)
        self.tenant_defaults: dict[str, SamplingParams] = dict(tenant_defaults or {})
        # which engine currently owns each live request (handles route
        # cancellation through this; reconfigure() re-homes the entries)
        self._where: dict[Request, ServeEngine] = {}
        self._fabrics: dict[Mode, list[ServeEngine]] = {}
        # ---- robustness state (see module docstring)
        self.admission = (
            AdmissionController(admission) if admission is not None else None
        )
        self.failure = failure
        self.rehomed = 0
        self.stragglers = 0
        self._dead: set[int] = set()  # indices into the SPLIT fabric
        self._rehome_lock = threading.Lock()
        # orig -> (continuation, tokens committed before the death);
        # cont -> orig for mapping the survivor's finished list back
        self._rehomed_map: dict[Request, tuple[Request, int]] = {}
        self._cont_orig: dict[Request, Request] = {}
        self._seg_routes: dict[int, list] = {}  # replica -> current (t, req)s
        self.mode = Mode.parse(mode)
        if self.mode is Mode.MERGE and self._hetero:
            raise ValueError(
                f"merge mode cannot fuse {len(self.models)} different "
                "models into one engine; a heterogeneous cluster is "
                "split-only"
            )
        self._ensure_fabric(self.mode)

    # ------------------------------------------------------------ hetero glue

    @staticmethod
    def _norm_model_spec(name: str, spec) -> tuple[LM, object]:
        """Normalize one ``models=`` entry to ``(LM, params)``. Accepts
        ``(LM, params)`` or ``(ArchConfig, params)`` — a config is wrapped
        in a fresh LM, so callers can hand archs straight from
        :func:`repro.configs.get_arch`."""
        try:
            head, params = spec
        except (TypeError, ValueError):
            raise ValueError(
                f"models[{name!r}] must be (model, params); got {type(spec)}"
            ) from None
        if isinstance(head, LM):
            return head, params
        if hasattr(head, "n_layers"):  # an ArchConfig
            return LM(head), params
        raise ValueError(
            f"models[{name!r}][0] must be an LM or ArchConfig, "
            f"got {type(head)}"
        )

    @property
    def _hetero(self) -> bool:
        return self.models is not None and len(self.models) > 1

    def _plan_replicas(self, placement) -> Optional[list[str]]:
        """Pin one model name per split replica (None = homogeneous).
        ``placement`` overrides the planner's replica counts; either way
        every model gets ≥1 replica and counts sum to the device count.
        Assignment is contiguous in ``models`` insertion order — replica
        index blocks, deterministic for tests and logs."""
        if self.models is None:
            return None
        n = len(self.devices)
        if placement is not None:
            counts = dict(placement)
            unknown = set(counts) - set(self.models)
            if unknown:
                raise ValueError(f"placement names unknown models {unknown}")
            missing = set(self.models) - set(counts)
            if missing or any(c < 1 for c in counts.values()):
                raise ValueError(
                    "placement must give every model at least one replica"
                )
            if sum(counts.values()) != n:
                raise ValueError(
                    f"placement sums to {sum(counts.values())}, "
                    f"cluster has {n} devices"
                )
        else:
            counts = plan_hetero_placement(
                {name: m.cfg for name, (m, _) in self.models.items()}, n
            )
        out: list[str] = []
        for name in self.models:
            out.extend([name] * counts[name])
        return out

    def replica_plan(self) -> Optional[dict[str, list[int]]]:
        """{model name: replica indices} for a heterogeneous cluster
        (None when homogeneous) — the placement the planner or the
        ``placement=`` override committed to."""
        if self._replica_model is None:
            return None
        plan: dict[str, list[int]] = {name: [] for name in self.models}
        for i, name in enumerate(self._replica_model):
            plan[name].append(i)
        return plan

    def _resolve_model(self, req: Request) -> None:
        """Pin a request to a named model before routing: explicit
        ``req.model`` wins, then the tenant's ``tenant_models`` mapping,
        then the primary (first) model. Unknown names raise
        :class:`NoModelReplica` — routing a request onto a *different*
        model would silently change the distribution it samples from."""
        if self.models is None:
            return
        if req.model is None and req.tenant is not None:
            req.model = self.tenant_models.get(req.tenant)
        if req.model is None:
            req.model = next(iter(self.models))
        if req.model not in self.models:
            raise NoModelReplica(
                req.model,
                f"model {req.model!r} is not in this cluster's placement "
                f"({list(self.models)})",
            )

    # ----------------------------------------------------------------- fabric

    @property
    def engines(self) -> list[ServeEngine]:
        return self._fabrics[self.mode]

    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    def _ensure_fabric(self, mode: Mode) -> tuple[bool, int]:
        """Build (or warm-reset) the engines for ``mode``.

        Returns ``(cached, bytes_placed)``: a cached fabric only resets its
        engines' tick state (compiled programs and placement survive)."""
        if mode in self._fabrics:
            for e in self._fabrics[mode]:
                e.reset()
            return True, 0
        if mode is Mode.MERGE:
            if self._hetero:
                raise ValueError(
                    f"merge mode cannot fuse {len(self.models)} different "
                    "models into one engine; a heterogeneous cluster is "
                    "split-only"
                )
            info = serving_mesh_info(self.devices)
            if info.model_size > 1:
                # a fresh LM view carrying the mesh: decode/packed attention
                # runs head-sharded (models/attention._head_constraint)
                model = LM(self.model.cfg, mesh_info=info)
                backend = ShardedBackend(info)
            else:  # one device: merge degenerates to a pinned plain engine
                model, backend = self.model, DeviceBackend(self.devices[0])
            engines = [
                ServeEngine(
                    model, self.params, seed=self.seed, backend=backend,
                    **self._engine_kw,
                )
            ]
        else:
            engines = []
            for i, d in enumerate(self.devices):
                if self._replica_model is not None:
                    m, p = self.models[self._replica_model[i]]
                else:
                    m, p = self.model, self.params
                engines.append(
                    ServeEngine(
                        m, p, seed=self.seed + i,
                        backend=DeviceBackend(d), **self._engine_kw,
                    )
                )
        jax.block_until_ready([e.params for e in engines])
        jax.block_until_ready([e.cache for e in engines])
        self._fabrics[mode] = engines
        placed = sum(pytree_bytes(e.params) + pytree_bytes(e.cache) for e in engines)
        return False, placed

    def prewarm(self, sampling: bool = False) -> None:
        """Compile every dispatch variant of the CURRENT mode's fabric off
        the serving path (replica prewarms run concurrently in split mode)."""
        engines = self.engines
        if len(engines) == 1:
            engines[0].prewarm(sampling)
            return
        with ThreadPoolExecutor(len(engines)) as ex:
            list(ex.map(lambda e: e.prewarm(sampling), engines))

    # ------------------------------------------------------------------ admit

    def submit(self, req: Request) -> RequestHandle:
        """Apply the tenant's default SamplingParams (if the request came
        without explicit config), route, and enqueue; returns a
        :class:`RequestHandle` owned by the cluster — its ``cancel()``
        follows the request to whichever engine currently holds it, across
        split/merge switches and mid-stream reconfiguration."""
        if req.tenant is not None and req.tenant in self.tenant_defaults:
            req.apply_default_params(self.tenant_defaults[req.tenant])
        self._resolve_model(req)  # raises NoModelReplica on unknown names
        if self.admission is not None:
            self._admission_gate(req)  # raises AdmissionRejected
        return self._submit_admitted(req)

    def _submit_admitted(self, req: Request) -> RequestHandle:
        engines = self.engines
        if self.mode is Mode.MERGE:  # one fused engine, no routing
            i = 0
        else:
            # split mode always routes — even a degenerate 1-replica fabric
            # keeps its JSQ/affinity telemetry truthful
            i = self.router.route(req)
        handle = engines[i].submit(req)
        handle._owner = self
        handle.replica = i
        self._where[req] = engines[i]
        return handle

    def _admission_gate(self, req: Request) -> None:
        """Gate a request against its PROSPECTIVE target replica's queue:
        depth bounds backpressure, queued cost feeds the TTFT predictor."""
        engines = self.engines
        i = 0 if self.mode is Mode.MERGE else self.router.peek(req)
        target = engines[i]
        depth = len(target.waiting)
        queued = sum(Router.cost(r) for r in target.waiting) + sum(
            float(r.params.max_new - len(r.generated))
            for r in target.slot_req
            if r is not None
        )
        self.admission.admit(req, queue_depth=depth, queue_cost=queued)

    def _arrival_gate(self, eng: ServeEngine, replica: Optional[int] = None):
        """Admission closure for one engine's arrival stream (engine.run
        ``gate=``): gates against the engine's LIVE queue at each
        request's scheduled arrival time. On rejection the pre-routed
        request's load charge and ownership entry are rolled back before
        the engine finalizes it as "rejected"."""
        if self.admission is None:
            return None
        adm = self.admission

        def gate(req: Request) -> None:
            depth = len(eng.waiting)
            queued = sum(Router.cost(r) for r in eng.waiting) + sum(
                float(r.params.max_new - len(r.generated))
                for r in eng.slot_req
                if r is not None
            )
            try:
                adm.admit(req, queue_depth=depth, queue_cost=queued)
            except AdmissionRejected:
                if replica is not None:
                    self.router.unassign(replica, req)
                self._where.pop(req, None)
                raise

        return gate

    def cancel(self, req: Request) -> None:
        """Abort a request wherever it currently lives (handle plumbing).
        Cancelling a request that already finished is a no-op, matching
        the engine-level semantics (a client-side timeout racing normal
        completion must not crash). A re-homed request's cancel follows it
        to the survivor's continuation; the sync pass then folds the
        "cancelled" outcome back into the original handle."""
        with self._rehome_lock:
            pair = self._rehomed_map.get(req)
        if pair is not None:
            req = pair[0]
        eng = self._where.get(req)
        if eng is None:
            if req.finish_reason is not None:
                return  # completed (and pruned from the ownership map)
            raise KeyError(f"request {req.rid} was never submitted to this cluster")
        eng.cancel(req)

    def _handle_pump(self, req: Request) -> None:
        """Progress hook for a blocked handle iterator: drive the owning
        engine when this thread can, politely poll when a controller
        thread owns it (split-mode replicas run under their own threads).
        For a re-homed request the survivor's CONTINUATION is pumped and
        its progress synced back into the original (the handle's view)."""
        with self._rehome_lock:
            pair = self._rehomed_map.get(req)
        target = pair[0] if pair is not None else req
        eng = self._where.get(target)
        if eng is None or eng._running or eng._poisoned:
            time.sleep(2e-4)
            return
        eng._handle_pump(target)
        if pair is not None:
            self._sync_rehomed()
        if req.complete:
            self._handle_done(req)

    def _handle_done(self, req: Request) -> None:
        """Drop a COMPLETE request from the ownership map — a purely
        handle-streamed request never passes through _run_segment's prune,
        and without this a run()-less cluster grows the map without bound.
        Only once complete (values harvested), never merely
        count-finished: the final chunk's tokens are still in flight when
        ``finish_reason`` lands, and the iterator needs the engine mapping
        to pump them home."""
        if req.complete:
            self._where.pop(req, None)

    # ------------------------------------------------------------ reconfigure

    def reconfigure(self, mode: Mode | str, drain_seconds: float = 0.0) -> ReconfigureReport:
        """Switch the serving fabric: collect undrained requests, re-place
        (or warm-reset) the target mode's engines, re-route the carried
        requests, and report the measured cost. Engines must be idle (no
        in-flight slots) — ``run()`` drains before returning, and the
        scheduled mid-stream path measures its drain into the report."""
        mode = Mode.parse(mode)
        if mode is Mode.MERGE and self._hetero:
            # refuse BEFORE draining queues — a failed fabric build after
            # the collect loop below would strand the carried requests
            raise ValueError(
                f"merge mode cannot fuse {len(self.models)} different "
                "models into one engine; a heterogeneous cluster is "
                "split-only"
            )
        carried: list[Request] = []
        routed = self.mode is not Mode.MERGE  # split queues went through JSQ
        for idx, e in enumerate(self.engines):
            assert all(r is None for r in e.slot_req), (
                "reconfigure() with in-flight slots; run() must drain first"
            )
            for r in e.waiting:
                if routed:  # re-routed below — give the JSQ load back
                    self.router.unassign(idx, r)
                carried.append(r)
            e.waiting.clear()
        carried.sort(key=lambda r: r.submitted_at)
        old = self.mode
        t0 = time.perf_counter()
        cached, placed = self._ensure_fabric(mode)
        place_s = time.perf_counter() - t0
        self.mode = mode
        for r in carried:
            t = r.submitted_at  # preserve the TTFT clock across the switch
            self.submit(r)  # re-homes _where, so live handles follow
            r.submitted_at = t
        rep = ReconfigureReport(
            str(old), str(mode), drain_seconds, place_s, placed, cached
        )
        self.reconfigures.append(rep)
        return rep

    # --------------------------------------------------- failure / re-homing

    def _make_tick(self, idx: int, wd: Optional[Watchdog]):
        """Per-replica heartbeat closure for the serving loop's on_tick:
        beat the watchdog lane, then run the (test-injectable) hook — in
        that order, so a stalling hook leaves the beat stale and the
        watchdog sees exactly the stall it is meant to catch."""
        hook = self.failure.tick_hook if self.failure is not None else None
        lane = f"replica{idx}"

        def tick() -> None:
            if wd is not None:
                wd.beat(lane)
            if hook is not None:
                hook(idx)

        return tick

    def _on_straggler(self, lane: str, state) -> None:
        self.stragglers += 1

    def _on_dead(self, lane: str, state) -> None:
        self._rehome_dead(int(lane.removeprefix("replica")))

    def _rehome_dead(self, idx: int) -> None:
        """Declare split replica ``idx`` dead and move its live requests
        to survivors. Runs on the watchdog thread while the dead replica's
        controller thread is stuck: the poison pill guarantees that if
        that thread ever resumes, it aborts at its next iteration boundary
        without touching the state re-homed here (beats only happen at
        iteration boundaries, so a dead verdict implies the thread is
        parked inside its tick hook or a dispatch, not mid-bookkeeping).

        Requests with committed (harvested) tokens continue on a survivor
        via :func:`build_continuation` — prompt' = prompt ++ committed —
        and their remaining draws land at the same absolute positions, so
        seeded streams stay bit-identical. Unharvested in-flight draws on
        the dead replica are re-derived (same fold_in key, same value)."""
        with self._rehome_lock:
            if idx in self._dead:
                return
            engines = self._fabrics[Mode.SPLIT]
            e = engines[idx]
            e._poisoned = True
            self._dead.add(idx)
            self.router.retire(idx)
            survivors = [
                j for j in range(len(engines)) if j not in self._dead
            ]
            # work the dead replica DID finish is kept, not re-served
            self.finished.extend(self._cont_orig.pop(r, r) for r in e.finished)
            e.finished = []
            if not survivors:
                return  # whole fabric gone: handles stay blocked, by design
            moved: list[Request] = []
            for r in list(e.waiting):
                if r.finish_reason is None:
                    self.router.unassign(idx, r)
                    moved.append(r)
            e.waiting.clear()
            for slot, r in enumerate(e.slot_req):
                if r is not None and r.finish_reason is None:
                    self.router.unassign(idx, r)
                    moved.append(r)
                e.slot_req[slot] = None
            e.slot_len[:] = 0
            e.slot_fed[:] = 0
            e._prefilling.clear()
            e._pending.clear()
            # scheduled arrivals the dead loop never got to submit
            seen = set(map(id, moved))
            for _t, r in self._seg_routes.get(idx, ()):
                if (
                    r.finish_reason is None
                    and r.submitted_at == 0.0
                    and id(r) not in seen
                ):
                    self.router.unassign(idx, r)
                    moved.append(r)
            for r in moved:
                try:
                    self._resubmit_rehomed(r)
                except NoModelReplica as exc:
                    # every replica serving this request's model died —
                    # close it out rather than continue on a survivor
                    # running a DIFFERENT model (wrong distribution)
                    self._mark_unroutable(r, exc)
            self.rehomed += len(moved)

    def _resubmit_rehomed(self, req: Request) -> None:
        """Hand one live request from a dead replica to a survivor.
        Caller holds ``_rehome_lock``; the router already skips the dead
        replica, so routing here lands on a survivor."""
        committed = len(req.generated)
        req.n_generated = committed  # in-flight draws will be re-derived
        if committed >= req.params.max_new:
            # fully harvested — nothing left to serve, just close it out
            req.finish_reason = req.finish_reason or "length"
            req.done_at = req.done_at or time.perf_counter()
            self.finished.append(req)
            return
        if committed == 0:
            # nothing committed: a clean restart IS the same stream
            # (fold_in keying — first draw lands at the same position)
            t = req.submitted_at
            self._submit_admitted(req)
            if t:
                req.submitted_at = t  # keep the original TTFT clock
            return
        cont, base = build_continuation(req)
        i = self.router.route(cont)
        eng = self._fabrics[Mode.SPLIT][i]
        eng.submit(cont)
        cont.submitted_at = req.submitted_at  # recovery latency is visible
        self._where[cont] = eng
        self._where[req] = eng
        self._rehomed_map[req] = (cont, base)
        self._cont_orig[cont] = req

    def _sync_rehomed(self) -> None:
        """Fold re-homed continuations' progress back into their original
        request objects — the handles clients hold point at the originals.
        Safe to call from any thread; completed pairs are retired here
        (the finished-list fold maps cont→orig separately)."""
        with self._rehome_lock:
            for orig, (cont, base) in list(self._rehomed_map.items()):
                synced = len(orig.generated) - base
                fresh = cont.generated[synced:]
                if fresh:
                    orig.generated.extend(fresh)
                if (
                    orig.first_token_at is None
                    and cont.first_token_at is not None
                ):
                    orig.first_token_at = cont.first_token_at
                if cont.complete:
                    orig.n_generated = base + cont.n_generated
                    orig.finish_reason = cont.finish_reason
                    orig.done_at = cont.done_at
                    del self._rehomed_map[orig]

    def _mark_unroutable(self, req: Request, exc: NoModelReplica) -> None:
        """Close out a request no live replica can serve (typed rejection,
        same bookkeeping as an arrival-stream admission rejection)."""
        req.finish_reason = "rejected"
        req.reject_reason = exc.reason
        req.done_at = time.perf_counter()
        self._where.pop(req, None)
        self.finished.append(req)

    # -------------------------------------------------------------------- run

    def _run_segment(
        self, seg_arrivals: list, deadline_s: Optional[float] = None
    ) -> SegmentStats:
        engines = self.engines
        # arrival-stream requests take the same intake path as submit():
        # tenant default params attach and the ownership map learns their
        # engine (so handle.cancel() reaches a request that arrived
        # mid-stream, and per-tenant policy is honoured either way).
        # Admission is NOT gated here: routing happens at handover but the
        # gate fires at each request's scheduled arrival time, on the
        # serving thread, against the live queue (engine.run's ``gate=``) —
        # intake-time gating would wave an entire burst through because
        # the queue was empty when the slice was handed over.
        rejected: list[Request] = []
        for _, req in seg_arrivals:
            if req.tenant is not None and req.tenant in self.tenant_defaults:
                req.apply_default_params(self.tenant_defaults[req.tenant])
            try:
                self._resolve_model(req)
            except NoModelReplica as exc:
                self._mark_unroutable(req, exc)
                rejected.append(req)
        if rejected:
            dropped = set(map(id, rejected))
            seg_arrivals = [
                (t, r) for t, r in seg_arrivals if id(r) not in dropped
            ]
        if self.mode is Mode.MERGE:
            for _, req in seg_arrivals:
                self._where[req] = engines[0]
            stats = [
                engines[0].run(
                    arrivals=seg_arrivals or None,
                    deadline_s=deadline_s,
                    gate=self._arrival_gate(engines[0]),
                )
            ]
        else:
            per: list[list] = [[] for _ in engines]
            for t, req in seg_arrivals:
                try:
                    i = self.router.route(req)
                except NoModelReplica as exc:
                    # the pinned model's replicas are all dead: reject —
                    # serving the request on a different model's survivor
                    # would silently answer from the wrong distribution
                    self._mark_unroutable(req, exc)
                    continue
                per[i].append((t, req))
                self._where[req] = engines[i]
            self._seg_routes = {i: pl for i, pl in enumerate(per)}
            if len(engines) == 1:  # degenerate split: no threads needed
                stats = [
                    engines[0].run(
                        arrivals=(per[0] or None),
                        deadline_s=deadline_s,
                        gate=self._arrival_gate(engines[0]),
                    )
                ]
            else:
                stats = self._run_split_threads(engines, per, deadline_s)
            self._seg_routes = {}
        self._sync_rehomed()
        if not stats:
            stats = [ServeStats()]
        carrier = stats[0]  # stream-stats fold target (order-independent:
        # the threaded path returns stats in completion order, and a dead
        # replica's stats are lost with its thread)
        for i, e in enumerate(engines):
            if self.mode is not Mode.MERGE and i in self._dead:
                continue  # folded once, at declaration time (_rehome_dead)
            # work served OUTSIDE run() — handle-driven streaming and idle
            # cancellations — landed in the engine's stream-stats; fold
            # every counter into this segment (and zero them) so
            # ClusterStats reports the whole session, not just the drains
            ss = e.stream_stats
            carrier.total_tokens += ss.total_tokens
            carrier.total_requests += ss.total_requests
            carrier.ticks += ss.ticks
            carrier.prefill_compiles += ss.prefill_compiles
            carrier.cancelled += ss.cancelled
            ss.total_tokens = ss.total_requests = ss.ticks = 0
            ss.prefill_compiles = ss.cancelled = 0
            # a survivor's finished list may hold re-homed CONTINUATIONS —
            # clients only know the originals, so map them back
            self.finished.extend(self._cont_orig.pop(r, r) for r in e.finished)
            e.finished = []
        # drop completed requests from the ownership map (cancellation can
        # no longer reach them; keeps the map from growing unboundedly)
        self._where = {r: e for r, e in self._where.items() if r.finish_reason is None}
        return SegmentStats(str(self.mode), stats)

    def _run_split_threads(
        self,
        engines: list[ServeEngine],
        per: list[list],
        deadline_s: Optional[float],
    ) -> list[ServeStats]:
        """One controller thread per replica — the paper's "each core
        driven by its own scalar core"; jax dispatch is thread-safe across
        disjoint engines. With a :class:`FailurePolicy` armed, a watchdog
        monitors per-iteration heartbeats; a replica declared dead has its
        future ABANDONED (never joined — shutdown(wait=False) leaves the
        stuck thread to die on the poison pill) and its requests re-homed,
        after which any survivor that already returned is re-run to drain
        the work it inherited."""
        wd = None
        if self.failure is not None:
            wd = Watchdog(
                straggler_after=self.failure.straggler_after,
                dead_after=self.failure.dead_after,
                poll=self.failure.poll,
                on_straggler=self._on_straggler,
                on_dead=self._on_dead,
            )
            for i in range(len(engines)):
                if i not in self._dead:
                    wd.register(f"replica{i}")
            wd.start()
        ex = ThreadPoolExecutor(len(engines))
        stats: list[ServeStats] = []
        try:
            futs = {
                i: ex.submit(
                    e.run,
                    arrivals=(pl or None),
                    deadline_s=deadline_s,
                    on_tick=self._make_tick(i, wd),
                    gate=self._arrival_gate(e, i),
                )
                for i, (e, pl) in enumerate(zip(engines, per))
                if i not in self._dead
            }
            done: set[int] = set()
            while futs:
                if wd is not None:
                    # a replica that finished its stream stops beating —
                    # keep its lane fresh so only genuinely stuck threads
                    # (not early finishers) can be declared dead
                    for i in done:
                        wd.beat(f"replica{i}")
                for i in list(futs):
                    if i in self._dead:
                        futs.pop(i)  # abandoned: poison pill reaps it
                        continue
                    try:
                        stats.append(futs[i].result(timeout=0.02))
                    except _FutTimeout:
                        continue
                    futs.pop(i)
                    done.add(i)
            if wd is not None:  # concurrency over: nothing left to monitor
                wd.stop()
                wd = None
            # survivors may have inherited re-homed work AFTER their run
            # returned — drain it now (skipped under a deadline: the next
            # control interval serves it)
            if deadline_s is None:
                progressed = True
                while progressed:
                    progressed = False
                    for i, e in enumerate(engines):
                        if i in self._dead:
                            continue
                        if e.waiting or any(
                            r is not None for r in e.slot_req
                        ):
                            stats.append(
                                e.run(on_tick=self._make_tick(i, None))
                            )
                            progressed = True
        finally:
            ex.shutdown(wait=False)
            if wd is not None:
                wd.stop()
        return stats

    def run(self, arrivals=None, reconfigure_schedule=None) -> ClusterStats:
        """Drain all submitted work (+ an optional open-loop ``arrivals``
        schedule), optionally switching modes mid-stream.

        ``reconfigure_schedule``: ``[(t_offset_seconds, mode), ...]`` —
        at each offset the cluster stops admitting, drains in-flight
        chunks, reconfigures, and resumes with the remaining arrivals.
        Arrival offsets stay anchored to the ORIGINAL stream clock: a
        segment's offsets are re-based by the wall time already consumed
        (serving + drain + re-placement), going negative when the switch
        overran an arrival — the engine then submits it immediately with
        its true scheduled ``submitted_at``, so reconfiguration latency
        SHOWS UP in TTFT instead of hiding behind a restarted clock (the
        same no-hiding rule as the engine's own arrival handling)."""
        schedule = sorted(reconfigure_schedule or [], key=lambda x: x[0])
        arr = sorted(arrivals or [], key=lambda a: a[0])
        segments: list[SegmentStats] = []
        reports: list[ReconfigureReport] = []
        base = self._counter_base()
        elapsed = 0.0  # true wall time consumed before the current segment
        for idx in range(len(schedule) + 1):
            if idx < len(schedule):
                t_switch, nxt = schedule[idx]
                seg_arr = [(t - elapsed, r) for t, r in arr if t < t_switch]
                arr = [(t, r) for t, r in arr if t >= t_switch]
            else:
                t_switch, nxt = None, None
                seg_arr = [(t - elapsed, r) for t, r in arr]
            seg = self._run_segment(seg_arr)
            segments.append(seg)
            if t_switch is None:
                break
            drain = max(0.0, seg.wall_seconds - max(t_switch - elapsed, 0.0))
            rep = self.reconfigure(nxt, drain_seconds=drain)
            reports.append(rep)
            # drain already lives inside seg.wall_seconds; only the
            # re-placement extends the clock beyond the segment
            elapsed += seg.wall_seconds + rep.place_seconds
        return self._finish_stats(segments, reports, base)

    def run_controlled(
        self, arrivals=None, controller=None
    ) -> ClusterStats:
        """Closed-loop serving: slice the stream into control intervals,
        observe a :class:`~repro.serve.controller.WindowSample` at each
        boundary, and let the controller trigger split↔merge switches.

        Each interval runs with ``deadline_s`` — in-flight slots drain at
        the boundary but queued work stays queued, which is exactly the
        reconfigure()-safe state — so a committed switch carries the
        backlog to the new fabric. A ``controller`` defaults to
        :meth:`ReconfigController.for_cluster`; anything with the same
        ``interval_s`` / ``observe`` / ``note_switched`` surface works
        (tests drive the machinery with scripted deciders)."""
        ctl = (
            controller
            if controller is not None
            else ReconfigController.for_cluster(self)
        )
        arr = sorted(arrivals or [], key=lambda a: a[0])
        segments: list[SegmentStats] = []
        reports: list[ReconfigureReport] = []
        base = self._counter_base()
        elapsed = 0.0
        while True:
            interval = ctl.interval_s
            t_end = elapsed + interval
            seg_arr = [(t - elapsed, r) for t, r in arr if t < t_end]
            arr = [(t, r) for t, r in arr if t >= t_end]
            seg = self._run_segment(seg_arr, deadline_s=interval)
            segments.append(seg)
            seg_wall = seg.wall_seconds
            if arr and seg_wall < interval and not self._work_pending():
                # idle gap: sleep the stream clock forward to the next
                # arrival (bounded by one control interval)
                gap = min(interval, arr[0][0] - elapsed) - seg_wall
                if gap > 0:
                    time.sleep(gap)
                    seg_wall += gap
            elapsed += seg_wall
            # ---- observe + decide
            sample = self._window_sample(seg, seg_arr, elapsed)
            warm = self._other_mode(self.mode) in self._fabrics
            decision = ctl.observe(sample, warm_target=warm)
            if decision is not None and decision.mode is Mode.MERGE and self._hetero:
                decision = None  # un-mergeable: pinned models keep it split
            if decision is not None and decision.mode is not self.mode:
                self._sync_rehomed()
                rep = self.reconfigure(
                    decision.mode,
                    drain_seconds=max(0.0, seg_wall - interval),
                )
                reports.append(rep)
                ctl.note_switched(elapsed, rep)
                elapsed += rep.place_seconds
            # ---- service-rate feedback for the deadline predictor
            if self.admission is not None:
                toks = sum(r.total_tokens for r in seg.replicas)
                live = max(len(seg.replicas), 1)
                if toks and seg.wall_seconds > 0:
                    self.admission.note_service_rate(
                        toks / seg.wall_seconds / live
                    )
            if not arr and not self._work_pending():
                break
        return self._finish_stats(segments, reports, base)

    def _work_pending(self) -> bool:
        for i, e in enumerate(self.engines):
            if self.mode is not Mode.MERGE and i in self._dead:
                continue
            if e.waiting or any(r is not None for r in e.slot_req):
                return True
        return False

    def _window_sample(
        self, seg: SegmentStats, seg_arr: list, elapsed: float
    ) -> WindowSample:
        depth = 0
        for i, e in enumerate(self.engines):
            if self.mode is not Mode.MERGE and i in self._dead:
                continue
            depth += len(e.waiting)
        reqs = [r for _, r in seg_arr]
        ttfts = [t for r in seg.replicas for t in r.ttfts]
        tpots = [t for r in seg.replicas for t in r.tpots]
        return WindowSample(
            t=elapsed,
            mode=str(self.mode),
            queue_depth=depth,
            n_requests=len(reqs),
            prompt_tokens=sum(len(r.prompt) for r in reqs),
            decode_tokens=sum(r.params.max_new for r in reqs),
            longest_tokens=max(
                (r.params.max_new for r in reqs), default=0
            ),
            n_tenants=len({r.tenant for r in reqs if r.tenant is not None}),
            ttft_p99=percentile(ttfts, 99),
            tpot_p99=percentile(tpots, 99),
        )

    @staticmethod
    def _other_mode(mode: Mode) -> Mode:
        return Mode.MERGE if mode is Mode.SPLIT else Mode.SPLIT

    def _counter_base(self) -> dict:
        adm = self.admission
        return dict(
            rehomed=self.rehomed,
            stragglers=self.stragglers,
            dead=len(self._dead),
            shed=adm.shed if adm is not None else 0,
            rejected=adm.rejected if adm is not None else 0,
        )

    def _finish_stats(
        self,
        segments: list[SegmentStats],
        reports: list[ReconfigureReport],
        base: dict,
    ) -> ClusterStats:
        modes = [s.mode for s in segments]
        # collapse only ADJACENT repeats: a split->merge->split round trip
        # must read as such, not dedupe to "split->merge"
        mode_label = "->".join(
            m for i, m in enumerate(modes) if i == 0 or modes[i - 1] != m
        )
        st = ClusterStats(
            mode=mode_label,
            segments=segments,
            reconfigures=reports,
        )
        st.rehomed = self.rehomed - base["rehomed"]
        st.stragglers = self.stragglers - base["stragglers"]
        st.dead_replicas = len(self._dead) - base["dead"]
        if self.admission is not None:
            st.shed = self.admission.shed - base["shed"]
            st.rejected = self.admission.rejected - base["rejected"]
        return st

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"ServeCluster(mode={self.mode}, devices={len(self.devices)}, "
            f"replicas={self.n_replicas})"
        )
