"""Request-level sampling configuration + the device-side fused sampler.

This is the serving API's *reconfiguration knob*: Spatzformer's thesis is
that one fixed fabric serves mixed workloads best when the configuration is
chosen per-workload, off the hot path.  :class:`SamplingParams` is that
choice at request granularity — every request carries a frozen parameter
record, the engine folds the per-slot parameter rows into device-resident
arrays, and each dispatch runs ONE of a finite zoo of compiled sampler
variants (``smode``), selected per tick by a host ``if`` over the active
slots.  Reconfiguration (a request with different sampling needs arriving)
is a cheap event-driven array upload, never a recompile — ``prewarm()``
builds every variant before serving, the same way split/merge is a CSR
write rather than a per-kernel cost.

The three compiled variants:

* ``SMODE_GREEDY`` (0) — plain argmax, **no PRNG, no bias scatter, no
  sort**: the all-greedy fast path, bit-identical to the pre-SamplingParams
  engine (threefry is a real cost on small hosts; a greedy deployment never
  pays it).
* ``SMODE_GUMBEL`` (1) — gumbel-max (categorical) at per-slot temperature.
* ``SMODE_MASKED`` (2) — masked renormalized sampling: per-slot logit bias,
  temperature scaling, top-k and top-p (nucleus) masks applied to the
  scaled logits, then gumbel-max over the surviving set.  With
  ``top_k=0, top_p=1`` and no bias the mask keeps everything and the draw
  equals variant 1 exactly — so a mixed batch can always run the widest
  variant any slot needs without perturbing the narrower slots.

Determinism is structural, not incidental: every draw's PRNG key is
``fold_in(key(request_seed), position)`` — a pure function of the request's
seed and the absolute position being sampled.  No shared key chain exists,
so a seeded stream is reproducible across decode chunk sizes, across the
legacy and unified engines, across split/merge cluster modes, and is
untouched by a neighbouring slot being admitted or cancelled mid-stream.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

# sampler dispatch variants (static jit arg -> one compiled program each)
SMODE_GREEDY, SMODE_GUMBEL, SMODE_MASKED = 0, 1, 2

# per-request logit-bias entries are capped so the device-resident bias
# rows have a static shape ([B, MAX_LOGIT_BIAS] token/value pairs)
MAX_LOGIT_BIAS = 8

# scatter index for unused bias lanes: far out of any vocab, dropped by
# the .add(mode="drop") scatter (negative padding would wrap in jax)
_BIAS_PAD = 2**30


@dataclass(frozen=True)
class SamplingParams:
    """Frozen per-request sampling/termination configuration.

    ``temperature <= 0`` means greedy (argmax).  ``top_k=0`` and
    ``top_p=1.0`` disable their masks.  ``seed=None`` lets the engine
    assign one at admission (deterministic per engine, but not across
    cluster modes — pass an explicit seed for cross-fabric reproducible
    streams).  ``stop`` token ids terminate the stream; the stop token
    itself is emitted and counted into ``n_generated`` (exactly like a
    ``max_new`` boundary token).  ``logit_bias`` is up to
    ``MAX_LOGIT_BIAS`` ``(token_id, bias)`` pairs added to the logits
    before every sampling decision (greedy included)."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    max_new: int = 16
    stop: tuple[int, ...] = ()
    logit_bias: tuple[tuple[int, float], ...] = ()

    def __post_init__(self):
        # normalize the container fields so params hash/compare by value
        object.__setattr__(self, "stop", tuple(int(t) for t in self.stop))
        lb = self.logit_bias
        if isinstance(lb, Mapping):
            lb = tuple(lb.items())
        object.__setattr__(
            self, "logit_bias", tuple((int(t), float(v)) for t, v in lb)
        )
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.seed is not None and not -(2**31) <= self.seed < 2**31:
            # the seed rides a device-resident int32 row; reject a
            # non-representable one here, not mid-serving-loop
            raise ValueError(f"seed must fit int32, got {self.seed}")
        if len(self.logit_bias) > MAX_LOGIT_BIAS:
            raise ValueError(
                f"at most {MAX_LOGIT_BIAS} logit_bias entries, got {len(self.logit_bias)}"
            )

    @property
    def smode(self) -> int:
        """The narrowest compiled sampler variant this request needs."""
        if self.temperature <= 0 and not self.logit_bias:
            return SMODE_GREEDY
        if self.top_k == 0 and self.top_p >= 1.0 and not self.logit_bias:
            return SMODE_GUMBEL
        return SMODE_MASKED


def bias_row(params: SamplingParams) -> tuple[np.ndarray, np.ndarray]:
    """One request's ``(tokens, values)`` bias row, padded to static shape."""
    bt = np.full(MAX_LOGIT_BIAS, _BIAS_PAD, np.int32)
    bv = np.zeros(MAX_LOGIT_BIAS, np.float32)
    for j, (t, v) in enumerate(params.logit_bias):
        bt[j], bv[j] = t, v
    return bt, bv


def param_rows(slot_params, seeds) -> tuple[np.ndarray, ...]:
    """Per-slot parameter rows for a slot pool: ``slot_params`` is a list of
    ``Optional[SamplingParams]`` (None = free slot), ``seeds`` the resolved
    per-slot seeds.  Returns ``(spf [2,B] f32, spi [2,B] i32, bias_tok
    [B,K] i32, bias_val [B,K] f32)`` with rows (temperature, top_p) and
    (top_k, seed) — the arrays the engine keeps device-resident."""
    b = len(slot_params)
    spf = np.zeros((2, b), np.float32)
    spf[1] = 1.0
    spi = np.zeros((2, b), np.int32)
    btok = np.full((b, MAX_LOGIT_BIAS), _BIAS_PAD, np.int32)
    bval = np.zeros((b, MAX_LOGIT_BIAS), np.float32)
    for i, p in enumerate(slot_params):
        if p is None:
            continue
        spf[0, i] = p.temperature
        spf[1, i] = p.top_p
        spi[0, i] = p.top_k
        spi[1, i] = seeds[i]
        bt, bv = bias_row(p)
        btok[i], bval[i] = bt, bv
    return spf, spi, btok, bval


def _fold_keys(seeds, pos):
    """Per-slot PRNG keys: ``fold_in(key(seed), position)`` — a pure
    function of (request seed, absolute position), the whole reason seeded
    streams survive rechunking, engine swaps, and cluster reconfiguration."""
    return jax.vmap(lambda s, p: jax.random.fold_in(jax.random.key(s), p))(
        seeds, pos
    )


def _keep_mask(scaled, top_k, top_p):
    """Joint top-k/top-p keep mask over temperature-scaled logits [B, V].

    One descending sort serves both criteria: the k-th largest value
    thresholds top-k (``top_k=0`` -> keep all; ties at the threshold are
    kept), and the smallest value whose *exclusive* cumulative softmax mass
    is still below ``top_p`` thresholds the nucleus (so at least one token
    always survives)."""
    v = scaled.shape[-1]
    srt = jnp.sort(scaled, axis=-1)[:, ::-1]  # [B, V] descending
    k_eff = jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v)
    kth = jnp.take_along_axis(srt, k_eff[:, None] - 1, axis=-1)  # [B, 1]
    keep = scaled >= kth
    probs = jax.nn.softmax(srt, axis=-1)
    cum_excl = jnp.cumsum(probs, axis=-1) - probs
    n_keep = jnp.maximum((cum_excl < top_p[:, None]).sum(-1), 1)
    pth = jnp.take_along_axis(srt, n_keep[:, None] - 1, axis=-1)
    return keep & (scaled >= pth)


def fused_sample(logits, temps, top_k, top_p, seeds, pos, bias_tok, bias_val,
                 *, smode: int):
    """ONE device-side sampling decision for every slot — the single sampler
    implementation shared by the decode scan, the packed ragged dispatch,
    the fused admission, and the legacy host path (which jits this on a
    one-row batch).  Change sampling behaviour here, nowhere else.

    logits [B, V] (any float dtype), temps/top_p [B] f32, top_k/seeds/pos
    [B] i32, bias_tok/bias_val [B, MAX_LOGIT_BIAS].  ``smode`` is static:
    0 = argmax only (no PRNG — the bit-identical all-greedy fast path),
    1 = gumbel-max temperature sampling, 2 = logit bias + masked
    renormalized top-k/top-p.  Greedy slots (temp <= 0) inside a sampled
    batch take argmax of the (biased) logits regardless of smode."""
    logits = logits.astype(jnp.float32)
    if smode == SMODE_GREEDY:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if smode == SMODE_MASKED:
        rows = jnp.arange(logits.shape[0])[:, None]
        logits = logits.at[rows, bias_tok].add(bias_val, mode="drop")
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # -inf-masked logits + gumbel, argmaxed, IS the renormalized categorical
    # over the kept set (masked entries stay -inf); the per-(seed, pos) key
    # makes the draw independent of batch composition and chunk boundaries
    gumbel = jax.vmap(
        lambda k: jax.random.gumbel(k, logits.shape[-1:], jnp.float32)
    )(_fold_keys(seeds, pos))
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    if smode == SMODE_MASKED:
        scaled = jnp.where(_keep_mask(scaled, top_k, top_p), scaled, -jnp.inf)
    sampled = jnp.argmax(scaled + gumbel, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def spec_verify(logits, drafts, depth, active, temps, top_k, top_p, seeds,
                pos0, bias_tok, bias_val, *, smode: int):
    """Draft-and-verify acceptance over one packed verify dispatch — the
    speculative member of the ``smode`` zoo, built ON :func:`fused_sample`
    so target tokens and sequential tokens can never drift apart.

    ``logits`` [B*(K+1), V] are the packed rows for slot-major verify
    descriptors ``[last_token, draft_1 .. draft_K]`` per slot: row (i, j)
    holds slot i's logits after consuming its context plus the first j
    drafts, i.e. the prediction for position ``pos0[i] + j + 1``, sampled
    with PRNG position ``pos0[i] + j`` — the engine's pre-increment key
    convention, unchanged.  ``drafts`` [B, K] i32, ``depth``/``active``/
    ``pos0`` [B] i32, sampler rows as in :func:`param_rows` (per-SLOT —
    they are repeated across each slot's K+1 rows here).

    Acceptance is the EXACT-MATCH rule, not stochastic min(1, p/q)
    rejection sampling: this engine's sampler is deterministic given
    (context, seed, position) — the target distribution at each position
    is a point mass on the seeded gumbel-max draw — so the rejection rule
    degenerates to the equality indicator.  Accepting anything the
    sequential engine would not have sampled would break the engine's
    seeded bit-reproducibility guarantee; the price is that acceptance is
    capped by the collision probability of drafter and target streams.
    Under ``smode 0`` the targets are plain argmax rows, so verification
    is argmax prefix agreement and the program stays threefry/sort-free.

    Returns ``(targets [B, K+1], n_accept [B], commit [B])``: ``n_accept``
    is the length of the leading run of drafts equal to the target drawn
    one position earlier, clamped to ``depth``; ``commit = n_accept + 1``
    for active slots (the run plus the bonus token sampled after it — a
    depth-0 slot commits exactly its next sequential token) and 0
    otherwise.  ``targets[i, n_accept[i]]`` is slot i's new last token."""
    b, k = drafts.shape
    w = k + 1
    pos = (pos0[:, None] + jnp.arange(w, dtype=pos0.dtype)[None, :]).reshape(-1)

    def rep(a):
        return jnp.repeat(a, w, axis=0)

    targets = fused_sample(
        logits, rep(temps), rep(top_k), rep(top_p), rep(seeds), pos,
        rep(bias_tok), rep(bias_val), smode=smode,
    ).reshape(b, w)
    if k:
        match = (targets[:, :k] == drafts) & (
            jnp.arange(k)[None, :] < depth[:, None]
        )
        n_accept = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    else:
        n_accept = jnp.zeros(b, jnp.int32)
    commit = jnp.where(active.astype(bool), n_accept + 1, 0)
    return targets, n_accept, commit
