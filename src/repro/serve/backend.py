"""Device-placement backends: where the serving engine's arrays live.

The engine (`serve/engine.py`) is device-agnostic: every host→device
boundary crossing — initial parameter/cache placement, the device-resident
tick state, the per-tick host staging uploads, and program compilation —
goes through ONE of these backends. The engine never calls ``jnp.asarray``
or ``jax.device_put`` itself, so the same tick loop serves three fabrics:

* :class:`DefaultBackend` — the process default device, exactly the
  pre-refactor behaviour (uncommitted ``jnp.asarray`` staging). The gated
  single-device steady-state hot path runs through this backend, so it must
  stay free of per-tick overhead (C3 parity: the cluster layer must not tax
  the engine it grew out of).
* :class:`DeviceBackend` — pins an engine to one explicit device: the
  split-mode fabric, one independent replica per mesh device. Everything,
  including the per-tick host staging, lands directly on that device —
  replicas never serialize through the process default device.
* :class:`ShardedBackend` — tensor-parallel placement over a
  :class:`~repro.dist.sharding.MeshInfo` ``model`` axis: params via
  ``param_shardings`` (attention heads partitioned), the ``[L,B,S,KV,hd]``
  KV cache via ``serve_cache_shardings``, tick state and host staging
  replicated. Dispatch programs are plain ``jax.jit`` — GSPMD partitions
  them from the operand shardings (merge-mode serving).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import (
    MeshInfo,
    param_shardings,
    replicated,
    serve_cache_shardings,
    serve_state_shardings,
)


class PlacementBackend:
    """Default placement: the process default device, uncommitted arrays.

    Subclasses override the four placement hooks; ``jit`` is shared (a
    dispatch program's placement follows its committed operands, so pinning
    or sharding the params/cache/state is sufficient).
    """

    def put_params(self, model, params) -> Any:
        """Place the model parameters (called once at engine construction)."""
        return params

    def put_cache(self, model, cache) -> Any:
        """Place the decode cache pytree (once; donated thereafter)."""
        return cache

    def put_state(self, x) -> Any:
        """Place a device-resident tick-state array (tokens/lengths/PRNG)."""
        return x

    def put_host(self, x) -> Any:
        """Upload a freshly-built host staging array (per-tick path)."""
        return jnp.asarray(x)

    def jit(self, fn, **kwargs) -> Any:
        return jax.jit(fn, **kwargs)

    def describe(self) -> str:
        return "default-device"


# the pre-refactor engine behaviour, importable by name
DefaultBackend = PlacementBackend


class DeviceBackend(PlacementBackend):
    """Pin one engine to one explicit device (a split-mode replica)."""

    def __init__(self, device) -> None:
        self.device = device

    def put_params(self, model, params) -> Any:
        return jax.device_put(params, self.device)

    def put_cache(self, model, cache) -> Any:
        return jax.device_put(cache, self.device)

    def put_state(self, x) -> Any:
        return jax.device_put(x, self.device)

    def put_host(self, x) -> Any:
        # staging lands DIRECTLY on the replica's device: uncommitted
        # jnp.asarray would place it on the process default device and pay
        # an extra hop (and serialize all replicas through device 0) on a
        # real multi-device fabric
        return jax.device_put(x, self.device)

    def describe(self) -> str:
        return f"device:{self.device.id}"


class ShardedBackend(PlacementBackend):
    """Tensor-parallel placement over ``mesh_info`` (merge-mode serving).

    Params shard per ``spec_for_param`` (attention heads on the ``model``
    axis), the KV cache per ``serve_cache_shardings`` (KV heads, head_dim
    fallback), and everything per-slot/host-built replicates — the tick
    loop's descriptors and override lanes are control state, identical on
    every shard, exactly like the paper's merged fabric running under ONE
    controller.
    """

    def __init__(self, mesh_info: MeshInfo) -> None:
        self.mesh_info = mesh_info

    def put_params(self, model, params) -> Any:
        return jax.device_put(params, param_shardings(params, self.mesh_info))

    def put_cache(self, model, cache) -> Any:
        return jax.device_put(
            cache,
            serve_cache_shardings(jax.eval_shape(lambda: cache), self.mesh_info),
        )

    def put_state(self, x) -> Any:
        return jax.device_put(x, serve_state_shardings(x, self.mesh_info))

    def put_host(self, x) -> Any:
        return jax.device_put(jnp.asarray(x), replicated(self.mesh_info))

    def describe(self) -> str:
        mi = self.mesh_info
        return f"sharded:model={mi.model_size},devices={mi.n_devices}"


def resolve_backend(backend: Optional[PlacementBackend]) -> PlacementBackend:
    return backend if backend is not None else DefaultBackend()
